//! Cold-start bench: mmap-load vs heap-load of a packed multi-layer
//! model at the paper shape (§Perf iteration 7 in EXPERIMENTS.md).
//!
//! The claim under test is the artifact subsystem's reason to exist:
//! `LoadMode::Mmap` parses only the container directory and borrows
//! every bulk tensor from the mapping, so "load" is microseconds of
//! header work plus page faults amortized over the first decode steps —
//! while `LoadMode::Heap` pays the full read + decode up front.  Both
//! modes produce bit-identical logits (asserted here per trial).
//!
//! Reported per mode: load ms (artifact open + layer build), first-step
//! ms (page-fault-inclusive prefill of one decode step), steady-step ms,
//! and RSS delta around the load (linux `/proc/self/status`, 0
//! elsewhere).  Writes `runs/tables/cold_start.csv`.
//!
//! Run: `cargo bench --bench cold_start [-- smoke]`
//! `-- smoke` additionally asserts mmap load is faster than heap load
//! (the CI gate) on a reduced trial count.

use std::path::Path;

use butterfly_moe::artifact::{synthesize, LoadMode, Mmap, ModelArtifact, SynthSpec};
use butterfly_moe::bench::Table;
use butterfly_moe::coordinator::{Backend, InflightBatch, InflightSeq, NativeLmBackend};
use butterfly_moe::util::{human_bytes, stats, Stopwatch};

/// VmRSS in KiB from /proc/self/status (0 where unavailable).
fn rss_kib() -> u64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmRSS:") {
                return rest
                    .trim()
                    .trim_end_matches(" kB")
                    .trim()
                    .parse()
                    .unwrap_or(0);
            }
        }
    }
    0
}

struct Trial {
    load_ms: f64,
    first_step_ms: f64,
    steady_step_ms: f64,
    rss_delta_kib: i64,
}

fn batch() -> InflightBatch {
    let mut b = InflightBatch::new();
    for i in 0..4i64 {
        b.push(InflightSeq::new(
            i as u64,
            (0..6).map(|j| ((i * 97 + j * 31) % 512) as i32).collect(),
        ));
    }
    b
}

fn run_trial(path: &Path, mode: LoadMode) -> anyhow::Result<(Trial, Vec<f32>)> {
    let rss0 = rss_kib() as i64;
    let sw = Stopwatch::start();
    let artifact = ModelArtifact::load(path, mode)?;
    let backend = NativeLmBackend::from_artifact(&artifact, 8, None, 0)?;
    let load_ms = sw.millis();
    let rss_delta_kib = rss_kib() as i64 - rss0;
    let sw = Stopwatch::start();
    let mut b = batch();
    let out = backend.step(&mut b)?;
    let first_step_ms = sw.millis();
    let logits = out[0]
        .logits
        .clone()
        .expect("all-at-once prefill emits logits");
    let sw = Stopwatch::start();
    let iters = 3;
    for _ in 0..iters {
        backend.step(&mut b)?;
    }
    Ok((
        Trial {
            load_ms,
            first_step_ms,
            steady_step_ms: sw.millis() / iters as f64,
            rss_delta_kib,
        },
        logits,
    ))
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "smoke");
    let trials = if smoke { 3 } else { 7 };
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;

    // paper shape, 4 residual blocks: a multi-MB artifact dominated by
    // the per-expert angle tables + dense projections
    let spec = SynthSpec::paper(4, 0xC01D);
    eprintln!(
        "synthesizing {} layers x {} experts (d={}, d_ff={})...",
        spec.n_layers, spec.n_experts, spec.d_model, spec.d_ff
    );
    let model = synthesize(&spec);
    let dir = std::env::temp_dir().join("bmoe_cold_start");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("paper_shape.bmoe");
    let pack = model.pack(&path)?;
    drop(model); // the loads below must stand on the file alone
    eprintln!(
        "packed {} ({} tensors, {} pads) -> {}",
        human_bytes(pack.file_bytes as f64),
        pack.tensors,
        pack.pads,
        path.display()
    );

    let modes: Vec<LoadMode> = if Mmap::supported() {
        vec![LoadMode::Heap, LoadMode::Mmap]
    } else {
        eprintln!("(mmap unsupported on this target: heap mode only, no gate)");
        vec![LoadMode::Heap]
    };

    let mut t = Table::new(
        &format!(
            "Cold start at the paper shape ({} on disk, {} layers x {} experts)",
            human_bytes(pack.file_bytes as f64),
            spec.n_layers,
            spec.n_experts
        ),
        &[
            "Load",
            "Load ms (med)",
            "Load ms (p95)",
            "First step ms",
            "Steady step ms",
            "RSS delta",
        ],
    );
    let mut median_load = Vec::new();
    let mut reference_logits: Option<Vec<f32>> = None;
    for &mode in &modes {
        let mut loads = Vec::new();
        let mut firsts = Vec::new();
        let mut steadies = Vec::new();
        let mut rss = Vec::new();
        for _ in 0..trials {
            let (trial, logits) = run_trial(&path, mode)?;
            // the invariant that makes the load mode a free choice:
            // identical logits bits from either loader
            match &reference_logits {
                None => reference_logits = Some(logits),
                Some(want) => anyhow::ensure!(
                    &logits == want,
                    "{} load produced different logits bits",
                    mode.name()
                ),
            }
            loads.push(trial.load_ms);
            firsts.push(trial.first_step_ms);
            steadies.push(trial.steady_step_ms);
            rss.push(trial.rss_delta_kib as f64);
        }
        let med = stats::median(&loads);
        median_load.push((mode, med));
        t.row(&[
            mode.name().to_string(),
            format!("{med:.2}"),
            format!("{:.2}", stats::percentile(&loads, 95.0)),
            format!("{:.2}", stats::median(&firsts)),
            format!("{:.2}", stats::median(&steadies)),
            format!("{}", human_bytes(stats::median(&rss) * 1024.0)),
        ]);
    }
    t.print();
    t.write_csv(&out.join("cold_start.csv"))?;
    println!("wrote runs/tables/cold_start.csv");

    if median_load.len() == 2 {
        let heap = median_load[0].1;
        let mmap = median_load[1].1;
        println!(
            "mmap load {mmap:.2} ms vs heap load {heap:.2} ms ({:.1}x)",
            heap / mmap.max(1e-9)
        );
        if smoke {
            // the acceptance gate (smoke/CI only; a plain measurement
            // run reports without failing)
            anyhow::ensure!(
                mmap < heap,
                "SMOKE FAIL: mmap load ({mmap:.2} ms) not faster than heap load ({heap:.2} ms)"
            );
            println!("cold-start gate OK: mmap < heap");
        }
    }
    Ok(())
}
