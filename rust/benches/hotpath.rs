//! Hot-path microbenchmarks — the §Perf harness.
//!
//! Measures the three native kernels the serving path is made of, across
//! layouts and sizes, plus the gate and the full Alg.-1 mixture:
//!
//!   * ternary GEMV: 2-bit packed vs bitplane vs dense-f32 reference
//!   * butterfly apply: by dimension and depth
//!   * blocked-kernel ablation (§Perf iteration 6): stage-outer blocked
//!     butterfly vs the retained per-row walk, register-blocked GEMM vs
//!     the retained dot-loop reference (outputs bit-identical; only the
//!     schedule differs)
//!   * top-k gate routing
//!   * end-to-end expert mixture (tokens/s)
//!   * expert-parallel scaling: full-forward tokens/s at workers
//!     {1, 2, 4, 8} (CSV + JSON — the `--workers` dial, bit-identical
//!     outputs at every point)
//!
//! Run: `cargo bench --bench hotpath` — results feed EXPERIMENTS.md §Perf
//! and write the machine-readable `BENCH_hotpath.json` at the repo root
//! (median tok/s per config) so future PRs have a perf trajectory to
//! compare against.
//!
//! §Perf iteration 8 adds the runtime-ISA axis: the full run measures
//! every available kernel path (forced via `kernels::dispatch`) at the
//! paper shape, and `BENCH_hotpath.json` records the active `isa` so
//! curves from different CI legs (`BMOE_KERNEL_ISA` matrix) never get
//! compared apples-to-oranges.
//!
//! `cargo bench --bench hotpath -- smoke` (or BMOE_BENCH_SMOKE=1) is the
//! CI gate: a tiny 2-worker scaling check (parallel ≥ sequential) plus
//! blocked-vs-reference kernel checks (blocked ≥ reference tok/s at the
//! bench shape) plus the dispatch gate — the startup-selected ISA path
//! must at least match the blocked-scalar reference (within a 5% noise
//! floor; on a scalar-pinned leg the two are the same path).  It also
//! emits `BENCH_hotpath.json` (mode "smoke").

use std::sync::Arc;

use butterfly_moe::bench::{black_box, Bencher, Table};
use butterfly_moe::butterfly::Butterfly;
use butterfly_moe::kernels::{dispatch, Isa, TernaryScratch};
use butterfly_moe::moe::{ButterflyMoeLayer, GateNetwork, MoeLayer, StandardMoeLayer};
use butterfly_moe::parallel::WorkerPool;
use butterfly_moe::quant::ternary_quantize;
use butterfly_moe::tensor::Tensor;
use butterfly_moe::ternary::{BitplaneTernary, PackedTernary};
use butterfly_moe::util::Rng;

struct BenchProxy {
    median: f64,
}

/// Median full-forward tokens/s of a fresh seeded layer at `workers`
/// threads (same seed ⇒ identical weights across points, so the curve
/// varies only the schedule).
fn forward_tokens_per_sec(
    bencher: &Bencher,
    workers: usize,
    d: usize,
    dff: usize,
    experts: usize,
    batch: usize,
) -> f64 {
    let mut rng = Rng::new(0x5CA1E);
    let mut layer = ButterflyMoeLayer::random(d, dff, experts, 2, None, &mut rng);
    if workers > 1 {
        layer.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
    }
    let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32(1.0)).collect();
    let mut y = vec![0.0f32; batch * d];
    let r = bencher.run(&format!("forward {workers}w"), || {
        layer.forward(&x, batch, &mut y);
        black_box(&y);
    });
    r.throughput(batch as f64)
}

/// Median batched-butterfly rows/s for one kernel variant.
fn butterfly_batch_rows_per_sec(
    bencher: &Bencher,
    d: usize,
    depth: usize,
    rows: usize,
    blocked: bool,
) -> f64 {
    let mut rng = Rng::new(0xB1F);
    let b = Butterfly::random(d, depth, 0.5, &mut rng);
    let mut xb: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32(1.0)).collect();
    let variant = if blocked { "blocked" } else { "per_row" };
    let name = format!("bfly {variant} d{d} l{depth} r{rows}");
    let r = if blocked {
        let mut scratch = Vec::new();
        bencher.run(&name, || {
            b.apply_batch_with(&mut xb, &mut scratch);
            black_box(&xb);
        })
    } else {
        bencher.run(&name, || {
            b.apply_batch_per_row(&mut xb);
            black_box(&xb);
        })
    };
    r.throughput(rows as f64)
}

/// Median ternary-GEMM tokens/s for one kernel variant
/// (`dot_loop` = retained reference, `blocked`, `blocked_a8`).
fn ternary_gemm_tokens_per_sec(
    bencher: &Bencher,
    rows: usize,
    cols: usize,
    t: usize,
    variant: &str,
) -> f64 {
    let mut rng = Rng::new(0x6E3);
    let w = Tensor::rand_normal(&[rows, cols], 0.05, &mut rng);
    let bp = BitplaneTernary::from_quant(&ternary_quantize(&w));
    let x: Vec<f32> = (0..t * cols).map(|_| rng.normal_f32(1.0)).collect();
    let mut y = vec![0.0f32; t * rows];
    let mut scratch = TernaryScratch::default();
    let name = format!("gemm {variant} {rows}x{cols} t{t}");
    let r = match variant {
        "dot_loop" => bencher.run(&name, || {
            bp.gemm_ref(&x, t, &mut y);
            black_box(&y);
        }),
        "blocked" => bencher.run(&name, || {
            bp.gemm_with(&x, t, &mut y, &mut scratch);
            black_box(&y);
        }),
        "blocked_a8" => bencher.run(&name, || {
            bp.gemm_a8_with(&x, t, &mut y, &mut scratch);
            black_box(&y);
        }),
        _ => unreachable!("unknown gemm variant {variant}"),
    };
    r.throughput(t as f64)
}

/// Machine-readable perf trajectory at the repo root: median tok/s per
/// kernel config plus the workers curve — future PRs diff against it.
fn write_bench_json(
    mode: &str,
    isa: Isa,
    kernels: &[String],
    workers: &[String],
) -> std::io::Result<()> {
    let body = format!(
        "{{\n  \"schema\": \"bmoe_hotpath_v1\",\n  \"mode\": \"{mode}\",\n  \
         \"isa\": \"{isa}\",\n  \
         \"kernels\": [\n{}\n  ],\n  \"workers\": [\n{}\n  ]\n}}\n",
        kernels.join(",\n"),
        workers.join(",\n"),
    );
    std::fs::write("BENCH_hotpath.json", body)?;
    println!("\nwrote BENCH_hotpath.json (mode {mode})");
    Ok(())
}

fn kernel_json_row(kernel: &str, variant: &str, config: &str, tps: f64) -> String {
    format!(
        "    {{\"kernel\": \"{kernel}\", \"variant\": \"{variant}\", \
         \"config\": \"{config}\", \"tokens_per_sec\": {tps:.1}}}"
    )
}

fn worker_json_row(workers: usize, tps: f64, speedup: f64) -> String {
    format!(
        "{{\"workers\": {workers}, \"tokens_per_sec\": {tps:.1}, \
         \"speedup\": {speedup:.3}}}"
    )
}

/// CI smoke gate: quick samples, best-of-3 per point to damp scheduler
/// noise on small CI boxes.  Exits nonzero unless (a) the 2-worker
/// parallel schedule at least matches the sequential one, and (b) each
/// blocked kernel at least matches its retained reference at the bench
/// shape.  Emits `BENCH_hotpath.json` (mode "smoke") with the points it
/// measured.
fn smoke() -> anyhow::Result<()> {
    let bencher = Bencher::quick();
    // the startup-selected path (BMOE_KERNEL_ISA in the CI matrix, else
    // detection) — everything below runs on it unless explicitly forced
    let active = dispatch::active();
    println!("[smoke] kernel ISA: {active}");
    let (d, dff, e, batch) = (256usize, 1024usize, 8usize, 32usize);
    let best = |f: &dyn Fn() -> f64| (0..3).map(|_| f()).fold(0.0f64, f64::max);
    let seq = best(&|| forward_tokens_per_sec(&bencher, 1, d, dff, e, batch));
    let par = best(&|| forward_tokens_per_sec(&bencher, 2, d, dff, e, batch));
    println!(
        "[smoke] sequential {seq:.0} tok/s | 2 workers {par:.0} tok/s ({:.2}x)",
        par / seq
    );
    // blocked vs reference kernels at the bench (paper) shape
    let (bd, bdepth, brows) = (512usize, Butterfly::max_depth(512), 32usize);
    let bf_ref = best(&|| butterfly_batch_rows_per_sec(&bencher, bd, bdepth, brows, false));
    let bf_blk = best(&|| butterfly_batch_rows_per_sec(&bencher, bd, bdepth, brows, true));
    println!(
        "[smoke] butterfly d{bd} l{bdepth} r{brows}: per-row {bf_ref:.0} rows/s | \
         blocked {bf_blk:.0} rows/s ({:.2}x)",
        bf_blk / bf_ref
    );
    let (grows, gcols, gt) = (2048usize, 512usize, 32usize);
    let gm_ref = best(&|| ternary_gemm_tokens_per_sec(&bencher, grows, gcols, gt, "dot_loop"));
    let gm_blk = best(&|| ternary_gemm_tokens_per_sec(&bencher, grows, gcols, gt, "blocked"));
    println!(
        "[smoke] gemm {grows}x{gcols} t{gt}: dot-loop {gm_ref:.0} tok/s | \
         blocked {gm_blk:.0} tok/s ({:.2}x)",
        gm_blk / gm_ref
    );
    // dispatch gate: the startup-selected path must at least match the
    // blocked-scalar reference.  5% noise floor: on a scalar-pinned leg
    // both measurements are the same code, and best-of-3 medians on
    // shared CI boxes still jitter a few percent.
    dispatch::force_isa(Isa::Scalar)?;
    let bf_s = best(&|| butterfly_batch_rows_per_sec(&bencher, bd, bdepth, brows, true));
    let gm_s = best(&|| ternary_gemm_tokens_per_sec(&bencher, grows, gcols, gt, "blocked"));
    let a8_s = best(&|| ternary_gemm_tokens_per_sec(&bencher, grows, gcols, gt, "blocked_a8"));
    dispatch::force_isa(active)?;
    let bf_d = best(&|| butterfly_batch_rows_per_sec(&bencher, bd, bdepth, brows, true));
    let gm_d = best(&|| ternary_gemm_tokens_per_sec(&bencher, grows, gcols, gt, "blocked"));
    let a8_d = best(&|| ternary_gemm_tokens_per_sec(&bencher, grows, gcols, gt, "blocked_a8"));
    println!(
        "[smoke] isa {active} vs scalar: butterfly {bf_d:.0}/{bf_s:.0} rows/s | \
         gemm {gm_d:.0}/{gm_s:.0} tok/s | a8 {a8_d:.0}/{a8_s:.0} tok/s"
    );
    let bcfg = format!("d{bd}_l{bdepth}_r{brows}");
    let gcfg = format!("{grows}x{gcols}_t{gt}");
    let kernel_rows = vec![
        kernel_json_row("butterfly_batch", "per_row", &bcfg, bf_ref),
        kernel_json_row("butterfly_batch", "blocked", &bcfg, bf_blk),
        kernel_json_row("ternary_gemm", "dot_loop", &gcfg, gm_ref),
        kernel_json_row("ternary_gemm", "blocked", &gcfg, gm_blk),
        kernel_json_row("butterfly_batch", "blocked_scalar", &bcfg, bf_s),
        kernel_json_row("ternary_gemm", "blocked_scalar", &gcfg, gm_s),
        kernel_json_row("ternary_gemm", "blocked_a8_scalar", &gcfg, a8_s),
        kernel_json_row("butterfly_batch", &format!("blocked_{active}"), &bcfg, bf_d),
        kernel_json_row("ternary_gemm", &format!("blocked_{active}"), &gcfg, gm_d),
        kernel_json_row("ternary_gemm", &format!("blocked_a8_{active}"), &gcfg, a8_d),
    ];
    let worker_rows = vec![
        format!("    {}", worker_json_row(1, seq, 1.0)),
        format!("    {}", worker_json_row(2, par, par / seq)),
    ];
    write_bench_json("smoke", active, &kernel_rows, &worker_rows)?;
    anyhow::ensure!(
        par >= seq,
        "parallel ({par:.0} tok/s) must be >= sequential ({seq:.0} tok/s)"
    );
    anyhow::ensure!(
        bf_blk >= bf_ref,
        "blocked butterfly ({bf_blk:.0} rows/s) must be >= per-row ({bf_ref:.0} rows/s)"
    );
    anyhow::ensure!(
        gm_blk >= gm_ref,
        "blocked gemm ({gm_blk:.0} tok/s) must be >= dot-loop ({gm_ref:.0} tok/s)"
    );
    anyhow::ensure!(
        bf_d >= 0.95 * bf_s && gm_d >= 0.95 * gm_s && a8_d >= 0.95 * a8_s,
        "dispatched ISA {active} slower than blocked-scalar: butterfly \
         {bf_d:.0}/{bf_s:.0} rows/s, gemm {gm_d:.0}/{gm_s:.0} tok/s, \
         a8 {a8_d:.0}/{a8_s:.0} tok/s"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if std::env::args().any(|a| a == "smoke" || a == "--smoke")
        || std::env::var("BMOE_BENCH_SMOKE").is_ok_and(|v| v == "1")
    {
        return smoke();
    }
    let bencher = Bencher::default();
    let mut rng = Rng::new(0x407);
    let out = std::path::Path::new("runs/tables");
    std::fs::create_dir_all(out)?;

    // ------------------------------------------------------------------
    // ternary GEMV layouts (d_ff x d_model = 2048 x 512, paper shape)
    // ------------------------------------------------------------------
    let (dff, d) = (2048usize, 512usize);
    let w = Tensor::rand_normal(&[dff, d], 0.05, &mut rng);
    let tq = ternary_quantize(&w);
    let packed = PackedTernary::from_quant(&tq);
    let bitplane = BitplaneTernary::from_quant(&tq);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(1.0)).collect();
    let mut y = vec![0.0f32; dff];

    let mut t = Table::new(
        "Ternary GEMV (2048x512), one token",
        &["Layout", "Median", "GB/s (weight bits)", "vs dense f32"],
    );
    let dense_w = tq.dequantize();
    let r_dense = bencher.run("dense f32", || {
        for r in 0..dff {
            let row = dense_w.row(r);
            let mut acc = 0.0f32;
            for c in 0..d {
                acc += row[c] * x[c];
            }
            y[r] = acc;
        }
        black_box(&y);
    });
    let r_packed = bencher.run("2-bit packed", || {
        packed.gemv(&x, &mut y);
        black_box(&y);
    });
    let r_bitplane = bencher.run("bitplane", || {
        bitplane.gemv(&x, &mut y);
        black_box(&y);
    });
    let r_sparse = bencher.run("bitplane sparse", || {
        bitplane.gemv_sparse(&x, &mut y);
        black_box(&y);
    });
    // batched: 16 tokens through one decode-amortized GEMM
    let xb16: Vec<f32> = (0..16 * d).map(|_| rng.normal_f32(1.0)).collect();
    let mut yb16 = vec![0.0f32; 16 * dff];
    let r_gemm = bencher.run("bitplane gemm b16", || {
        bitplane.gemm(&xb16, 16, &mut yb16);
        black_box(&yb16);
    });
    let r_gemm_scaled = BenchProxy {
        median: r_gemm.median_secs() / 16.0,
    };
    let weight_bits = (dff * d) as f64 * 2.0 / 8.0; // bytes touched (2-bit)
    for (name, r, bytes) in [
        ("dense f32", &r_dense, (dff * d * 4) as f64),
        ("2-bit packed", &r_packed, weight_bits),
        ("bitplane (branchless)", &r_bitplane, weight_bits),
        ("bitplane (sparse walk)", &r_sparse, weight_bits),
    ] {
        t.row(&[
            name.to_string(),
            butterfly_moe::bench::format_secs(r.median_secs()),
            format!("{:.2}", bytes / r.median_secs() / 1e9),
            format!("{:.2}x", r_dense.median_secs() / r.median_secs()),
        ]);
    }
    t.row(&[
        "bitplane gemm (per token, b=16)".to_string(),
        butterfly_moe::bench::format_secs(r_gemm_scaled.median),
        format!("{:.2}", weight_bits / 16.0 / r_gemm_scaled.median / 1e9),
        format!("{:.2}x", r_dense.median_secs() / r_gemm_scaled.median),
    ]);
    t.print();
    t.write_csv(&out.join("hotpath_gemv.csv"))?;

    // ------------------------------------------------------------------
    // butterfly apply
    // ------------------------------------------------------------------
    let mut t = Table::new(
        "Butterfly apply (one vector)",
        &["d", "depth", "Median", "M rot-pairs/s"],
    );
    for d in [256usize, 512, 2048] {
        for depth in [2usize, Butterfly::max_depth(d)] {
            let b = Butterfly::random(d, depth, 0.5, &mut rng);
            let mut v: Vec<f32> = (0..d).map(|_| rng.normal_f32(1.0)).collect();
            let r = bencher.run(&format!("bfly d{d} l{depth}"), || {
                b.apply(&mut v);
                black_box(&v);
            });
            let pairs = (d / 2 * depth) as f64;
            t.row(&[
                d.to_string(),
                depth.to_string(),
                butterfly_moe::bench::format_secs(r.median_secs()),
                format!("{:.1}", pairs / r.median_secs() / 1e6),
            ]);
        }
    }
    t.print();
    t.write_csv(&out.join("hotpath_butterfly.csv"))?;

    // ------------------------------------------------------------------
    // blocked-kernel ablation (§Perf iteration 6): old vs new schedules,
    // bit-identical outputs.  Feeds BENCH_hotpath.json.
    // ------------------------------------------------------------------
    let mut kernel_rows: Vec<String> = Vec::new();
    let mut t = Table::new(
        "Blocked butterfly vs per-row (batched apply, bit-identical)",
        &["d", "depth", "rows", "per-row rows/s", "blocked rows/s", "Speedup"],
    );
    for (d, rows) in [(512usize, 16usize), (512, 64), (2048, 16)] {
        let depth = Butterfly::max_depth(d);
        let per_row = butterfly_batch_rows_per_sec(&bencher, d, depth, rows, false);
        let blocked = butterfly_batch_rows_per_sec(&bencher, d, depth, rows, true);
        t.row(&[
            d.to_string(),
            depth.to_string(),
            rows.to_string(),
            format!("{per_row:.0}"),
            format!("{blocked:.0}"),
            format!("{:.2}x", blocked / per_row),
        ]);
        let cfg = format!("d{d}_l{depth}_r{rows}");
        kernel_rows.push(kernel_json_row("butterfly_batch", "per_row", &cfg, per_row));
        kernel_rows.push(kernel_json_row("butterfly_batch", "blocked", &cfg, blocked));
    }
    t.print();
    t.write_csv(&out.join("hotpath_butterfly_blocked.csv"))?;

    let mut t = Table::new(
        "Blocked ternary GEMM vs dot-loop (2048x512, bit-identical)",
        &["t", "dot-loop tok/s", "blocked tok/s", "Speedup", "blocked a8 tok/s"],
    );
    for tt in [4usize, 16, 64] {
        let dot_loop = ternary_gemm_tokens_per_sec(&bencher, dff, d, tt, "dot_loop");
        let blocked = ternary_gemm_tokens_per_sec(&bencher, dff, d, tt, "blocked");
        let blocked_a8 = ternary_gemm_tokens_per_sec(&bencher, dff, d, tt, "blocked_a8");
        t.row(&[
            tt.to_string(),
            format!("{dot_loop:.0}"),
            format!("{blocked:.0}"),
            format!("{:.2}x", blocked / dot_loop),
            format!("{blocked_a8:.0}"),
        ]);
        let cfg = format!("{dff}x{d}_t{tt}");
        kernel_rows.push(kernel_json_row("ternary_gemm", "dot_loop", &cfg, dot_loop));
        kernel_rows.push(kernel_json_row("ternary_gemm", "blocked", &cfg, blocked));
        kernel_rows.push(kernel_json_row("ternary_gemm", "blocked_a8", &cfg, blocked_a8));
    }
    t.print();
    t.write_csv(&out.join("hotpath_gemm_blocked.csv"))?;

    // ------------------------------------------------------------------
    // per-ISA curves (§Perf iteration 8): the same blocked kernels on
    // every available dispatch path at the paper shape.  f32 outputs
    // are bit-identical across paths (tests/kernels.rs); only the
    // instruction selection differs.
    // ------------------------------------------------------------------
    let active = dispatch::active();
    let mut t = Table::new(
        "Kernel ISA curves (blocked kernels, paper shape, bit-identical)",
        &["ISA", "bfly rows/s", "gemm tok/s", "a8 tok/s"],
    );
    let idepth = Butterfly::max_depth(512);
    for isa in Isa::ALL {
        if !isa.available() {
            println!("skipping ISA {isa}: unavailable on this machine");
            continue;
        }
        dispatch::force_isa(isa)?;
        let bf = butterfly_batch_rows_per_sec(&bencher, 512, idepth, 32, true);
        let gm = ternary_gemm_tokens_per_sec(&bencher, dff, d, 16, "blocked");
        let a8 = ternary_gemm_tokens_per_sec(&bencher, dff, d, 16, "blocked_a8");
        t.row(&[
            isa.name().to_string(),
            format!("{bf:.0}"),
            format!("{gm:.0}"),
            format!("{a8:.0}"),
        ]);
        let bcfg = format!("d512_l{idepth}_r32");
        let gcfg = format!("{dff}x{d}_t16");
        let bv = format!("blocked_{isa}");
        let av = format!("blocked_a8_{isa}");
        kernel_rows.push(kernel_json_row("butterfly_batch", &bv, &bcfg, bf));
        kernel_rows.push(kernel_json_row("ternary_gemm", &bv, &gcfg, gm));
        kernel_rows.push(kernel_json_row("ternary_gemm", &av, &gcfg, a8));
    }
    dispatch::force_isa(active)?;
    t.print();
    t.write_csv(&out.join("hotpath_isa.csv"))?;

    // ------------------------------------------------------------------
    // gate + full mixture, butterfly vs standard (paper layer shape)
    // ------------------------------------------------------------------
    let batch = 16usize;
    let gate = GateNetwork::new(Tensor::rand_normal(&[8, 512], 0.1, &mut rng), 2);
    let xb = Tensor::rand_normal(&[batch, 512], 1.0, &mut rng);
    let r_gate = bencher.run("gate route_batch", || {
        black_box(gate.route_batch(&xb.data, batch));
    });

    let mut bf_layer = ButterflyMoeLayer::random(512, 2048, 8, 2, None, &mut rng);
    let std_layer = StandardMoeLayer::random(512, 2048, 8, 2, &mut rng);
    let mut h = vec![0.0f32; batch * 2048];
    let r_bf = bencher.run("butterfly experts_forward", || {
        bf_layer.experts_forward(&xb.data, batch, &mut h);
        black_box(&h);
    });
    bf_layer.act_quant = true;
    let r_bf_a8 = bencher.run("butterfly experts_forward a8", || {
        bf_layer.experts_forward(&xb.data, batch, &mut h);
        black_box(&h);
    });
    bf_layer.act_quant = false;
    let r_std = bencher.run("standard experts_forward", || {
        std_layer.experts_forward(&xb.data, batch, &mut h);
        black_box(&h);
    });

    let mut t = Table::new(
        "MoE layer hot path (d=512, d_ff=2048, 8 experts, top-2, batch 16)",
        &["Stage", "Median", "tokens/s"],
    );
    for (name, r) in [
        ("gate routing", &r_gate),
        ("butterfly mixture (exact)", &r_bf),
        ("butterfly mixture (W1.58A8)", &r_bf_a8),
        ("standard mixture (dense f32)", &r_std),
    ] {
        t.row(&[
            name.to_string(),
            butterfly_moe::bench::format_secs(r.median_secs()),
            format!("{:.0}", r.throughput(batch as f64)),
        ]);
    }
    t.print();
    t.write_csv(&out.join("hotpath_layer.csv"))?;
    println!(
        "\ngate overhead: {:.1}% of the butterfly mixture",
        100.0 * r_gate.median_secs() / r_bf.median_secs()
    );

    // ------------------------------------------------------------------
    // expert-parallel scaling: full forward (mixture + GELU + shared
    // down projection) tokens/s vs worker count, paper layer shape.
    // Outputs are bit-identical at every point (tests/determinism.rs).
    // ------------------------------------------------------------------
    let (sd, sdff, sexp, sbatch) = (512usize, 2048usize, 8usize, 16usize);
    let mut t = Table::new(
        "Expert-parallel scaling (d=512, d_ff=2048, 8 experts top-2, batch 16)",
        &["Workers", "tokens/s", "Speedup", "Efficiency"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let mut worker_rows: Vec<String> = Vec::new();
    let mut seq_tps = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let tps = forward_tokens_per_sec(&bencher, workers, sd, sdff, sexp, sbatch);
        if workers == 1 {
            seq_tps = tps;
        }
        let speedup = tps / seq_tps.max(1e-9);
        t.row(&[
            workers.to_string(),
            format!("{tps:.0}"),
            format!("{speedup:.2}x"),
            format!("{:.0}%", 100.0 * speedup / workers as f64),
        ]);
        let row = worker_json_row(workers, tps, speedup);
        json_rows.push(format!("  {row}"));
        worker_rows.push(format!("    {row}"));
    }
    t.print();
    t.write_csv(&out.join("hotpath_scaling.csv"))?;
    std::fs::write(
        out.join("hotpath_scaling.json"),
        format!("[\n{}\n]\n", json_rows.join(",\n")),
    )?;
    println!("\nwrote runs/tables/hotpath_scaling.csv and hotpath_scaling.json");
    write_bench_json("full", dispatch::active(), &kernel_rows, &worker_rows)?;
    Ok(())
}
