//! Table 3 — energy per inference vs expert count (standard vs
//! butterfly), with the DRAM/compute breakdown and the abstract's
//! "up to 99.5% bandwidth energy reduction" claim.
//!
//! Run: `cargo bench --bench table3_energy`

use std::path::Path;

use butterfly_moe::bench::{paper_tables, Table};
use butterfly_moe::energy::{butterfly_moe_energy, standard_moe_energy};
use butterfly_moe::memmodel::LayerShape;

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    paper_tables::table3(out)?;

    // breakdown view
    let s = LayerShape::paper();
    let mut t = Table::new(
        "Energy breakdown (µJ): DRAM vs compute",
        &["Experts", "Std DRAM", "Std compute", "Bf DRAM", "Bf compute"],
    );
    for n in [8usize, 64, 256] {
        let e1 = standard_moe_energy(n, 2, s);
        let e2 = butterfly_moe_energy(n, 2, s);
        t.row(&[
            n.to_string(),
            format!("{:.1}", e1.dram_nj / 1e3),
            format!("{:.2}", e1.compute_nj / 1e3),
            format!("{:.3}", e2.dram_nj / 1e3),
            format!("{:.3}", e2.compute_nj / 1e3),
        ]);
    }
    t.print();
    t.write_csv(&out.join("table3_breakdown.csv"))?;

    println!("\npaper rows (nJ): 8->320/4.05 (98.7%), 64->2560/18.54 (99.3%),");
    println!("256->10240/68.22 (99.3%).  Their absolute scale implies a much");
    println!("smaller energy/bit constant than the 6.4 pJ/bit they cite; the");
    println!("savings-percentage column — the claim — reproduces (see above).");
    Ok(())
}
