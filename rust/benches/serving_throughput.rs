//! Serving throughput/latency bench: the generation-session coordinator
//! under an open-loop Poisson session load with a mixed-length workload
//! (short 4-token and long 32-token budgets) — the systems-side
//! evaluation of the L3 contribution.
//!
//! Reports sustained tokens/sec, TTFT, inter-token latency, and the
//! continuous-batching headline: short sessions *overtake* long ones
//! that were submitted earlier, instead of convoying behind them.
//! Prefill and decode throughput are measured and reported separately —
//! both as columns of the session CSVs (prompt tokens and decoded
//! tokens move at very different rates through the same engine loop)
//! and as a dedicated two-window measurement written to the
//! machine-readable `BENCH_serving.json` at the repo root.
//!
//! Runs the native backend always, and the PJRT LM backend when
//! `make artifacts` has produced `artifacts/manifest.json`.
//!
//! Run: `cargo bench --bench serving_throughput`
//! `cargo bench --bench serving_throughput -- smoke` (or
//! `BMOE_BENCH_SMOKE=1`) is the CI gate: only the prefill/decode
//! split runs, `BENCH_serving.json` (mode "smoke") is written, and the
//! bench exits nonzero unless chunked prefill moves prompt tokens at
//! least as fast as the decode loop moves generated ones.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::bench::Table;
use butterfly_moe::coordinator::{
    collect_stream, Backend, Coordinator, GenerateRequest, NativeLmBackend, NativeMoeBackend,
    PjrtLmBackend, SchedulerConfig,
};
use butterfly_moe::moe::ButterflyMoeLayer;
use butterfly_moe::parallel::WorkerPool;
use butterfly_moe::util::{stats, Rng};

const SHORT_TOKENS: usize = 4;
const LONG_TOKENS: usize = 32;

struct WorkloadResult {
    /// decoded (generated) tokens per wall second
    tok_per_sec: f64,
    /// prompt tokens consumed per wall second over the same window
    prefill_tok_per_sec: f64,
    ttft: Vec<f64>,
    short_e2e: Vec<f64>,
    long_e2e: Vec<f64>,
    /// short sessions that finished before an earlier-submitted long one
    overtakes: usize,
    occupancy: f64,
    itl_p50: f64,
}

/// Open-loop Poisson arrivals at `sps` sessions/sec for `seconds`;
/// every 4th session is long.  Latencies are server-side (from the
/// event stream), completion ordering is reconstructed from submit
/// time + end-to-end duration.
fn drive(
    coord: &Coordinator,
    vocab: usize,
    sps: f64,
    seconds: f64,
    rng: &mut Rng,
) -> anyhow::Result<WorkloadResult> {
    let t0 = Instant::now();
    let mut pending = Vec::new(); // (is_long, submitted_at_secs, rx)
    let mut next = 0.0f64;
    let mut n = 0usize;
    while t0.elapsed().as_secs_f64() < seconds {
        let now = t0.elapsed().as_secs_f64();
        if now >= next {
            let is_long = n % 4 == 3;
            let budget = if is_long { LONG_TOKENS } else { SHORT_TOKENS };
            let prompt: Vec<i32> = (0..8).map(|_| rng.below(vocab) as i32).collect();
            pending.push((is_long, now, coord.submit(GenerateRequest::greedy(prompt, budget))));
            n += 1;
            next += rng.exponential(sps);
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    let mut ttft = Vec::new();
    let mut short_e2e = Vec::new();
    let mut long_e2e = Vec::new();
    let mut finished = Vec::new(); // (is_long, submitted, finished)
    let mut tokens = 0u64;
    for (is_long, submitted, rx) in pending {
        let c = collect_stream(&rx, Duration::from_secs(120))?;
        tokens += c.tokens.len() as u64;
        if let Some(t) = c.ttft {
            ttft.push(t.as_secs_f64());
        }
        let e2e = c.total.as_secs_f64();
        if is_long {
            long_e2e.push(e2e);
        } else {
            short_e2e.push(e2e);
        }
        finished.push((is_long, submitted, submitted + e2e));
    }
    let wall = t0.elapsed().as_secs_f64();
    // a short session "overtakes" when some long session submitted
    // earlier finishes later
    let mut overtakes = 0;
    for &(is_long, sub, fin) in &finished {
        if is_long {
            continue;
        }
        if finished
            .iter()
            .any(|&(l, lsub, lfin)| l && lsub < sub && lfin > fin)
        {
            overtakes += 1;
        }
    }
    let snap = coord.metrics.snapshot();
    Ok(WorkloadResult {
        tok_per_sec: tokens as f64 / wall,
        prefill_tok_per_sec: snap.prefill_tokens as f64 / wall,
        ttft,
        short_e2e,
        long_e2e,
        overtakes,
        occupancy: snap.mean_batch_size,
        itl_p50: snap.itl_p50,
    })
}

fn bench_backend(
    label: &str,
    make: impl Fn() -> Arc<dyn Backend>,
    vocab: usize,
    loads: &[f64],
    seconds: f64,
    out: &Path,
    rng: &mut Rng,
) -> anyhow::Result<()> {
    let mut t = Table::new(
        &format!("Serving sessions ({label}): mixed 4/32-token workload, batch<=16, wait<=2ms"),
        &[
            "Offered sess/s",
            "Decode tok/s",
            "Prefill tok/s",
            "Occupancy",
            "TTFT p50 ms",
            "TTFT p99 ms",
            "ITL p50 ms",
            "Short e2e p50 ms",
            "Long e2e p50 ms",
            "Short overtakes",
        ],
    );
    for &sps in loads {
        let backend = make();
        // warm every compiled batch bucket so XLA compilation stays out
        // of the measured window
        butterfly_moe::coordinator::warm(backend.as_ref())?;
        let coord =
            Coordinator::start(backend, SchedulerConfig::new(16, Duration::from_millis(2)));
        let r = drive(&coord, vocab, sps, seconds, rng)?;
        t.row(&[
            format!("{sps:.0}"),
            format!("{:.0}", r.tok_per_sec),
            format!("{:.0}", r.prefill_tok_per_sec),
            format!("{:.1}", r.occupancy),
            format!("{:.2}", 1e3 * stats::percentile(&r.ttft, 50.0)),
            format!("{:.2}", 1e3 * stats::percentile(&r.ttft, 99.0)),
            format!("{:.3}", 1e3 * r.itl_p50),
            format!("{:.2}", 1e3 * stats::percentile(&r.short_e2e, 50.0)),
            format!("{:.2}", 1e3 * stats::percentile(&r.long_e2e, 50.0)),
            format!("{}/{}", r.overtakes, r.short_e2e.len()),
        ]);
        coord.shutdown();
    }
    t.print();
    t.write_csv(out)?;
    Ok(())
}

/// Closed-loop serving throughput vs worker count: same seeded native
/// backend at `--workers` ∈ {1, 2, 4, 8}, a fixed 48-session × 16-token
/// greedy workload, sustained tokens/s measured end-to-end through the
/// coordinator.  Decoded streams are asserted identical across points —
/// the scaling dial must never change output bits.
fn bench_worker_scaling(out: &Path) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Serving scaling (native-moe d=256 d_ff=1024, 8 experts top-2): tokens/s vs --workers",
        &["Workers", "tok/s", "Speedup", "Session p50 ms"],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let mut base_tps = 0.0f64;
    let mut reference_streams: Option<Vec<Vec<i32>>> = None;
    for workers in [1usize, 2, 4, 8] {
        let mut layer_rng = Rng::new(7);
        let mut layer = ButterflyMoeLayer::random(256, 1024, 8, 2, None, &mut layer_rng);
        layer.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
        let backend: Arc<dyn Backend> =
            Arc::new(NativeMoeBackend::new(Arc::new(layer), 512, 32, 16));
        butterfly_moe::coordinator::warm(backend.as_ref())?;
        let coord =
            Coordinator::start(backend, SchedulerConfig::new(16, Duration::from_millis(2)));
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..48)
            .map(|i| {
                let prompt: Vec<i32> = (0..8).map(|j| ((i * 89 + j * 13) % 512) as i32).collect();
                coord.submit(GenerateRequest::greedy(prompt, 16))
            })
            .collect();
        let mut tokens = 0u64;
        let mut e2e = Vec::new();
        let mut streams = Vec::new();
        for rx in rxs {
            let c = collect_stream(&rx, Duration::from_secs(120))?;
            tokens += c.tokens.len() as u64;
            e2e.push(c.total.as_secs_f64());
            streams.push(c.tokens);
        }
        let wall = t0.elapsed().as_secs_f64();
        coord.shutdown();
        match &reference_streams {
            None => reference_streams = Some(streams),
            Some(want) => anyhow::ensure!(
                &streams == want,
                "workers={workers}: decoded streams diverged from workers=1"
            ),
        }
        let tps = tokens as f64 / wall;
        if workers == 1 {
            base_tps = tps;
        }
        t.row(&[
            workers.to_string(),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / base_tps.max(1e-9)),
            format!("{:.2}", 1e3 * stats::percentile(&e2e, 50.0)),
        ]);
        json_rows.push(format!(
            "  {{\"workers\": {workers}, \"tokens_per_sec\": {tps:.1}, \
             \"speedup\": {:.3}}}",
            tps / base_tps.max(1e-9)
        ));
    }
    t.print();
    t.write_csv(&out.join("serving_scaling.csv"))?;
    std::fs::write(
        out.join("serving_scaling.json"),
        format!("[\n{}\n]\n", json_rows.join(",\n")),
    )?;
    println!("wrote runs/tables/serving_scaling.csv and serving_scaling.json");
    Ok(())
}

/// Serving throughput vs model depth: the Table-2 per-layer scaling on
/// the hot path instead of only analytically.  Synthesized native LMs at
/// `L ∈ {1, 2, 4}` residual blocks (same per-layer shape and seed
/// family), a fixed closed-loop 24-session × 16-token greedy workload.
/// ms/token should scale ~linearly in L (each decode step runs L
/// expert mixtures + down projections).
fn bench_layer_scaling(out: &Path) -> anyhow::Result<()> {
    use butterfly_moe::artifact::{synthesize, ShTensor, SynthSpec};
    use butterfly_moe::moe::MoeLayer;
    let mut t = Table::new(
        "Serving depth scaling (native-lm d=256 d_ff=1024, 8 experts top-2): tokens/s vs layers",
        &["Layers", "tok/s", "ms/token", "Session p50 ms"],
    );
    for n_layers in [1usize, 2, 4] {
        let spec = SynthSpec {
            d_model: 256,
            d_ff: 1024,
            n_experts: 8,
            top_k: 2,
            n_layers,
            vocab: 512,
            seq_len: 32,
            depth: None,
            seed: 7,
        };
        let model = synthesize(&spec);
        let pool = Arc::new(WorkerPool::new(
            butterfly_moe::parallel::resolve_workers(0),
        ));
        let layers: Vec<Arc<dyn MoeLayer>> = model
            .layers
            .into_iter()
            .map(|mut l| {
                l.attach_worker_pool(pool.clone());
                Arc::new(l) as Arc<dyn MoeLayer>
            })
            .collect();
        let backend: Arc<dyn Backend> = Arc::new(NativeLmBackend::from_layers(
            layers,
            ShTensor::from_tensor(model.embed),
            ShTensor::from_tensor(model.readout),
            spec.vocab,
            spec.seq_len,
            16,
        ));
        butterfly_moe::coordinator::warm(backend.as_ref())?;
        let coord =
            Coordinator::start(backend, SchedulerConfig::new(16, Duration::from_millis(2)));
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..24)
            .map(|i| {
                let prompt: Vec<i32> = (0..8).map(|j| ((i * 89 + j * 13) % 512) as i32).collect();
                coord.submit(GenerateRequest::greedy(prompt, 16))
            })
            .collect();
        let mut tokens = 0u64;
        let mut e2e = Vec::new();
        for rx in rxs {
            let c = collect_stream(&rx, Duration::from_secs(120))?;
            tokens += c.tokens.len() as u64;
            e2e.push(c.total.as_secs_f64());
        }
        let wall = t0.elapsed().as_secs_f64();
        coord.shutdown();
        let tps = tokens as f64 / wall;
        t.row(&[
            n_layers.to_string(),
            format!("{tps:.0}"),
            format!("{:.3}", 1e3 / tps.max(1e-9)),
            format!("{:.2}", 1e3 * stats::percentile(&e2e, 50.0)),
        ]);
    }
    t.print();
    t.write_csv(&out.join("serving_layers.csv"))?;
    println!("wrote runs/tables/serving_layers.csv");
    Ok(())
}

/// Two-window prefill-vs-decode split over the seeded native backend,
/// written to `BENCH_serving.json` at the repo root.
///
/// Window A (prefill): sessions whose prompt fills the whole model
/// window (32 tokens) decode a single token each, with `--prefill-chunk
/// 8`, so nearly all engine work is chunked prompt ingestion.  Window B
/// (decode): 1-token prompts generate 32 tokens each, so nearly all
/// work is the one-token-per-tick decode loop.  Chunked prefill shares
/// one dispatch-block gather across every token of a chunk and crosses
/// the session channel zero times mid-prompt, so its tokens/s must be
/// at least the decode loop's — `smoke` turns that into a hard gate.
fn bench_prefill_vs_decode(mode: &str) -> anyhow::Result<(f64, f64)> {
    const PREFILL_CHUNK: usize = 8;
    const PROMPT: usize = 32; // == seq_len: the full model window
    let sessions = if mode == "smoke" { 24 } else { 96 };
    let make_coord = |chunk: usize| {
        let mut layer_rng = Rng::new(7);
        let mut layer = ButterflyMoeLayer::random(256, 1024, 8, 2, None, &mut layer_rng);
        layer.attach_worker_pool(Arc::new(WorkerPool::new(
            butterfly_moe::parallel::resolve_workers(0),
        )));
        let backend: Arc<dyn Backend> =
            Arc::new(NativeMoeBackend::new(Arc::new(layer), 512, PROMPT, 16));
        butterfly_moe::coordinator::warm(backend.as_ref()).unwrap();
        Coordinator::start(
            backend,
            SchedulerConfig::new(16, Duration::from_millis(2)).with_prefill_chunk(chunk),
        )
    };
    let run = |coord: &Coordinator, prompt_len: usize, budget: usize| -> anyhow::Result<f64> {
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..sessions)
            .map(|i| {
                let prompt: Vec<i32> = (0..prompt_len)
                    .map(|j| ((i * 89 + j * 13) % 512) as i32)
                    .collect();
                coord.submit(GenerateRequest::greedy(prompt, budget))
            })
            .collect();
        for rx in rxs {
            collect_stream(&rx, Duration::from_secs(120))?;
        }
        Ok(t0.elapsed().as_secs_f64())
    };

    // window A: full-window prompts, one decoded token each
    let coord = make_coord(PREFILL_CHUNK);
    let wall = run(&coord, PROMPT, 1)?;
    let snap = coord.metrics.snapshot();
    anyhow::ensure!(snap.prefill_tokens == (sessions * PROMPT) as u64);
    let prefill_tok_s = snap.prefill_tokens as f64 / wall;
    coord.shutdown();

    // window B: one-token prompts, full decode budgets
    let coord = make_coord(PREFILL_CHUNK);
    let wall = run(&coord, 1, PROMPT)?;
    let decode_tok_s = (sessions * PROMPT) as f64 / wall;
    coord.shutdown();

    println!(
        "[prefill/decode] chunk {PREFILL_CHUNK}: prefill {prefill_tok_s:.0} tok/s | \
         decode {decode_tok_s:.0} tok/s ({:.2}x)",
        prefill_tok_s / decode_tok_s.max(1e-9)
    );
    let body = format!(
        "{{\n  \"schema\": \"bmoe_serving_v1\",\n  \"mode\": \"{mode}\",\n  \
         \"prefill_chunk\": {PREFILL_CHUNK},\n  \"sessions\": {sessions},\n  \
         \"prompt_tokens\": {PROMPT},\n  \"prefill_tok_s\": {prefill_tok_s:.1},\n  \
         \"decode_tok_s\": {decode_tok_s:.1}\n}}\n"
    );
    std::fs::write("BENCH_serving.json", body)?;
    println!("wrote BENCH_serving.json (mode {mode})");
    Ok((prefill_tok_s, decode_tok_s))
}

fn main() -> anyhow::Result<()> {
    if std::env::args().any(|a| a == "smoke" || a == "--smoke")
        || std::env::var("BMOE_BENCH_SMOKE").is_ok_and(|v| v == "1")
    {
        let (prefill, decode) = bench_prefill_vs_decode("smoke")?;
        anyhow::ensure!(
            prefill >= decode,
            "SMOKE FAIL: chunked prefill ({prefill:.0} tok/s) slower than \
             the decode loop ({decode:.0} tok/s)"
        );
        println!("serving gate OK: prefill tok/s >= decode tok/s");
        return Ok(());
    }
    let out = std::path::Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    let mut rng = Rng::new(0x5EE);

    // prefill vs decode split + BENCH_serving.json (reported, not gated,
    // outside smoke)
    bench_prefill_vs_decode("full")?;

    // tokens/s-vs-workers scaling curve for the native backend
    bench_worker_scaling(out)?;

    // tokens/s-vs-depth curve for the multi-layer native LM
    bench_layer_scaling(out)?;

    // native edge backend: always available; hot path parallel by
    // default (BMOE_WORKERS env overrides, streams identical regardless)
    let mut layer_rng = Rng::new(7);
    let layer = {
        let mut l = ButterflyMoeLayer::random(256, 1024, 8, 2, None, &mut layer_rng);
        l.attach_worker_pool(Arc::new(WorkerPool::new(
            butterfly_moe::parallel::resolve_workers(0),
        )));
        Arc::new(l)
    };
    bench_backend(
        "native-moe",
        || Arc::new(NativeMoeBackend::new(layer.clone(), 512, 32, 16)),
        512,
        &[20.0, 80.0, 320.0],
        3.0,
        &out.join("serving_sessions_native.csv"),
        &mut rng,
    )?;

    // PJRT LM backend: needs compiled artifacts
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let (backend, _join) = PjrtLmBackend::start(artifacts, "tiny", None)?;
        let backend: Arc<dyn Backend> = Arc::new(backend);
        let vocab = backend.vocab();
        bench_backend(
            "pjrt-lm:tiny",
            || backend.clone(),
            vocab,
            &[5.0, 20.0],
            3.0,
            &out.join("serving_sessions_pjrt.csv"),
            &mut rng,
        )?;
        std::process::exit(0); // engine thread would otherwise hold the process
    } else {
        println!("(skipping PJRT backend: run `make artifacts` to enable)");
    }
    Ok(())
}
