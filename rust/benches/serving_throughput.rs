//! Serving throughput/latency bench: the coordinator over the native
//! backend (edge scenario) under increasing load and across batching
//! policies — the systems-side evaluation of the L3 contribution.
//!
//! Run: `cargo bench --bench serving_throughput`

use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::bench::Table;
use butterfly_moe::coordinator::{Coordinator, NativeMoeBackend};
use butterfly_moe::moe::ButterflyMoeLayer;
use butterfly_moe::util::{stats, Rng};

fn drive(
    coord: &Coordinator,
    rps: f64,
    seconds: f64,
    rng: &mut Rng,
) -> (f64, Vec<f64>) {
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut next = 0.0f64;
    while t0.elapsed().as_secs_f64() < seconds {
        let now = t0.elapsed().as_secs_f64();
        if now >= next {
            let prompt: Vec<i32> = (0..8).map(|_| rng.below(512) as i32).collect();
            pending.push(coord.submit(prompt));
            next += rng.exponential(rps);
        } else {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    let mut lats = Vec::with_capacity(pending.len());
    for rx in pending {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        lats.push(resp.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    (lats.len() as f64 / wall, lats)
}

fn main() -> anyhow::Result<()> {
    let out = std::path::Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    let mut rng = Rng::new(0x5EE);
    let layer = Arc::new(ButterflyMoeLayer::random(256, 1024, 8, 2, None, &mut rng));

    // load sweep at a fixed policy
    let mut t = Table::new(
        "Serving: offered load sweep (native backend, batch<=16, wait<=2ms)",
        &["Offered rps", "Served rps", "p50 ms", "p95 ms", "p99 ms", "mean batch"],
    );
    for rps in [50.0f64, 200.0, 800.0] {
        let backend = Arc::new(NativeMoeBackend::new(layer.clone(), 512, 32, 16));
        let coord = Coordinator::start(backend, 16, Duration::from_millis(2), 2);
        let (served, lats) = drive(&coord, rps, 3.0, &mut rng);
        let snap = coord.metrics.snapshot();
        t.row(&[
            format!("{rps:.0}"),
            format!("{served:.0}"),
            format!("{:.2}", 1e3 * stats::percentile(&lats, 50.0)),
            format!("{:.2}", 1e3 * stats::percentile(&lats, 95.0)),
            format!("{:.2}", 1e3 * stats::percentile(&lats, 99.0)),
            format!("{:.1}", snap.mean_batch_size),
        ]);
        coord.shutdown();
    }
    t.print();
    t.write_csv(&out.join("serving_load_sweep.csv"))?;

    // batching-policy ablation at fixed load
    let mut t = Table::new(
        "Serving: batching policy ablation (400 rps offered)",
        &["max_batch", "max_wait ms", "Served rps", "p50 ms", "p99 ms", "mean batch"],
    );
    for (mb, mw) in [(1usize, 0u64), (4, 1), (16, 2), (16, 10)] {
        let backend = Arc::new(NativeMoeBackend::new(layer.clone(), 512, 32, 16));
        let coord = Coordinator::start(backend, mb, Duration::from_millis(mw), 2);
        let (served, lats) = drive(&coord, 400.0, 3.0, &mut rng);
        let snap = coord.metrics.snapshot();
        t.row(&[
            mb.to_string(),
            mw.to_string(),
            format!("{served:.0}"),
            format!("{:.2}", 1e3 * stats::percentile(&lats, 50.0)),
            format!("{:.2}", 1e3 * stats::percentile(&lats, 99.0)),
            format!("{:.1}", snap.mean_batch_size),
        ]);
        coord.shutdown();
    }
    t.print();
    t.write_csv(&out.join("serving_policy_ablation.csv"))?;
    Ok(())
}
