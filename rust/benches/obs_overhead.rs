//! Observability overhead bench: proves `--trace-sample` at the
//! documented default rate ([`DEFAULT_SAMPLE`]) costs at most 2% of
//! decode throughput versus tracing off, and that the decoded token
//! streams are bit-identical either way (the determinism contract of
//! DESIGN.md §7, pinned independently by rust/tests/determinism.rs).
//!
//! Method: a fixed closed-loop workload (N greedy sessions × M tokens
//! over the seeded native backend) decoded repeatedly with tracing off
//! and at rate [`DEFAULT_SAMPLE`], interleaved A/B so drift (thermal,
//! page cache, scheduler) hits both arms equally.  The headline is the
//! ratio of median tok/s.
//!
//! Output: machine-readable `BENCH_obs.json` at the repo root.
//!
//! Run: `cargo bench --bench obs_overhead`
//! CI:  `cargo bench --bench obs_overhead -- smoke` — smaller workload,
//! same gates: streams identical, sampling actually recorded, ratio
//! >= 0.98.

use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::coordinator::{
    collect_stream, warm, Backend, Coordinator, GenerateRequest, NativeMoeBackend,
    SchedulerConfig,
};
use butterfly_moe::moe::ButterflyMoeLayer;
use butterfly_moe::obs::trace::{self, DEFAULT_SAMPLE};
use butterfly_moe::parallel::WorkerPool;
use butterfly_moe::util::Rng;

struct RunResult {
    tokens_per_sec: f64,
    streams: Vec<Vec<i32>>,
    /// Stage occurrences recorded into the trace registry during the run.
    samples: u64,
}

/// Decode the fixed workload once at `sample` rate; the backend is
/// rebuilt and warmed outside the measured window.
fn decode_run(sample: u32, sessions: usize, budget: usize) -> anyhow::Result<RunResult> {
    trace::set_sample(sample);
    trace::reset();
    let mut layer_rng = Rng::new(7);
    let mut layer = ButterflyMoeLayer::random(128, 512, 8, 2, None, &mut layer_rng);
    layer.attach_worker_pool(Arc::new(WorkerPool::new(2)));
    let backend: Arc<dyn Backend> = Arc::new(NativeMoeBackend::new(Arc::new(layer), 512, 32, 16));
    warm(backend.as_ref())?;
    let coord = Coordinator::start(backend, SchedulerConfig::new(16, Duration::from_millis(2)));
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..sessions)
        .map(|i| {
            let prompt: Vec<i32> = (0..8).map(|j| ((i * 89 + j * 13) % 512) as i32).collect();
            coord.submit(GenerateRequest::greedy(prompt, budget))
        })
        .collect();
    let mut tokens = 0u64;
    let mut streams = Vec::new();
    for rx in rxs {
        let c = collect_stream(&rx, Duration::from_secs(120))?;
        tokens += c.tokens.len() as u64;
        streams.push(c.tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    let samples: u64 = trace::snapshot().iter().map(|s| s.hist.n).sum();
    trace::set_sample(0);
    trace::reset();
    Ok(RunResult {
        tokens_per_sec: tokens as f64 / wall,
        streams,
        samples,
    })
}

fn median(v: &mut Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn run(mode: &str) -> anyhow::Result<()> {
    let smoke = mode == "smoke";
    let (sessions, budget, reps) = if smoke { (12, 16, 3) } else { (32, 32, 5) };

    let mut off_tps = Vec::new();
    let mut on_tps = Vec::new();
    let mut on_samples = 0u64;
    let mut reference: Option<Vec<Vec<i32>>> = None;
    for rep in 0..reps {
        // interleave the arms so environmental drift cancels
        let off = decode_run(0, sessions, budget)?;
        let on = decode_run(DEFAULT_SAMPLE, sessions, budget)?;
        anyhow::ensure!(
            off.samples == 0,
            "tracing off must record nothing, got {} samples",
            off.samples
        );
        anyhow::ensure!(
            on.samples > 0,
            "rate {DEFAULT_SAMPLE} recorded no samples — instrumentation not hit"
        );
        on_samples += on.samples;
        match &reference {
            None => reference = Some(off.streams.clone()),
            Some(want) => anyhow::ensure!(
                &off.streams == want,
                "rep {rep}: tracing-off streams diverged across reps"
            ),
        }
        anyhow::ensure!(
            off.streams == on.streams,
            "rep {rep}: tracing at rate {DEFAULT_SAMPLE} changed decoded bits"
        );
        off_tps.push(off.tokens_per_sec);
        on_tps.push(on.tokens_per_sec);
    }
    let off_med = median(&mut off_tps);
    let on_med = median(&mut on_tps);
    let ratio = on_med / off_med.max(1e-9);
    println!(
        "obs overhead ({mode}): off {off_med:.0} tok/s, sample {DEFAULT_SAMPLE} {on_med:.0} tok/s \
         (ratio {ratio:.4}, {on_samples} stage samples over {reps} reps)"
    );

    let body = format!(
        "{{\n  \"schema\": \"bmoe_obs_v1\",\n  \"mode\": \"{mode}\",\n  \
         \"sample_rate\": {DEFAULT_SAMPLE},\n  \
         \"sessions\": {sessions},\n  \"budget\": {budget},\n  \"reps\": {reps},\n  \
         \"tokens_per_sec_off\": {off_med:.1},\n  \
         \"tokens_per_sec_sampled\": {on_med:.1},\n  \
         \"ratio\": {ratio:.4},\n  \
         \"stage_samples\": {on_samples},\n  \
         \"streams_identical\": true\n}}\n"
    );
    std::fs::write("BENCH_obs.json", body)?;
    println!("wrote BENCH_obs.json (mode {mode})");

    anyhow::ensure!(
        ratio >= 0.98,
        "tracing at rate {DEFAULT_SAMPLE} cost more than 2% of throughput: \
         {on_med:.0} vs {off_med:.0} tok/s (ratio {ratio:.4})"
    );
    println!("gates OK: streams identical, {on_samples} samples recorded, ratio {ratio:.4} >= 0.98");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "smoke" || a == "--smoke")
        || std::env::var("BMOE_BENCH_SMOKE").is_ok_and(|v| v == "1");
    run(if smoke { "smoke" } else { "full" })
}
