//! Chaos bench: deterministic fault schedules against a REAL fleet —
//! the robustness companion to `router_load` (DESIGN.md §8).
//!
//! Boots `bmoe route` machinery over real child `bmoe serve --native
//! --model <tiny.bmoe> --load mmap` processes and drives sequential
//! generation sessions while a seeded fault plan SIGKILLs placed
//! workers mid-stream (`kill_after` relayed tokens).  Because the
//! engine's determinism contract pins bit-identical streams across
//! workers, every completed session is compared token-for-token against
//! a fault-free reference — failover must be invisible to the client.
//!
//! Reports, per fault level: sessions completed / shed / lost,
//! failovers taken, replayed (verified + suppressed) tokens, and how
//! long the fleet took to return to full healthy capacity after the
//! plan cleared.
//!
//! Output: `runs/tables/chaos.csv` and machine-readable
//! `BENCH_chaos.json` at the repo root.
//!
//! Run: `cargo bench --bench chaos`
//! CI:  `cargo bench --bench chaos -- smoke` — one kill per run, gating
//! zero lost accepted sessions, >= 1 failover, bit-identical completed
//! streams, and recovery to full capacity.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::artifact::{synthesize, SynthSpec};
use butterfly_moe::bench::Table;
use butterfly_moe::faults::{self, FaultPlan};
use butterfly_moe::router::{worker::ProcessLauncher, Router, RouterConfig};

const BUDGET: usize = 24;
const KILL_AFTER: u64 = 8;

fn pack_tiny_model(dir: &Path) -> anyhow::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join("chaos_bench_tiny.bmoe");
    let spec = SynthSpec {
        d_model: 64,
        d_ff: 256,
        n_experts: 4,
        top_k: 2,
        n_layers: 1,
        vocab: 128,
        seq_len: 32,
        depth: None,
        seed: 7,
    };
    synthesize(&spec).pack(&path)?;
    Ok(path)
}

fn boot_router(model: &Path, fleet: usize) -> anyhow::Result<(Arc<Router>, SocketAddr)> {
    let bin = std::path::PathBuf::from(env!("CARGO_BIN_EXE_bmoe"));
    let wargs: Vec<String> = [
        "--native",
        "--model",
        model.to_str().unwrap(),
        "--load",
        "mmap",
        "--max-batch",
        "8",
        "--workers",
        "1",
        "--no-warmup",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let cfg = RouterConfig {
        port: 0,
        fleet,
        sessions_per_worker: 8,
        max_queue: 32,
        client_cap: 0,
        health_interval: Duration::from_millis(100),
        backoff_base: Duration::from_millis(100),
        failover_retries: 4,
        failover_wait: Duration::from_secs(30),
        ..RouterConfig::default()
    };
    let (listener, addr) = butterfly_moe::util::net::listen_reuse(0)?;
    let router = Router::start(cfg, Arc::new(ProcessLauncher::new(bin, wargs)))?;
    {
        let router = router.clone();
        std::thread::spawn(move || router.serve(listener));
    }
    Ok((router, addr))
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Completed,
    Shed,
    Lost,
}

/// One session over the wire; returns the outcome and the deterministic
/// payload (`<index> <token>`) of every TOK line, for bit-identity
/// comparison against the fault-free reference.
fn run_session(addr: SocketAddr, gen: &str) -> (Outcome, Vec<String>) {
    let mut payloads = Vec::new();
    let Ok(mut s) = TcpStream::connect(addr) else {
        return (Outcome::Lost, payloads);
    };
    s.set_nodelay(true).ok();
    if writeln!(s, "{gen}").is_err() {
        return (Outcome::Lost, payloads);
    }
    let mut reader = BufReader::new(s);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return (Outcome::Lost, payloads),
            Ok(_) => {}
        }
        if let Some(rest) = line.strip_prefix("TOK ") {
            let mut it = rest.split_whitespace();
            if let (Some(i), Some(t)) = (it.next(), it.next()) {
                payloads.push(format!("{i} {t}"));
            }
        } else if line.starts_with("END shed") || line.starts_with("END shutdown") {
            return (Outcome::Shed, payloads);
        } else if line.starts_with("END ") {
            return (Outcome::Completed, payloads);
        } else {
            return (Outcome::Lost, payloads);
        }
    }
}

fn wait_full_capacity(router: &Router, fleet: usize, budget: Duration) -> anyhow::Result<f64> {
    let t0 = Instant::now();
    while router.fleet.healthy() != fleet {
        anyhow::ensure!(
            t0.elapsed() < budget,
            "fleet never returned to full capacity ({}/{fleet} healthy)",
            router.fleet.healthy()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    Ok(1e3 * t0.elapsed().as_secs_f64())
}

struct Level {
    name: &'static str,
    kill_prob: f64,
    kill_limit: u64,
    sessions: usize,
}

struct LevelResult {
    completed: usize,
    shed: usize,
    lost: usize,
    mismatched: usize,
    failovers: u64,
    replayed: u64,
    recovery_ms: f64,
}

/// Run one fault level: install the plan, drive sequential sessions,
/// clear the plan, and wait out fleet recovery.
fn drive_level(
    router: &Arc<Router>,
    addr: SocketAddr,
    fleet: usize,
    gen: &str,
    reference: &[String],
    level: &Level,
) -> anyhow::Result<LevelResult> {
    let failovers0 = router.stats.failovers.load(Ordering::Relaxed);
    let replayed0 = router.stats.replayed_tokens.lock().unwrap().sum as u64;
    faults::install(FaultPlan {
        seed: 0xC4A05,
        kill_after: if level.kill_prob > 0.0 { KILL_AFTER } else { 0 },
        kill_prob: level.kill_prob,
        kill_limit: level.kill_limit,
        ..FaultPlan::default()
    });
    let (mut completed, mut shed, mut lost, mut mismatched) = (0usize, 0usize, 0usize, 0usize);
    for _ in 0..level.sessions {
        let (outcome, payloads) = run_session(addr, gen);
        match outcome {
            Outcome::Completed => {
                completed += 1;
                if payloads != reference {
                    mismatched += 1;
                }
            }
            Outcome::Shed => shed += 1,
            Outcome::Lost => lost += 1,
        }
    }
    faults::clear();
    let recovery_ms = wait_full_capacity(router, fleet, Duration::from_secs(60))?;
    Ok(LevelResult {
        completed,
        shed,
        lost,
        mismatched,
        failovers: router.stats.failovers.load(Ordering::Relaxed) - failovers0,
        replayed: router.stats.replayed_tokens.lock().unwrap().sum as u64 - replayed0,
        recovery_ms,
    })
}

fn level_json_row(l: &Level, r: &LevelResult) -> String {
    format!(
        "    {{\"level\": \"{}\", \"kill_prob\": {:.2}, \"kill_limit\": {}, \
         \"sessions\": {}, \"completed\": {}, \"shed\": {}, \"lost\": {}, \
         \"mismatched\": {}, \"failovers\": {}, \"replayed_tokens\": {}, \
         \"recovery_ms\": {:.0}}}",
        l.name,
        l.kill_prob,
        l.kill_limit,
        l.sessions,
        r.completed,
        r.shed,
        r.lost,
        r.mismatched,
        r.failovers,
        r.replayed,
        r.recovery_ms,
    )
}

fn write_bench_json(mode: &str, levels: &[String]) -> std::io::Result<()> {
    let body = format!(
        "{{\n  \"schema\": \"bmoe_chaos_v1\",\n  \"mode\": \"{mode}\",\n  \
         \"budget_tokens\": {BUDGET},\n  \"kill_after\": {KILL_AFTER},\n  \
         \"levels\": [\n{}\n  ]\n}}\n",
        levels.join(",\n"),
    );
    std::fs::write("BENCH_chaos.json", body)?;
    println!("\nwrote BENCH_chaos.json (mode {mode})");
    Ok(())
}

fn run(mode: &str) -> anyhow::Result<()> {
    let smoke = mode == "smoke";
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    let model = pack_tiny_model(out)?;
    let fleet = 2usize;
    let gen = format!("GEN {BUDGET} 0 0 0 -1 1 2");
    let levels: &[Level] = if smoke {
        &[Level { name: "one_kill", kill_prob: 1.0, kill_limit: 1, sessions: 8 }]
    } else {
        &[
            Level { name: "calm", kill_prob: 0.0, kill_limit: 0, sessions: 16 },
            Level { name: "kill_half", kill_prob: 0.5, kill_limit: 0, sessions: 24 },
            Level { name: "kill_every", kill_prob: 1.0, kill_limit: 0, sessions: 24 },
        ]
    };

    let (router, addr) = boot_router(&model, fleet)?;
    // fault-free reference stream: the bit-identity yardstick for every
    // completed session below
    let (outcome, reference) = run_session(addr, &gen);
    anyhow::ensure!(outcome == Outcome::Completed, "reference session failed");
    anyhow::ensure!(reference.len() == BUDGET, "reference length {}", reference.len());

    let mut table = Table::new(
        &format!("Chaos schedules (fleet={fleet}, kill after {KILL_AFTER} of {BUDGET} tokens)"),
        &[
            "Level",
            "Kill prob",
            "Sessions",
            "Completed",
            "Shed",
            "Lost",
            "Mismatched",
            "Failovers",
            "Replayed tok",
            "Recovery ms",
        ],
    );
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for level in levels {
        let r = drive_level(&router, addr, fleet, &gen, &reference, level)?;
        table.row(&[
            level.name.to_string(),
            format!("{:.2}", level.kill_prob),
            level.sessions.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.lost.to_string(),
            r.mismatched.to_string(),
            r.failovers.to_string(),
            r.replayed.to_string(),
            format!("{:.0}", r.recovery_ms),
        ]);
        rows.push(level_json_row(level, &r));
        results.push(r);
    }
    let lossless = router.drain();
    table.print();
    table.write_csv(&out.join("chaos.csv"))?;
    write_bench_json(mode, &rows)?;

    // ------------------------------------------------------------------
    // gates: failover must be invisible — no accepted session lost or
    // shed, every completed stream bit-identical, fleet recovered
    // ------------------------------------------------------------------
    for (level, r) in levels.iter().zip(&results) {
        anyhow::ensure!(
            r.lost == 0,
            "level {}: {} accepted session(s) lost — failover must absorb kills",
            level.name,
            r.lost
        );
        anyhow::ensure!(r.shed == 0, "level {}: {} shed under sequential load", level.name, r.shed);
        anyhow::ensure!(
            r.completed == level.sessions,
            "level {}: {}/{} sessions completed",
            level.name,
            r.completed,
            level.sessions
        );
        anyhow::ensure!(
            r.mismatched == 0,
            "level {}: {} completed stream(s) diverged from the fault-free reference",
            level.name,
            r.mismatched
        );
        if level.kill_prob >= 1.0 {
            anyhow::ensure!(
                r.failovers >= 1,
                "level {}: kills were scheduled but no failover happened",
                level.name
            );
        }
    }
    anyhow::ensure!(lossless, "final drain must be loss-free");
    let total_failovers: u64 = results.iter().map(|r| r.failovers).sum();
    println!(
        "gates OK: every session completed bit-identically through {total_failovers} failover(s), \
         0 lost, fleet recovered after every level"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "smoke" || a == "--smoke")
        || std::env::var("BMOE_BENCH_SMOKE").is_ok_and(|v| v == "1");
    run(if smoke { "smoke" } else { "full" })
}
