//! Fig. 5 — expert specialization: pairwise cosine similarity between
//! expert outputs and the diversity score, ButterflyMoE vs standard MoE.
//!
//! Expert outputs are computed on embedded tokens from the synthetic
//! corpus (the checkpoint's own embedding table), block-0 FFN, per
//! expert with gating disabled — the paper's "expert output similarity"
//! quantity.  diversity = 1 - mean off-diagonal cosine.
//!
//! Trains checkpoints on first run (cached in runs/figs/).
//! Run: `cargo bench --bench fig5_similarity`

use std::path::Path;

use butterfly_moe::bench::Table;
use butterfly_moe::data::{CorpusConfig, SyntheticCorpus};
use butterfly_moe::moe::ButterflyMoeLayer;
use butterfly_moe::runtime::Engine;
use butterfly_moe::tensor::store::TensorStore;
use butterfly_moe::train::ensure_checkpoint;
use butterfly_moe::util::stats::cosine_similarity;

fn steps() -> usize {
    std::env::var("BMOE_FIG_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150)
}

/// Embed `t` corpus tokens with the checkpoint's embedding table.
fn embedded_batch(store: &TensorStore, vocab: usize, t: usize) -> anyhow::Result<Vec<f32>> {
    let embed = store.get_f32("embed.tok")?;
    let d = embed.shape[1];
    let mut corpus = SyntheticCorpus::new(CorpusConfig {
        vocab,
        seed: 0x515,
        ..CorpusConfig::default()
    });
    let mut x = vec![0.0f32; t * d];
    for i in 0..t {
        let tok = corpus.next_token() as usize % vocab;
        x[i * d..(i + 1) * d].copy_from_slice(embed.row(tok));
    }
    Ok(x)
}

/// Per-expert outputs (flattened over the batch) for a butterfly layer.
fn butterfly_expert_outputs(
    store: &TensorStore,
    x: &[f32],
    t: usize,
    top_k: usize,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let layer = ButterflyMoeLayer::from_store(store, "blocks.0.ffn.", top_k)?;
    let (d, dff) = (store.get_f32("blocks.0.ffn.w_base")?.shape[1],
                    store.get_f32("blocks.0.ffn.w_base")?.shape[0]);
    let e = layer.experts.len();
    let mut outs = vec![Vec::with_capacity(t * dff); e];
    let mut scratch = vec![0.0f32; d];
    let mut y = vec![0.0f32; dff];
    for ei in 0..e {
        for ti in 0..t {
            layer.expert_forward(ei, &x[ti * d..(ti + 1) * d], &mut scratch, &mut y);
            outs[ei].extend_from_slice(&y);
        }
    }
    Ok(outs)
}

/// Per-expert outputs for the standard-MoE baseline (dense w_up (E,dff,d)).
fn standard_expert_outputs(
    store: &TensorStore,
    x: &[f32],
    t: usize,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let w = store.get_f32("blocks.0.ffn.w_up")?;
    let (e, dff, d) = (w.shape[0], w.shape[1], w.shape[2]);
    let mut outs = vec![Vec::with_capacity(t * dff); e];
    for ei in 0..e {
        let we = &w.data[ei * dff * d..(ei + 1) * dff * d];
        for ti in 0..t {
            let xi = &x[ti * d..(ti + 1) * d];
            for r in 0..dff {
                let row = &we[r * d..(r + 1) * d];
                let mut acc = 0.0f32;
                for c in 0..d {
                    acc += row[c] * xi[c];
                }
                outs[ei].push(acc);
            }
        }
    }
    Ok(outs)
}

fn report(name: &str, outs: &[Vec<f32>]) -> (f64, f64) {
    let e = outs.len();
    println!("\n== {name}: pairwise |cosine| matrix ==");
    let mut sum = 0.0;
    let mut count = 0;
    let mut max_od: f64 = 0.0;
    for i in 0..e {
        let mut row = String::new();
        for j in 0..e {
            let c = cosine_similarity(&outs[i], &outs[j]).abs();
            row.push_str(&format!(" {c:.3}"));
            if i != j {
                sum += c;
                count += 1;
                max_od = max_od.max(c);
            }
        }
        println!("  e{i}:{row}");
    }
    let mean_od = sum / count as f64;
    let diversity = 1.0 - mean_od;
    println!("  mean off-diag {mean_od:.3}, max {max_od:.3}, diversity {diversity:.3}");
    (mean_od, diversity)
}

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/figs");
    std::fs::create_dir_all(out)?;
    let engine = Engine::new(Path::new("artifacts"))?;
    let cfg = engine.manifest.config("tiny")?.clone();
    let n = steps();
    let t = 128usize;

    let bf_ck = TensorStore::read(&ensure_checkpoint(&engine, "tiny", n, out)?)?;
    let std_ck = TensorStore::read(&ensure_checkpoint(&engine, "tiny_standard", n, out)?)?;
    let init = TensorStore::read(&engine.manifest.dir.join("tiny.params.bmoe"))?;

    let x = embedded_batch(&bf_ck, cfg.vocab, t)?;
    let (_, div_bf) = report(
        &format!("ButterflyMoE (trained {n} steps)"),
        &butterfly_expert_outputs(&bf_ck, &x, t, cfg.top_k)?,
    );
    let x0 = embedded_batch(&init, cfg.vocab, t)?;
    let (_, div_init) = report(
        "ButterflyMoE (untrained init)",
        &butterfly_expert_outputs(&init, &x0, t, cfg.top_k)?,
    );
    let xs = embedded_batch(&std_ck, cfg.vocab, t)?;
    let (_, div_std) = report(
        &format!("Standard MoE (trained {n} steps)"),
        &standard_expert_outputs(&std_ck, &xs, t)?,
    );

    let mut tab = Table::new(
        "Fig. 5 summary — expert diversity (1 - mean off-diag cosine)",
        &["Model", "Diversity"],
    );
    tab.row(&["ButterflyMoE trained".into(), format!("{div_bf:.3}")]);
    tab.row(&["ButterflyMoE init".into(), format!("{div_init:.3}")]);
    tab.row(&["Standard MoE trained".into(), format!("{div_std:.3}")]);
    tab.print();
    tab.write_csv(&out.join("fig5_similarity.csv"))?;
    println!("\npaper: off-diag 0.08-0.14; diversity 0.87 (butterfly) vs 0.912");
    println!("(standard) — a ~5% gap.  The claim under test: orbit experts do");
    println!("not collapse (diversity stays close to the standard baseline).");
    Ok(())
}
