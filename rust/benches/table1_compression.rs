//! Table 1 — MoE compression method comparison (64 experts, d=512,
//! d_ff=2048).  Two halves:
//!
//!   1. the paper's analytic rows (memory models, Props. 1–2), and
//!   2. *measured* bytes from the working compressor implementations in
//!      `baselines::` applied to real expert tensors (scaled-down shape
//!      so the bench runs in seconds), including ButterflyMoE's actual
//!      packed storage.
//!
//! Run: `cargo bench --bench table1_compression`

use std::path::Path;

use butterfly_moe::baselines::{
    butterfly_measured_bytes, mc_compress, moqe_compress, puzzlemoe_compress, qmoe_compress,
};
use butterfly_moe::bench::{paper_tables, Table};
use butterfly_moe::quant::ternary_quantize;
use butterfly_moe::tensor::Tensor;
use butterfly_moe::ternary::PackedTernary;
use butterfly_moe::util::{human_bytes, Rng};

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;

    // 1. analytic rows at paper scale
    paper_tables::table1(out)?;

    // 2. measured compressors on real tensors (d=256, d_ff=1024, 16
    //    experts keeps the bench under a minute; ratios are shape-stable)
    let (d, dff, n) = (256usize, 1024usize, 16usize);
    let mut rng = Rng::new(0x7AB1E);
    // heavier-tailed weights emulate a trained distribution
    let experts: Vec<Tensor> = (0..n)
        .map(|_| {
            let mut t = Tensor::rand_normal(&[dff, d], 0.05, &mut rng);
            for v in t.data.iter_mut() {
                *v += 0.3 * v.signum() * v.abs().sqrt() * 0.1;
            }
            t
        })
        .collect();
    let raw: usize = experts.iter().map(Tensor::nbytes).sum();

    let mut t = Table::new(
        &format!("Table 1 (measured) — {n} experts, d={d}, d_ff={dff}, fp32 raw {}",
            human_bytes(raw as f64)),
        &["Method", "Measured bytes", "Ratio", "Recon rel-MSE"],
    );
    for r in [
        moqe_compress(&experts),
        qmoe_compress(&experts),
        puzzlemoe_compress(&experts),
        mc_compress(&experts),
    ] {
        t.row(&[
            r.method.to_string(),
            human_bytes(r.bytes as f64),
            format!("{:.1}x", r.ratio_vs_fp32(&experts)),
            format!("{:.4}", r.recon_error),
        ]);
    }
    // ButterflyMoE measured: packed ternary substrate + fp16 angles
    let substrate = Tensor::rand_normal(&[dff, d], 0.05, &mut rng);
    let packed = PackedTernary::from_quant(&ternary_quantize(&substrate));
    let bf_bytes = butterfly_measured_bytes(n, d, dff, packed.nbytes());
    // recon error of the substrate ternarization (the per-expert
    // rotations are exact orthogonal transforms — no additional error)
    let bf_err = butterfly_moe::quant::weight_quant_error(&substrate);
    t.row(&[
        "ButterflyMoE (2-bit pack)".to_string(),
        human_bytes(bf_bytes as f64),
        format!("{:.1}x", raw as f64 / bf_bytes as f64),
        format!("{bf_err:.4}"),
    ]);
    t.print();
    t.write_csv(&out.join("table1_measured.csv"))?;

    println!("\nNOTE: measured ButterflyMoE stores the substrate at 2.0 bits/weight");
    println!("(byte-aligned packing); the paper's 1.58 b/w is the information");
    println!("content — the analytic table above uses the paper's accounting.");
    Ok(())
}
