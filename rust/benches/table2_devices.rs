//! Device deployability table (§4.1) — max experts per memory budget for
//! each method on RPi 5 / Jetson Nano / ESP32, plus bandwidth-derived
//! latency floors per device.
//!
//! Run: `cargo bench --bench table2_devices`

use std::path::Path;

use butterfly_moe::bench::{paper_tables, Table};
use butterfly_moe::devices::ALL_DEVICES;
use butterfly_moe::memmodel::{butterfly_bytes, LayerShape, Method};

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    paper_tables::table_devices(out)?;

    // paper's own rows for side-by-side comparison
    let mut p = Table::new(
        "Paper's printed rows (their budget derivation is not stated)",
        &["Method", "RPi 5", "Jetson", "ESP32"],
    );
    p.row(&["Standard MoE".into(), "63".into(), "31".into(), "0".into()]);
    p.row(&["QMoE".into(), "314".into(), "157".into(), "2".into()]);
    p.row(&["MoQE".into(), "320".into(), "160".into(), "2".into()]);
    p.row(&["ButterflyMoE".into(), "21,079".into(), "10,540".into(), "131".into()]);
    p.print();
    println!("(shape check: ButterflyMoE fits 2-3 orders of magnitude more experts");
    println!(" everywhere, ESP32 goes 0 -> nonzero; our absolute numbers use the");
    println!(" full documented RAM budgets, the paper's imply a ~256 MB working set)");

    // bandwidth floor: time to stream the model once per token
    let s = LayerShape::paper();
    let mut t = Table::new(
        "Bandwidth latency floor per token (stream whole expert set once)",
        &["Device", "Standard 64E", "ButterflyMoE 64E"],
    );
    for dev in ALL_DEVICES {
        let std_s = Method::StandardMoe.bytes(64, s) / dev.mem_bandwidth;
        let bf_s = butterfly_bytes(64, s) / dev.mem_bandwidth;
        t.row(&[
            dev.name.to_string(),
            format!("{:.2} ms", std_s * 1e3),
            format!("{:.3} ms", bf_s * 1e3),
        ]);
    }
    t.print();
    t.write_csv(&out.join("table_devices_bandwidth.csv"))?;
    Ok(())
}
