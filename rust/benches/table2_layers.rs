//! Table 2 — butterfly-depth ablation: params/expert, throughput
//! (tokens/s) and speedup vs the full-depth (9-layer) stack.
//!
//! Paper setup: d=512, batch 16, depths {2,4,6,9}.  We report two
//! measurements per depth on the native engine:
//!
//!   * the **rotation stage alone** (B(theta)^T then B(phi) per routed
//!     token) — the cost the ablation actually varies, where the paper's
//!     "fewer layers => faster" shape must show; and
//!   * the **full Alg.-1 mixture** (gate + rotations + ternary GEMV) —
//!     where we find the bitplane GEMV dominates at d=512 on CPU, so
//!     end-to-end depth sensitivity is small (an honest finding recorded
//!     in EXPERIMENTS.md; the paper's 1.9x presumably reflects a
//!     rotation-bound GPU implementation).
//!
//! Run: `cargo bench --bench table2_layers`

use std::path::Path;

use butterfly_moe::bench::{black_box, Bencher, Table};
use butterfly_moe::butterfly::Butterfly;
use butterfly_moe::memmodel::{butterfly_bytes_depth, LayerShape};
use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer};
use butterfly_moe::tensor::Tensor;
use butterfly_moe::util::{human_bytes, Rng};

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    let (d, n_experts, top_k, batch) = (512usize, 8usize, 2usize, 16usize);
    let depths = [2usize, 4, 6, 9];

    let mut rng = Rng::new(0x7AB1E2);
    let x = Tensor::rand_normal(&[batch, d], 1.0, &mut rng);
    let bencher = Bencher::default();

    // Global warmup: get clocks/caches hot before any measured sweep so
    // the first depth isn't penalized (observed 2x cold-start skew).
    {
        let warm = ButterflyMoeLayer::random(d, d, n_experts, top_k, None, &mut rng);
        let mut h = vec![0.0f32; batch * d];
        let t0 = std::time::Instant::now();
        while t0.elapsed().as_secs_f64() < 1.0 {
            warm.experts_forward(&x.data, batch, &mut h);
            black_box(&h);
        }
    }

    struct Row {
        depth: usize,
        params: usize,
        rot_tps: f64,
        full_tps: f64,
    }
    let mut rows = Vec::new();
    for &depth in &depths {
        let layer = ButterflyMoeLayer::random(d, d, n_experts, top_k, Some(depth), &mut rng);
        // rotation stage alone: k experts' theta^T + phi per token
        let theta = Butterfly::random(d, depth, 0.5, &mut rng);
        let phi = Butterfly::random(d, depth, 0.5, &mut rng);
        let mut buf = x.data.clone();
        let r_rot = bencher.run(&format!("rot d{depth}"), || {
            for row in buf.chunks_exact_mut(d) {
                for _ in 0..top_k {
                    theta.apply_transpose(row);
                    phi.apply(row);
                }
            }
            black_box(&buf);
        });
        let mut h = vec![0.0f32; batch * d];
        let r_full = bencher.run(&format!("full d{depth}"), || {
            layer.experts_forward(&x.data, batch, &mut h);
            black_box(&h);
        });
        rows.push(Row {
            depth,
            params: 2 * depth * d / 2,
            rot_tps: r_rot.throughput(batch as f64),
            full_tps: r_full.throughput(batch as f64),
        });
    }
    let base_rot = rows.last().unwrap().rot_tps;
    let base_full = rows.last().unwrap().full_tps;

    let mut t = Table::new(
        "Table 2 — butterfly-depth ablation (d=512, batch 16, top-2, native engine)",
        &[
            "Layers",
            "Params/Expert",
            "Rotation tok/s",
            "Rot speedup",
            "Full-layer tok/s",
            "Full speedup",
            "Expert mem (64E)",
        ],
    );
    for r in &rows {
        t.row(&[
            r.depth.to_string(),
            r.params.to_string(),
            format!("{:.0}", r.rot_tps),
            format!("{:.2}x", r.rot_tps / base_rot),
            format!("{:.0}", r.full_tps),
            format!("{:.2}x", r.full_tps / base_full),
            human_bytes(butterfly_bytes_depth(
                64,
                LayerShape { d_model: d, d_ff: d },
                r.depth,
            )),
        ]);
    }
    t.print();
    t.write_csv(&out.join("table2_layers.csv"))?;
    println!("\npaper rows (T4 GPU, WikiText-2): 2->71594 tok/s (1.90x), 4->76026");
    println!("(1.42x), 6->58495 (1.25x), 9->45383 (1.0x).  Shape check: the");
    println!("rotation stage reproduces 'fewer layers => proportionally faster';");
    println!("end-to-end, our bitplane ternary GEMV dominates at d=512 so the");
    println!("full-layer column is depth-insensitive on this CPU testbed.");
    Ok(())
}
