//! Fig. 3 — memory consumption vs expert count (standard vs butterfly),
//! plus the same curve for every Table 1 method and an ASCII rendering.
//!
//! Run: `cargo bench --bench fig3_memory`

use std::path::Path;

use butterfly_moe::bench::{paper_tables, Table};
use butterfly_moe::memmodel::{cached_butterfly_bytes, LayerShape, Method, ALL_METHODS};

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    paper_tables::fig3(out)?;

    // all methods, wide sweep, CSV for plotting
    let s = LayerShape::paper();
    let headers: Vec<String> = std::iter::once("Experts".to_string())
        .chain(ALL_METHODS.iter().map(|m| format!("{} (MB)", m.name())))
        .collect();
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new("Fig. 3 (all methods)", &hdr_refs);
    let mut n = 8usize;
    while n <= 1024 {
        let mut row = vec![n.to_string()];
        for m in ALL_METHODS {
            row.push(format!("{:.2}", m.bytes(n, s) / (1024.0 * 1024.0)));
        }
        t.row(&row);
        n *= 2;
    }
    t.print();
    t.write_csv(&out.join("fig3_all_methods.csv"))?;

    // residency-cache companion curve: identity bytes plus R resident
    // working sets (the serving memory↔throughput dial; `expert_cache`
    // bench measures the throughput side)
    let mut t = Table::new(
        "Fig. 3b: with expert-residency cache (MB)",
        &["Experts", "R=0 (pure)", "R=2", "R=8", "R=all", "Standard"],
    );
    let mut n = 8usize;
    while n <= 1024 {
        let mb = |b: f64| format!("{:.2}", b / (1024.0 * 1024.0));
        t.row(&[
            n.to_string(),
            mb(cached_butterfly_bytes(n, 0, s)),
            mb(cached_butterfly_bytes(n, 2, s)),
            mb(cached_butterfly_bytes(n, 8, s)),
            mb(cached_butterfly_bytes(n, n, s)),
            mb(Method::StandardMoe.bytes(n, s)),
        ]);
        n *= 2;
    }
    t.print();
    t.write_csv(&out.join("fig3_cached.csv"))?;

    // ASCII log-log rendering of the two headline series
    println!("\nlog2(MB) vs log2(experts)   S=standard  B=butterfly");
    let rows = 14;
    for level in (0..rows).rev() {
        let mb_at = |v: f64| (v / (1024.0 * 1024.0)).log2().round() as i64;
        let mut line = format!("{:>6} |", format!("2^{}", level as i64 - 2));
        let mut n = 8usize;
        while n <= 1024 {
            let sv = mb_at(Method::StandardMoe.bytes(n, s)) + 2;
            let bv = mb_at(Method::ButterflyMoe.bytes(n, s)) + 2;
            let c = if sv == level as i64 && bv == level as i64 {
                '*'
            } else if sv == level as i64 {
                'S'
            } else if bv == level as i64 {
                'B'
            } else {
                ' '
            };
            line.push_str(&format!("   {c}   "));
            n *= 2;
        }
        println!("{line}");
    }
    println!("        +{}", "-".repeat(8 * 7));
    println!("            8      16     32     64     128    256    512   1024  experts");
    Ok(())
}
