//! Open-loop load generation against the fleet router — the SLO-grade
//! evaluation of `bmoe route`.
//!
//! Boots a real fleet (child `bmoe serve --native --model <tiny.bmoe>
//! --load mmap --port 0` processes behind an in-process `Router`) and
//! drives it with Poisson session arrivals at swept offered loads, a
//! mixed workload of short (4-token) and long (24-token) generation
//! budgets.  Open-loop means arrivals do NOT wait for completions — the
//! generator keeps offering load while the fleet saturates, which is
//! what makes shed rate and tail latency honest (a closed loop would
//! self-throttle and hide both).
//!
//! Reports, per offered-load level: client-observed TTFT and
//! inter-token latency p50/p95/p99, shed rate, worker-lost rate, and
//! delivered tokens/s.  Separately measures the RSS-per-worker curve at
//! fleet sizes 1/2/4 over the same mmap-packed model — the sub-linear
//! fleet-memory claim (workers share the packed substrate through the
//! page cache).
//!
//! Output: `runs/tables/router_load.csv`, `runs/tables/router_rss.csv`,
//! and machine-readable `BENCH_router.json` at the repo root.
//!
//! Run: `cargo bench --bench router_load`
//! CI:  `cargo bench --bench router_load -- smoke` — quick burst that
//! gates shed rate = 0 below capacity, tokens on >= 2 workers, and a
//! loss-free drain, then emits `BENCH_router.json` (mode "smoke").

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use butterfly_moe::artifact::{synthesize, SynthSpec};
use butterfly_moe::bench::Table;
use butterfly_moe::router::{worker::ProcessLauncher, Router, RouterConfig};
use butterfly_moe::util::{stats, Rng};

const SHORT_TOKENS: usize = 4;
const LONG_TOKENS: usize = 24;

/// Pack the tiny seeded model the whole fleet serves.
fn pack_tiny_model(dir: &Path) -> anyhow::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join("router_bench_tiny.bmoe");
    let spec = SynthSpec {
        d_model: 64,
        d_ff: 256,
        n_experts: 4,
        top_k: 2,
        n_layers: 1,
        vocab: 128,
        seq_len: 32,
        depth: None,
        seed: 7,
    };
    synthesize(&spec).pack(&path)?;
    Ok(path)
}

/// Boot a router over `fleet` real child worker processes serving
/// `model` via mmap; returns the router handle and its front-door
/// address.  The accept loop runs on a background thread until drain.
fn boot_router(model: &Path, fleet: usize) -> anyhow::Result<(Arc<Router>, SocketAddr)> {
    let bin = std::path::PathBuf::from(env!("CARGO_BIN_EXE_bmoe"));
    let wargs: Vec<String> = [
        "--native",
        "--model",
        model.to_str().unwrap(),
        "--load",
        "mmap",
        "--max-batch",
        "8",
        "--workers",
        "1",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let cfg = RouterConfig {
        port: 0,
        fleet,
        sessions_per_worker: 8,
        max_queue: 32,
        client_cap: 0, // the load generator is one IP; fairness is unit-tested
        health_interval: Duration::from_millis(200),
        ..RouterConfig::default()
    };
    let (listener, addr) = butterfly_moe::util::net::listen_reuse(0)?;
    let router = Router::start(cfg, Arc::new(ProcessLauncher::new(bin, wargs)))?;
    {
        let router = router.clone();
        std::thread::spawn(move || router.serve(listener));
    }
    Ok((router, addr))
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Completed,
    Shed,
    Lost,
}

struct SessionResult {
    outcome: Outcome,
    ttft: Option<f64>,
    gaps: Vec<f64>,
    tokens: u64,
}

/// One client session over the wire; latencies are client-observed.
fn run_session(addr: SocketAddr, budget: usize, prompt: &[usize], seed: u64) -> SessionResult {
    let fail = SessionResult {
        outcome: Outcome::Lost,
        ttft: None,
        gaps: Vec::new(),
        tokens: 0,
    };
    let Ok(mut s) = TcpStream::connect(addr) else { return fail };
    s.set_nodelay(true).ok();
    let words: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let t0 = Instant::now();
    if writeln!(s, "GEN {budget} 0 0 {seed} -1 {}", words.join(" ")).is_err() {
        return fail;
    }
    let mut reader = BufReader::new(s);
    let mut line = String::new();
    let mut ttft = None;
    let mut gaps = Vec::new();
    let mut tokens = 0u64;
    let mut last = t0;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => {
                return SessionResult { outcome: Outcome::Lost, ttft, gaps, tokens }
            }
            Ok(_) => {}
        }
        let now = Instant::now();
        if line.starts_with("TOK ") {
            if tokens == 0 {
                ttft = Some((now - t0).as_secs_f64());
            } else {
                gaps.push((now - last).as_secs_f64());
            }
            last = now;
            tokens += 1;
        } else if line.starts_with("END shed") || line.starts_with("END shutdown") {
            return SessionResult { outcome: Outcome::Shed, ttft, gaps, tokens };
        } else if line.starts_with("END ") {
            return SessionResult { outcome: Outcome::Completed, ttft, gaps, tokens };
        } else if line.starts_with("ERR") {
            return SessionResult { outcome: Outcome::Lost, ttft, gaps, tokens };
        }
    }
}

struct LevelResult {
    arrivals: usize,
    completed: usize,
    shed: usize,
    lost: usize,
    shed_rate: f64,
    tokens_per_sec: f64,
    ttft: Vec<f64>,
    itl: Vec<f64>,
}

/// Offer `sps` sessions/sec for `seconds`, open loop (every 4th session
/// is long).  Sessions run on their own threads; arrivals never block
/// on completions.
fn drive_level(addr: SocketAddr, sps: f64, seconds: f64, rng: &mut Rng) -> LevelResult {
    let t0 = Instant::now();
    let mut next = 0.0f64;
    let mut n = 0usize;
    let mut sessions = Vec::new();
    while t0.elapsed().as_secs_f64() < seconds {
        if t0.elapsed().as_secs_f64() >= next {
            let budget = if n % 4 == 3 { LONG_TOKENS } else { SHORT_TOKENS };
            let prompt: Vec<usize> = (0..4 + rng.below(5)).map(|_| rng.below(128)).collect();
            let seed = 1000 + n as u64;
            sessions.push(std::thread::spawn(move || {
                run_session(addr, budget, &prompt, seed)
            }));
            n += 1;
            next += rng.exponential(sps);
        } else {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let results: Vec<SessionResult> = sessions.into_iter().filter_map(|h| h.join().ok()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let tokens: u64 = results.iter().map(|r| r.tokens).sum();
    let count = |o: Outcome| results.iter().filter(|r| r.outcome == o).count();
    let (completed, shed, lost) = (count(Outcome::Completed), count(Outcome::Shed), count(Outcome::Lost));
    LevelResult {
        arrivals: results.len(),
        completed,
        shed,
        lost,
        shed_rate: shed as f64 / results.len().max(1) as f64,
        tokens_per_sec: tokens as f64 / wall,
        ttft: results.iter().filter_map(|r| r.ttft).collect(),
        itl: results.iter().flat_map(|r| r.gaps.iter().copied()).collect(),
    }
}

fn level_json_row(fleet: usize, sps: f64, r: &LevelResult) -> String {
    let pct = |v: &[f64], p: f64| 1e3 * stats::percentile(v, p);
    format!(
        "    {{\"fleet\": {fleet}, \"offered_sps\": {sps:.1}, \"arrivals\": {}, \
         \"completed\": {}, \"shed\": {}, \"worker_lost\": {}, \"shed_rate\": {:.4}, \
         \"tokens_per_sec\": {:.1}, \
         \"ttft_ms\": {{\"p50\": {:.3}, \"p95\": {:.3}, \"p99\": {:.3}}}, \
         \"itl_ms\": {{\"p50\": {:.3}, \"p95\": {:.3}, \"p99\": {:.3}}}}}",
        r.arrivals,
        r.completed,
        r.shed,
        r.lost,
        r.shed_rate,
        r.tokens_per_sec,
        pct(&r.ttft, 50.0),
        pct(&r.ttft, 95.0),
        pct(&r.ttft, 99.0),
        pct(&r.itl, 50.0),
        pct(&r.itl, 95.0),
        pct(&r.itl, 99.0),
    )
}

/// VmRSS of one pid in MB (linux /proc; None elsewhere).
fn rss_mb(pid: u32) -> Option<f64> {
    let status = std::fs::read_to_string(format!("/proc/{pid}/status")).ok()?;
    let kb: f64 = status
        .lines()
        .find(|l| l.starts_with("VmRSS:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()?;
    Some(kb / 1024.0)
}

struct RssPoint {
    fleet: usize,
    per_worker_mb: Vec<f64>,
    total_mb: f64,
}

/// Boot a fleet of `fleet` mmap workers over `model`, warm each with a
/// small burst, and read per-worker RSS.  Sharing the packed pages via
/// the page cache is what keeps mean-per-worker flat as the fleet grows.
fn measure_rss(model: &Path, fleet: usize, burst: usize, rng: &mut Rng) -> anyhow::Result<RssPoint> {
    let (router, addr) = boot_router(model, fleet)?;
    // touch every worker: sequential sessions round-robin across the fleet
    for i in 0..burst.max(2 * fleet) {
        let prompt: Vec<usize> = (0..6).map(|_| rng.below(128)).collect();
        let r = run_session(addr, SHORT_TOKENS, &prompt, 500 + i as u64);
        anyhow::ensure!(r.outcome == Outcome::Completed, "rss warm burst session failed");
    }
    let per_worker_mb: Vec<f64> = router
        .worker_pids()
        .into_iter()
        .flatten()
        .filter_map(rss_mb)
        .collect();
    let total_mb = per_worker_mb.iter().sum();
    router.drain();
    Ok(RssPoint { fleet, per_worker_mb, total_mb })
}

fn rss_json_row(p: &RssPoint) -> String {
    let per: Vec<String> = p.per_worker_mb.iter().map(|m| format!("{m:.1}")).collect();
    let mean = p.total_mb / p.per_worker_mb.len().max(1) as f64;
    format!(
        "    {{\"fleet\": {}, \"per_worker_mb\": [{}], \"mean_worker_mb\": {:.1}, \
         \"total_mb\": {:.1}}}",
        p.fleet,
        per.join(", "),
        mean,
        p.total_mb
    )
}

fn write_bench_json(mode: &str, levels: &[String], rss: &[String]) -> std::io::Result<()> {
    let body = format!(
        "{{\n  \"schema\": \"bmoe_router_v1\",\n  \"mode\": \"{mode}\",\n  \
         \"levels\": [\n{}\n  ],\n  \"rss\": [\n{}\n  ]\n}}\n",
        levels.join(",\n"),
        rss.join(",\n"),
    );
    std::fs::write("BENCH_router.json", body)?;
    println!("\nwrote BENCH_router.json (mode {mode})");
    Ok(())
}

fn run(mode: &str) -> anyhow::Result<()> {
    let smoke = mode == "smoke";
    let out = Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    let model = pack_tiny_model(out)?;
    let mut rng = Rng::new(0x40u64);

    // ------------------------------------------------------------------
    // offered-load sweep at fleet=2
    // ------------------------------------------------------------------
    let fleet = 2usize;
    // the lowest level must sit well below fleet service capacity — it
    // is the "shed rate must be 0" gate
    let (levels, seconds): (&[f64], f64) = if smoke {
        (&[6.0, 48.0], 1.5)
    } else {
        (&[10.0, 60.0, 240.0], 4.0)
    };
    let (router, addr) = boot_router(&model, fleet)?;
    let mut table = Table::new(
        &format!("Router open-loop load (fleet={fleet}, mmap tiny model, mixed 4/24-token)"),
        &[
            "Offered sess/s",
            "Arrivals",
            "Completed",
            "Shed",
            "Lost",
            "Shed rate",
            "tok/s",
            "TTFT p50 ms",
            "TTFT p95 ms",
            "TTFT p99 ms",
            "ITL p50 ms",
            "ITL p99 ms",
        ],
    );
    let mut level_rows = Vec::new();
    let mut first_level: Option<LevelResult> = None;
    for &sps in levels {
        let r = drive_level(addr, sps, seconds, &mut rng);
        let pct = |v: &[f64], p: f64| 1e3 * stats::percentile(v, p);
        table.row(&[
            format!("{sps:.0}"),
            r.arrivals.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.lost.to_string(),
            format!("{:.3}", r.shed_rate),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:.2}", pct(&r.ttft, 50.0)),
            format!("{:.2}", pct(&r.ttft, 95.0)),
            format!("{:.2}", pct(&r.ttft, 99.0)),
            format!("{:.3}", pct(&r.itl, 50.0)),
            format!("{:.3}", pct(&r.itl, 99.0)),
        ]);
        level_rows.push(level_json_row(fleet, sps, &r));
        if first_level.is_none() {
            first_level = Some(r);
        }
    }
    // worker spread + loss-free drain, asserted while the router is live
    let views = router.fleet.views();
    let busy = views.iter().filter(|v| v.tokens_relayed > 0).count();
    println!(
        "worker token spread: [{}]",
        views
            .iter()
            .map(|v| v.tokens_relayed.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let lossless = router.drain();
    table.print();
    table.write_csv(&out.join("router_load.csv"))?;

    // ------------------------------------------------------------------
    // RSS-per-worker curve at 1/2/4 workers over the same mmap model
    // ------------------------------------------------------------------
    let mut rss_table = Table::new(
        "Router fleet RSS (same mmap model; page-cache-shared substrate)",
        &["Fleet", "Mean worker RSS MB", "Total RSS MB"],
    );
    let mut rss_rows = Vec::new();
    let burst = if smoke { 6 } else { 24 };
    for n in [1usize, 2, 4] {
        let p = measure_rss(&model, n, burst, &mut rng)?;
        if p.per_worker_mb.is_empty() {
            println!("(no /proc RSS on this platform; skipping fleet={n} point)");
            continue;
        }
        let mean = p.total_mb / p.per_worker_mb.len() as f64;
        rss_table.row(&[
            n.to_string(),
            format!("{mean:.1}"),
            format!("{:.1}", p.total_mb),
        ]);
        rss_rows.push(rss_json_row(&p));
    }
    rss_table.print();
    rss_table.write_csv(&out.join("router_rss.csv"))?;

    write_bench_json(mode, &level_rows, &rss_rows)?;

    // ------------------------------------------------------------------
    // gates
    // ------------------------------------------------------------------
    let first = first_level.expect("at least one load level");
    anyhow::ensure!(
        first.completed > 0,
        "below-capacity level completed no sessions"
    );
    anyhow::ensure!(
        first.shed == 0,
        "shed rate must be 0 below capacity, got {}/{} shed",
        first.shed,
        first.arrivals
    );
    anyhow::ensure!(
        first.lost == 0,
        "no worker may be lost below capacity, got {}",
        first.lost
    );
    anyhow::ensure!(
        busy >= 2,
        "load must spread: expected tokens on >= 2 workers, got {busy}"
    );
    anyhow::ensure!(lossless, "drain under load must be loss-free");
    println!(
        "gates OK: {} completed, 0 shed/lost below capacity, tokens on {busy} workers, \
         loss-free drain",
        first.completed
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "smoke" || a == "--smoke")
        || std::env::var("BMOE_BENCH_SMOKE").is_ok_and(|v| v == "1");
    run(if smoke { "smoke" } else { "full" })
}
