//! Expert-residency cache bench — the memory↔throughput dial, measured.
//!
//! Tokens/sec of the Alg.-1 expert mixture at working-set budgets of
//! {0, 2, 8, all} resident experts under a *skewed* routing distribution
//! (a few high-norm gate rows dominate the top-k — the serving regime
//! the cache targets: most dispatches go to a small hot set).  Budget 0
//! is the pure sub-linear synthesis path; "all" bounds the dial's far
//! end.  Emits the usual table/CSV plus `expert_cache.json`.
//!
//! Run: `cargo bench --bench expert_cache`

use butterfly_moe::bench::{black_box, Bencher, Table};
use butterfly_moe::expertcache::{decoded_expert_bytes, ExpertCacheConfig};
use butterfly_moe::moe::{ButterflyMoeLayer, MoeLayer};
use butterfly_moe::util::{human_bytes, Rng};

const D: usize = 512;
const DFF: usize = 2048;
const E: usize = 32;
const BATCH: usize = 8;

/// Paper-shape layer with routing skew: scaling a gate row scales its
/// logit, so a few high-norm rows win the top-k for most inputs.
fn build_layer() -> ButterflyMoeLayer {
    let mut rng = Rng::new(0xCACE);
    let mut layer = ButterflyMoeLayer::random(D, DFF, E, 2, None, &mut rng);
    for e in 0..4 {
        for v in layer.gate.w.data[e * D..(e + 1) * D].iter_mut() {
            *v *= 3.0;
        }
    }
    layer
}

fn main() -> anyhow::Result<()> {
    let bencher = Bencher::quick();
    let out = std::path::Path::new("runs/tables");
    std::fs::create_dir_all(out)?;
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..BATCH * D).map(|_| rng.normal_f32(1.0)).collect();
    let entry = decoded_expert_bytes(DFF, D);

    let mut t = Table::new(
        "Expert cache: d=512 d_ff=2048, 32 experts top-2, skewed routing, batch 8",
        &[
            "Budget (experts)",
            "Working set",
            "Resident",
            "Hit rate",
            "Median/step",
            "tokens/s",
            "vs budget 0",
        ],
    );
    let mut json_rows: Vec<String> = Vec::new();
    let mut base_tps = 0.0f64;
    for budget_experts in [0usize, 2, 8, E] {
        let mut layer = build_layer();
        let cache = (budget_experts > 0).then(|| {
            layer.attach_expert_cache(ExpertCacheConfig {
                max_admissions_per_tick: 4,
                ..ExpertCacheConfig::with_budget_bytes(budget_experts * entry)
            })
        });
        let mut h = vec![0.0f32; BATCH * DFF];
        // converge admission to steady state before timing (the engine
        // loop ticks once per decode step; mirror that here)
        for _ in 0..32 {
            layer.experts_forward(&x, BATCH, &mut h);
            if let Some(c) = &cache {
                c.tick();
            }
        }
        let r = bencher.run(&format!("budget {budget_experts}"), || {
            layer.experts_forward(&x, BATCH, &mut h);
            if let Some(c) = &cache {
                c.tick();
            }
            black_box(&h);
        });
        let tps = r.throughput(BATCH as f64);
        if budget_experts == 0 {
            base_tps = tps;
        }
        let snap = cache.as_ref().map(|c| c.snapshot()).unwrap_or_default();
        t.row(&[
            budget_experts.to_string(),
            human_bytes((budget_experts * entry) as f64),
            format!("{}", snap.resident_experts),
            format!("{:.3}", snap.hit_rate()),
            butterfly_moe::bench::format_secs(r.median_secs()),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / base_tps.max(1e-9)),
        ]);
        json_rows.push(format!(
            "  {{\"budget_experts\": {budget_experts}, \"budget_bytes\": {}, \
             \"resident_experts\": {}, \"hit_rate\": {:.4}, \"median_step_secs\": {:.6e}, \
             \"tokens_per_sec\": {tps:.1}}}",
            budget_experts * entry,
            snap.resident_experts,
            snap.hit_rate(),
            r.median_secs(),
        ));
    }
    t.print();
    t.write_csv(&out.join("expert_cache.csv"))?;
    std::fs::write(
        out.join("expert_cache.json"),
        format!("[\n{}\n]\n", json_rows.join(",\n")),
    )?;
    println!("\nwrote runs/tables/expert_cache.csv and expert_cache.json");
    Ok(())
}
