//! Fig. 4 — quantization stability via learned rotations.
//!
//! Reproduces all four panels' quantities:
//!   * scaled-weight histograms of the substrate, untrained vs trained
//!     (top panels: trained weights cluster on the ternary grid),
//!   * relative weight quantization MSE (bottom right: the paper's
//!     51.3% -> 1.43%, a 97.2% reduction),
//!   * the activation-aware variant: relative *output* error of the
//!     ternarized substrate vs full precision, for learned-rotation
//!     training vs frozen-rotation ("static") training.
//!
//! Trains tiny checkpoints on first run (cached in runs/figs/).
//! Run: `cargo bench --bench fig4_quant` (env BMOE_FIG_STEPS to change
//! the training budget, default 150).

use std::path::Path;

use butterfly_moe::bench::Table;
use butterfly_moe::butterfly::Butterfly;
use butterfly_moe::moe::ButterflyMoeLayer;
use butterfly_moe::quant::{output_quant_error, scaled_weight_histogram, weight_quant_error};
use butterfly_moe::runtime::Engine;
use butterfly_moe::tensor::store::TensorStore;
use butterfly_moe::tensor::Tensor;
use butterfly_moe::train::ensure_checkpoint;
use butterfly_moe::util::Rng;

fn steps() -> usize {
    std::env::var("BMOE_FIG_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150)
}

/// Mean relative output error of ternary-vs-fp substrate across experts.
fn layer_output_error(store: &TensorStore, prefix: &str, top_k: usize) -> anyhow::Result<f64> {
    let layer = ButterflyMoeLayer::from_store(store, prefix, top_k)?;
    let w_base = store.get_f32(&format!("{prefix}w_base"))?;
    let (dff, d) = (w_base.shape[0], w_base.shape[1]);
    let mut rng = Rng::new(0xF16);
    let t = 64usize;
    let x = Tensor::rand_normal(&[t, d], 1.0, &mut rng);

    let theta = store.get_f32(&format!("{prefix}theta"))?;
    let phi = store.get_f32(&format!("{prefix}phi"))?;
    let e = theta.shape[0];
    let (din, hin) = (theta.shape[1], theta.shape[2]);
    let (dout, hout) = (phi.shape[1], phi.shape[2]);

    let mut scratch = vec![0.0f32; d];
    let mut y_q = vec![0.0f32; dff];
    let mut total = 0.0f64;
    for ei in 0..e {
        let bt = Butterfly::from_angles(d, din, &theta.data[ei * din * hin..(ei + 1) * din * hin]);
        let bp = Butterfly::from_angles(dff, dout, &phi.data[ei * dout * hout..(ei + 1) * dout * hout]);
        let mut qs = Vec::with_capacity(t * dff);
        let mut fs = Vec::with_capacity(t * dff);
        for ti in 0..t {
            let xi = &x.data[ti * d..(ti + 1) * d];
            // quantized path (the deployed one)
            layer.expert_forward(ei, xi, &mut scratch, &mut y_q);
            qs.extend_from_slice(&y_q);
            // full-precision path: same rotations, dense latent substrate
            scratch.copy_from_slice(xi);
            bt.apply_transpose(&mut scratch);
            let mut y_fp = vec![0.0f32; dff];
            for r in 0..dff {
                let row = w_base.row(r);
                let mut acc = 0.0f32;
                for c in 0..d {
                    acc += row[c] * scratch[c];
                }
                y_fp[r] = acc;
            }
            bp.apply(&mut y_fp);
            fs.extend_from_slice(&y_fp);
        }
        total += output_quant_error(&qs, &fs);
    }
    Ok(total / e as f64)
}

fn print_histogram(name: &str, w: &Tensor) {
    let bins = 19;
    let h = scaled_weight_histogram(w, bins, -3.0, 3.0);
    let max = *h.iter().max().unwrap() as f64;
    println!("  {name} (w/gamma in [-3,3], {} weights):", w.len());
    for (i, &c) in h.iter().enumerate() {
        let center = -3.0 + (i as f32 + 0.5) * 6.0 / bins as f32;
        let bar = "#".repeat((40.0 * c as f64 / max).round() as usize);
        let grid = if (center.abs() - 1.0).abs() < 0.16 || center.abs() < 0.16 {
            "<- grid"
        } else {
            ""
        };
        println!("   {center:>5.1} | {bar:<40} {grid}");
    }
}

fn main() -> anyhow::Result<()> {
    let out = Path::new("runs/figs");
    std::fs::create_dir_all(out)?;
    let engine = Engine::new(Path::new("artifacts"))?;
    let n = steps();

    let trained = ensure_checkpoint(&engine, "tiny", n, out)?;
    let static_ck = ensure_checkpoint(&engine, "tiny_static", n, out)?;

    let init = TensorStore::read(&engine.manifest.dir.join("tiny.params.bmoe"))?;
    let trained = TensorStore::read(&trained)?;
    let static_s = TensorStore::read(&static_ck)?;

    // weight histograms (block 0 substrate)
    println!("== Fig. 4 top panels: substrate weight distribution ==");
    print_histogram("untrained", init.get_f32("blocks.0.ffn.w_base")?);
    print_histogram(&format!("trained {n} steps (learned rotations + STE)"),
        trained.get_f32("blocks.0.ffn.w_base")?);

    // quantization error table
    let mut t = Table::new(
        "Fig. 4 bottom-right — relative quantization error (%)",
        &["Model state", "Weight rel-MSE %", "Output rel-MSE %"],
    );
    let cfg = engine.manifest.config("tiny")?.clone();
    for (name, store) in [
        ("untrained", &init),
        ("trained (learned rotations)", &trained),
        ("trained (static rotations)", &static_s),
    ] {
        // mean across blocks
        let mut werr = 0.0;
        let mut oerr = 0.0;
        let mut blocks = 0;
        for b in 0.. {
            let prefix = format!("blocks.{b}.ffn.");
            if store.get(&format!("{prefix}w_base")).is_none() {
                break;
            }
            werr += weight_quant_error(store.get_f32(&format!("{prefix}w_base"))?);
            oerr += layer_output_error(store, &prefix, cfg.top_k)?;
            blocks += 1;
        }
        t.row(&[
            name.to_string(),
            format!("{:.2}", 100.0 * werr / blocks as f64),
            format!("{:.2}", 100.0 * oerr / blocks as f64),
        ]);
    }
    t.print();
    t.write_csv(&out.join("fig4_quant.csv"))?;
    println!("\npaper: 51.3% (untrained) -> 1.43% (trained), a 97.2% reduction.");
    println!("The reproduced claim is the *drop* from training with STE +");
    println!("learned rotations, and learned < static on the output metric.");
    Ok(())
}
