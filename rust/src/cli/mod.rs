//! Hand-rolled CLI argument parser (clap is not in the offline vendor
//! set).  Supports `bmoe <subcommand> [--flag value] [--switch] [key=value]`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    /// bare key=value overrides (fed to RuntimeConfig::set)
    pub overrides: Vec<(String, String)>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                    && !is_switch(name)
                {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else if let Some((k, v)) = arg.split_once('=') {
                out.overrides.push((k.to_string(), v.to_string()));
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name} {v}: {e}")),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Flags that never consume a value (so `--quick train` parses right).
fn is_switch(name: &str) -> bool {
    matches!(
        name,
        "quick"
            | "verbose"
            | "help"
            | "csv"
            | "paper"
            | "native"
            | "pjrt"
            | "no-warmup"
            | "verify"
            | "exact"
    )
}

pub const USAGE: &str = "\
bmoe — ButterflyMoE coordinator / experiment driver

USAGE: bmoe <COMMAND> [--flag value] [key=value overrides]

COMMANDS:
  quickstart            load artifacts, run one forward, print memory stats
  train                 train a config via the AOT train-step artifact
  eval                  evaluate a checkpoint's CE loss on held-out batches
  serve                 start the TCP generation-session coordinator
                        (--native serves the pure-rust multi-layer LM, no
                        artifacts or PJRT runtime needed; --model serves a
                        packed .bmoe model artifact, mmap-loaded)
  route                 fleet front door: spawn and supervise N `serve
                        --native` worker processes (one shared mmap model
                        substrate) and load-balance streaming sessions
                        across them — least-loaded placement, bounded
                        queue with explicit shedding, per-client fairness,
                        health-checked restart, loss-free drain (DRAIN)
  pack-model            synthesize a multi-layer native model and pack it
                        into a .bmoe artifact (--out model.bmoe); serving
                        it reproduces the in-memory model bit-for-bit.
                        The manifest records per-tensor CRC-32 checksums
                        and payload totals for load-time integrity checks
  verify-model FILE     verify a packed artifact's integrity record:
                        payload-accounting preflight plus every tensor's
                        CRC-32 against the manifest; exits nonzero on any
                        mismatch, truncation, or a checksum-less artifact
  bench-client          stream sessions from a running server, report
                        TTFT / inter-token latency / tokens per second
  tables                regenerate every paper table/figure (analytic ones)
  info                  print artifact manifest summary

COMMON FLAGS:
  --artifacts DIR       artifacts directory (default: artifacts)
  --config NAME         model preset (tiny|tiny_static|tiny_standard|small...)
  --steps N  --lr F     training options
  --port P              serving: TCP port (default 7070)
  --max-batch N         serving: max sequences resident per decode step
  --prefill-chunk N     serving/route: max prompt tokens ingested per
                        engine tick per joining sequence; 0 (default) =
                        whole prompt at once.  Small chunks bound
                        batch-mates' inter-token latency under long
                        prompts; decoded streams are bit-identical for
                        every N
  --expert-cache-mb MB  serving (--native): byte budget for the expert
                        residency cache — hot experts keep a materialized
                        working set served by a plain dense GEMM,
                        bit-identical to on-the-fly synthesis; 0 (default)
                        disables it (pure sub-linear mode)
  --exact               serve/route (--native): opt out of the default
                        W1.58A8 quantized substrate GEMM and use the
                        exact f32 path — token streams bit-identical to
                        pre-A8 releases; also re-enables the expert
                        residency cache (bypassed under A8).  The A8
                        default's max logit error is bounded by the
                        accuracy-gate test (tests/determinism.rs)
  --kernel-isa ISA      serve/route/benches: pin the kernel ISA path
                        (scalar|avx2|neon|auto); default auto = runtime
                        detection.  Also read from the BMOE_KERNEL_ISA
                        env var.  All paths are bit-identical (f32) /
                        exactly equal (i8) — pinned by the cross-ISA
                        parity suite in tests/kernels.rs
  --no-warmup           serving: skip the pre-serve warmup pass (bucket
                        compilation + expert-cache pre-materialization)
  --workers N           serving (--native) / examples / benches: worker
                        threads for the MoE hot path; default 0 = auto
                        (BMOE_WORKERS env var, else all cores).  Decoded
                        token streams are bit-identical for every N
  --model FILE          serving (--native) / pack-model: the packed .bmoe
                        model artifact to serve / write.  Without it,
                        serve --native synthesizes the seeded stand-in
  --layers L            serving (--native) / pack-model: residual
                        ButterflyMoE blocks in the synthesized model
                        (default 1); a --model file carries its own count
  --load mmap|heap      serving (--native --model): mmap borrows tensor
                        payloads from a shared file mapping (zero-copy
                        cold start, page-cache shared across processes);
                        heap eagerly deserializes.  Token streams are
                        bit-identical either way (default: mmap)
  --verify              serving (--native --model): verify every tensor
                        checksum before serving.  Heap loads verify
                        eagerly regardless; this forces the full pass for
                        mmap loads too (faults in the whole file)
  --fleet N             route: worker processes to spawn (default 2)
  --sessions-per-worker N
                        route: concurrent sessions placed on one worker
                        before queueing; admission capacity is
                        healthy_workers x this (default 16)
  --route-queue N       route: bounded admission queue — arrivals beyond
                        it get an immediate 'END shed', never a stall
                        (default 64)
  --client-cap N        route: max concurrent sessions per client IP; the
                        greedy client sheds, others are unaffected
                        (default 0 = unlimited)
  --health-interval-ms M
                        route: STATS health-poll cadence; crashed workers
                        restart with exponential backoff (default 500)
  --failover-retries N  route: when a worker dies mid-stream the session
                        fails over — re-placed on a healthy worker, the
                        deterministic replay's already-delivered prefix
                        verified and suppressed, the stream resumed
                        seamlessly — up to N times before the terminal
                        'ERR worker lost' (default 2; 0 disables)
  --fault SPEC          serve/route: deterministic fault injection for
                        chaos testing ('key=value;...', e.g.
                        'seed=7;kill_after=5;kill_prob=0.5'); inert when
                        absent.  Also read from the BMOE_FAULT env var.
                        See faults/mod.rs for the injection points
  --trace-sample N      serve/route: time every Nth hot-path stage
                        occurrence (gather/rotate/GEMM/reduce/...) into
                        per-layer histograms surfaced by METRICS; 0
                        (default) = off, one atomic load per site.
                        Token streams are bit-identical at every rate
  --log-json PATH|-     serve/route: structured JSONL event log (session
                        and worker lifecycle + all [tagged] log lines);
                        '-' = stdout.  Recent events are also kept in an
                        in-memory flight ring dumped to
                        bmoe-flight-<pid>.jsonl on panic, worker death,
                        or protocol ERR (dir: $BMOE_FLIGHT_DIR, else
                        the OS temp dir)
  --max-new-tokens N    bench-client: token budget requested per session
  --temperature F       bench-client: sampling temperature (0 = greedy)
  --top-k N             bench-client: top-k truncation (0 = full vocab)
  --out DIR|FILE        output directory for CSV/checkpoints; for
                        pack-model, the .bmoe file to write
                        (pack-model also takes --d-model --d-ff --experts
                        --top-k-experts --vocab --seq-len --depth --seed)

Any bare key=value is applied to the runtime config (see config/mod.rs).
The serve wire protocol is documented in coordinator/server.rs:
  GEN <max_new> <temperature> <top_k> <seed> <eos|-1> <tok> <tok> ...
streams back 'TOK <index> <token> <latency_us>' lines and a terminal
'END <reason> <n_tokens> <total_us> <truncated>'.  'STATS' returns one key=value
telemetry line including the expert cache's hit rate / resident bytes.
'METRICS' returns Prometheus text exposition (counters, gauges, and
cumulative-bucket histograms incl. the per-stage --trace-sample
timings), terminated by a '# EOF' line.
The router speaks the same protocol (clients point at it unchanged) and
adds 'DRAIN' (loss-free fleet shutdown) plus the terminals 'END shed'
(admission), 'ERR worker lost' (worker died mid-stream and every
failover retry was exhausted — sessions fail over transparently first;
see --failover-retries) and 'ERR replay diverged' (a failover replay
contradicted the already-delivered prefix); its METRICS aggregates
every worker's exposition under worker=\"wN\" labels plus fleet-level
bmoe_router_* series.";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --config tiny --steps 100 --quick lr=0.01");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.flag("config"), Some("tiny"));
        assert_eq!(a.flag("steps"), Some("100"));
        assert!(a.has_switch("quick"));
        assert_eq!(a.overrides, vec![("lr".to_string(), "0.01".to_string())]);
    }

    #[test]
    fn eq_style_flags() {
        let a = parse("serve --port=8080");
        assert_eq!(a.flag("port"), Some("8080"));
    }

    #[test]
    fn trailing_switch() {
        let a = parse("tables --csv");
        assert!(a.has_switch("csv"));
    }

    #[test]
    fn exact_is_a_switch_kernel_isa_takes_a_value() {
        // --exact must not swallow the following token
        let a = parse("serve --native --exact --kernel-isa avx2 --port 8080");
        assert!(a.has_switch("exact"));
        assert!(a.has_switch("native"));
        assert_eq!(a.flag("kernel-isa"), Some("avx2"));
        assert_eq!(a.flag("port"), Some("8080"));
    }

    #[test]
    fn flag_parse_typed() {
        let a = parse("train --steps 42");
        assert_eq!(a.flag_parse::<usize>("steps").unwrap(), Some(42));
        assert_eq!(a.flag_parse::<usize>("missing").unwrap(), None);
        let bad = parse("train --steps abc");
        assert!(bad.flag_parse::<usize>("steps").is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("eval ckpt1 ckpt2");
        assert_eq!(a.positional, vec!["ckpt1", "ckpt2"]);
    }
}
