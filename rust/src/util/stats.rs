//! Summary statistics used by the bench harness and the coordinator's
//! latency accounting.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on a copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean pairwise cosine similarity helpers (Fig. 5).
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

/// Streaming histogram with fixed log-spaced buckets for latency tracking.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [base * ratio^i, base * ratio^(i+1)) seconds
    counts: Vec<u64>,
    base: f64,
    ratio: f64,
    pub n: u64,
    pub sum: f64,
    pub max: f64,
    /// Smallest recorded sample; `f64::INFINITY` until the first record
    /// (read it through [`Self::min`], which reports 0.0 when empty).
    min: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new(1e-6, 1.3, 64)
    }
}

impl LatencyHistogram {
    pub fn new(base: f64, ratio: f64, buckets: usize) -> Self {
        LatencyHistogram {
            counts: vec![0; buckets],
            base,
            ratio,
            n: 0,
            sum: 0.0,
            max: 0.0,
            min: f64::INFINITY,
        }
    }

    pub fn record(&mut self, secs: f64) {
        self.n += 1;
        self.sum += secs;
        if secs > self.max {
            self.max = secs;
        }
        if secs < self.min {
            self.min = secs;
        }
        let idx = if secs <= self.base {
            0
        } else {
            ((secs / self.base).ln() / self.ratio.ln()).floor() as usize
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Smallest recorded sample (0.0 while empty, mirroring `max`).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Cumulative buckets as `(upper_edge_secs, cumulative_count)` in
    /// ascending edge order — exactly the Prometheus `le` convention:
    /// the count paired with an edge is the number of samples `<=` that
    /// edge, and the last entry carries `n`.  Bucket `i` is reported at
    /// its upper edge `base * ratio^(i+1)` (the same edge `quantile`
    /// returns); bucket 0 also absorbs samples at or below the base, and
    /// the last bucket absorbs overflow, so the running sum is
    /// monotonically non-decreasing and complete by construction.
    pub fn cumulative_buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        let mut acc = 0u64;
        self.counts.iter().enumerate().map(move |(i, &c)| {
            acc += c;
            (self.base * self.ratio.powi(i as i32 + 1), acc)
        })
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * self.ratio.powi(i as i32 + 1);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn cosine_basics() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!((cosine_similarity(&a, &a) - 1.0).abs() < 1e-9);
        assert!(cosine_similarity(&a, &b).abs() < 1e-9);
        let c = [-1.0f32, 0.0];
        assert!((cosine_similarity(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10us .. 10ms
        }
        let p50 = h.quantile(0.5);
        assert!(p50 > 3e-3 && p50 < 8e-3, "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= p50);
        assert_eq!(h.n, 1000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(1e-3);
        b.record(2e-3);
        a.merge(&b);
        assert_eq!(a.n, 2);
        assert!((a.mean() - 1.5e-3).abs() < 1e-9);
        assert!((a.min() - 1e-3).abs() < 1e-12);
        assert!((a.max - 2e-3).abs() < 1e-12);
    }

    #[test]
    fn histogram_min_tracking() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.min(), 0.0, "empty histogram reports 0");
        h.record(5e-3);
        assert!((h.min() - 5e-3).abs() < 1e-12, "one sample: min == sample");
        assert!((h.max - 5e-3).abs() < 1e-12);
        h.record(2e-3);
        h.record(9e-3);
        assert!((h.min() - 2e-3).abs() < 1e-12);
        assert!((h.max - 9e-3).abs() < 1e-12);
    }

    #[test]
    fn cumulative_buckets_empty_and_one_sample() {
        let h = LatencyHistogram::new(1e-6, 2.0, 8);
        let edges: Vec<(f64, u64)> = h.cumulative_buckets().collect();
        assert_eq!(edges.len(), 8);
        assert!(edges.iter().all(|&(_, c)| c == 0), "empty: all zero");
        assert!((edges[0].0 - 2e-6).abs() < 1e-18, "first edge is base*ratio");

        let mut h = LatencyHistogram::new(1e-6, 2.0, 8);
        h.record(3e-6); // bucket 1: [2e-6, 4e-6)
        let edges: Vec<(f64, u64)> = h.cumulative_buckets().collect();
        assert_eq!(edges[0].1, 0, "below the sample's bucket");
        assert!(edges[1..].iter().all(|&(_, c)| c == 1), "at and above it");
        assert_eq!(edges.last().unwrap().1, h.n, "last bucket carries n");
    }

    #[test]
    fn cumulative_buckets_boundaries_and_monotonicity() {
        let mut h = LatencyHistogram::new(1e-6, 2.0, 8);
        h.record(5e-7); // below base -> clamps into bucket 0
        h.record(1e-6); // exactly base -> bucket 0
        h.record(2e-6); // exactly bucket-0 upper edge -> bucket 1
        h.record(1.0); // far past the last edge -> clamps into the last bucket
        let edges: Vec<(f64, u64)> = h.cumulative_buckets().collect();
        assert_eq!(edges[0].1, 2, "base-and-below samples land in bucket 0");
        assert_eq!(edges[1].1, 3, "edge sample rolls into the next bucket");
        assert_eq!(edges.last().unwrap().1, 4, "overflow clamps, total intact");
        for w in edges.windows(2) {
            assert!(w[1].1 >= w[0].1, "cumulative counts must be monotonic");
            assert!(w[1].0 > w[0].0, "edges strictly ascend");
        }
    }
}
