//! Small shared utilities: PRNG, statistics, timing, formatting.
//!
//! The offline vendor set has no `rand` crate, so the repo carries its own
//! xoshiro256++ generator ([`Rng`]) seeded via SplitMix64 — deterministic
//! across runs, good enough for data generation and property tests.

pub mod crc32;
pub mod net;
pub mod rng;
pub mod stats;

pub use rng::Rng;

use std::time::Instant;

/// Wall-clock stopwatch returning seconds as f64.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Format a byte count with binary units ("1.91 MB" style, as the paper
/// reports memory footprints).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", v as u64, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Integer log2 for power-of-two `n`; panics otherwise.
pub fn log2_exact(n: usize) -> u32 {
    assert!(n.is_power_of_two(), "{n} is not a power of two");
    n.trailing_zeros()
}

/// Dot product with 8 independent accumulators (§Perf iteration 4).
///
/// A naive `acc += a[i]*b[i]` reduction is a serial dependency chain the
/// compiler may not reassociate (float addition isn't associative);
/// splitting into 8 lanes exposes ILP/SIMD and measures ~4-6x faster on
/// this testbed.  All dense dot products in the crate route through here
/// or through the register-blocked tiles in [`crate::kernels`], which
/// reproduce **this exact lane association** (same 8-lane accumulators,
/// same reduction tree, same scalar tail) — that shared association is
/// the crate's bitwise-parity contract, so any change here must be
/// mirrored there (the kernel unit tests pin the equivalence).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let n8 = n - n % 8;
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i < n8 {
        // fixed-width block: bounds checks hoisted, lanes independent
        let (av, bv) = (&a[i..i + 8], &b[i..i + 8]);
        for l in 0..8 {
            acc[l] += av[l] * bv[l];
        }
        i += 8;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for j in n8..n {
        s += a[j] * b[j];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KB");
        assert_eq!(human_bytes(1.9 * 1024.0 * 1024.0), "1.90 MB");
    }

    #[test]
    fn log2_exact_ok() {
        assert_eq!(log2_exact(1), 0);
        assert_eq!(log2_exact(512), 9);
        assert_eq!(log2_exact(2048), 11);
    }

    #[test]
    #[should_panic]
    fn log2_exact_rejects_non_pow2() {
        log2_exact(12);
    }
}
