//! xoshiro256++ PRNG with SplitMix64 seeding.
//!
//! The vendor set ships no `rand` crate; this generator covers data
//! synthesis, weight init, and property tests.  Deterministic for a given
//! seed on all platforms (no floating-point in the core).

/// xoshiro256++ generator (Blackman & Vigna, 2019).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via SplitMix64 so low-entropy seeds still fill all 256 bits.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-expert / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire's multiply-shift rejection-free bound is overkill here;
        // modulo bias is negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300); // avoid log(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(std);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Exponential inter-arrival sample with the given rate (events/sec).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(6);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
