//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the artifact
//! integrity checksum (DESIGN.md §8).
//!
//! The vendor set has no hashing crate, so the repo carries the standard
//! table-driven implementation; the table is built by a `const fn`, so
//! the 1 KiB lookup lives in rodata with zero startup cost.  CRC-32 is
//! an *integrity* check (bit rot, truncation, torn writes), not an
//! authenticity check — exactly the failure class a packed model on an
//! edge device's flash is exposed to.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `data` (matches `zlib.crc32` / `cksum -o3` semantics).
pub fn crc32(data: &[u8]) -> u32 {
    crc32_update(0, data)
}

/// Continue a running CRC-32: `crc32_update(crc32(a), b) == crc32(a ++ b)`.
pub fn crc32_update(crc: u32, data: &[u8]) -> u32 {
    let mut c = !crc;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical check value for "123456789", plus zlib-verified
        // vectors for the empty string and a longer ASCII run
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn update_is_concatenation() {
        let all = crc32(b"hello world");
        assert_eq!(crc32_update(crc32(b"hello "), b"world"), all);
        assert_ne!(crc32(b"hello world!"), all);
    }

    #[test]
    fn single_bit_flip_changes_the_checksum() {
        let mut data = vec![0xA5u8; 4096];
        let clean = crc32(&data);
        for idx in [0, 1, 2048, 4095] {
            data[idx] ^= 0x01;
            assert_ne!(crc32(&data), clean, "flip at {idx} undetected");
            data[idx] ^= 0x01;
        }
        assert_eq!(crc32(&data), clean);
    }
}
