//! TCP listener construction with `SO_REUSEADDR`.
//!
//! `std::net::TcpListener::bind` gives no way to set socket options
//! before `bind(2)`, and the offline vendor set carries neither `libc`
//! nor `socket2`.  Serving processes restart frequently (the router
//! restarts crashed workers, CI boots fleets back to back), so without
//! `SO_REUSEADDR` a fixed port sits unusable for the TIME_WAIT interval
//! after every exit — a guaranteed bind race.  On Linux and macOS the
//! listener is therefore built by hand (`socket` → `setsockopt` →
//! `bind` → `listen`, raw `extern "C"` bindings in the style of
//! `vendor/mman`) and handed to `std` via `FromRawFd`; every other
//! target falls back to plain `TcpListener::bind` (best effort, no
//! `SO_REUSEADDR`).
//!
//! Port 0 is fully supported: the kernel picks an ephemeral port and
//! `TcpListener::local_addr` reports the real one — how `bmoe serve
//! --port 0` workers get collision-free ports under `bmoe route`.

use std::net::{SocketAddr, TcpListener};

use anyhow::{Context, Result};

/// Loopback listener on `port` (0 = kernel-assigned) with
/// `SO_REUSEADDR` where the platform path exists.  Returns the listener
/// plus its actually-bound address.
pub fn listen_reuse(port: u16) -> Result<(TcpListener, SocketAddr)> {
    let listener = bind_loopback(port)
        .with_context(|| format!("bind 127.0.0.1:{port}"))?;
    let addr = listener.local_addr().context("local_addr")?;
    Ok((listener, addr))
}

#[cfg(any(target_os = "linux", target_os = "macos"))]
fn bind_loopback(port: u16) -> Result<TcpListener> {
    use std::os::unix::io::FromRawFd;
    use sys::*;

    // SAFETY: plain POSIX socket calls on a fresh fd; the fd is either
    // handed to TcpListener (which owns closing it) or closed on the
    // error paths below.
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return Err(std::io::Error::last_os_error()).context("socket");
        }
        fn fail(fd: i32, what: &'static str) -> Result<TcpListener> {
            let err = std::io::Error::last_os_error();
            unsafe { super::sys::close(fd) };
            Err(err).context(what)
        }
        let one: i32 = 1;
        if setsockopt(
            fd,
            SOL_SOCKET,
            SO_REUSEADDR,
            &one as *const i32 as *const core::ffi::c_void,
            core::mem::size_of::<i32>() as u32,
        ) < 0
        {
            return fail(fd, "setsockopt SO_REUSEADDR");
        }
        let addr = sockaddr_in_loopback(port);
        if bind(
            fd,
            &addr as *const SockaddrIn as *const core::ffi::c_void,
            core::mem::size_of::<SockaddrIn>() as u32,
        ) < 0
        {
            return fail(fd, "bind");
        }
        if listen(fd, 128) < 0 {
            return fail(fd, "listen");
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

#[cfg(not(any(target_os = "linux", target_os = "macos")))]
fn bind_loopback(port: u16) -> Result<TcpListener> {
    // No raw-socket path on this target: std bind, without SO_REUSEADDR.
    Ok(TcpListener::bind(("127.0.0.1", port))?)
}

#[cfg(any(target_os = "linux", target_os = "macos"))]
mod sys {
    //! Raw socket bindings (see `vendor/mman` for the policy: the few
    //! POSIX calls std doesn't surface are declared here and resolve
    //! against the C library std already links).
    use core::ffi::c_void;

    pub const AF_INET: i32 = 2;
    pub const SOCK_STREAM: i32 = 1;
    #[cfg(target_os = "linux")]
    pub const SOL_SOCKET: i32 = 1;
    #[cfg(target_os = "macos")]
    pub const SOL_SOCKET: i32 = 0xffff;
    #[cfg(target_os = "linux")]
    pub const SO_REUSEADDR: i32 = 2;
    #[cfg(target_os = "macos")]
    pub const SO_REUSEADDR: i32 = 0x0004;

    /// `struct sockaddr_in`.  Linux leads with a 16-bit family; the BSDs
    /// (macOS) split it into a length byte plus an 8-bit family.
    #[repr(C)]
    pub struct SockaddrIn {
        #[cfg(target_os = "macos")]
        pub sin_len: u8,
        #[cfg(target_os = "macos")]
        pub sin_family: u8,
        #[cfg(target_os = "linux")]
        pub sin_family: u16,
        /// Network byte order.
        pub sin_port: u16,
        /// Network byte order.
        pub sin_addr: u32,
        pub sin_zero: [u8; 8],
    }

    /// 127.0.0.1:`port` in the platform's `sockaddr_in` layout.
    pub fn sockaddr_in_loopback(port: u16) -> SockaddrIn {
        SockaddrIn {
            #[cfg(target_os = "macos")]
            sin_len: core::mem::size_of::<SockaddrIn>() as u8,
            #[cfg(target_os = "macos")]
            sin_family: AF_INET as u8,
            #[cfg(target_os = "linux")]
            sin_family: AF_INET as u16,
            sin_port: port.to_be(),
            sin_addr: u32::from(std::net::Ipv4Addr::LOCALHOST).to_be(),
            sin_zero: [0; 8],
        }
    }

    extern "C" {
        pub fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        pub fn setsockopt(
            fd: i32,
            level: i32,
            name: i32,
            value: *const c_void,
            len: u32,
        ) -> i32;
        pub fn bind(fd: i32, addr: *const c_void, len: u32) -> i32;
        pub fn listen(fd: i32, backlog: i32) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    #[test]
    fn port_zero_reports_real_ephemeral_port() {
        let (listener, addr) = listen_reuse(0).unwrap();
        assert_ne!(addr.port(), 0, "kernel must assign a concrete port");
        assert!(addr.ip().is_loopback());
        // the listener actually accepts on that address
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        client.write_all(b"ping").unwrap();
        let mut buf = [0u8; 4];
        server.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
    }

    #[test]
    fn rebinding_a_just_released_port_succeeds() {
        // SO_REUSEADDR's observable contract: bind, drop, immediately
        // bind the same port again.  Without the option this can fail
        // when a connection leaves the socket in TIME_WAIT.
        let (listener, addr) = listen_reuse(0).unwrap();
        let client = std::net::TcpStream::connect(addr).unwrap();
        let _srv = listener.accept().unwrap();
        drop(client);
        drop(listener);
        let (_l2, addr2) = listen_reuse(addr.port()).unwrap();
        assert_eq!(addr2.port(), addr.port());
    }
}
