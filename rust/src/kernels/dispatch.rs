//! Runtime ISA dispatch for the blocked micro-kernels (§Perf
//! iteration 8).
//!
//! The kernel suite ships three implementations of every hot kernel —
//! the blocked-scalar reference ([`super`]), explicit AVX2
//! (`kernels::x86`, x86_64) and explicit NEON (`kernels::neon`,
//! aarch64) — and selects one **once** at startup:
//!
//! 1. a programmatic [`force`] / [`force_isa`] (the `--kernel-isa`
//!    flag, tests, benches), else
//! 2. the `BMOE_KERNEL_ISA` env var (`scalar` | `avx2` | `neon`), else
//! 3. [`Isa::detect`]: the widest path the CPU supports.
//!
//! After resolution every dispatched kernel entry is one relaxed atomic
//! load plus a predictable match — no per-tile indirection, no
//! allocation (pinned by `rust/tests/alloc_guard.rs`).
//!
//! # Why forcing is part of the design, not a debug hack
//!
//! The bit-identity contract (`super` module docs) is *cross-ISA*: the
//! f32 kernels must produce the blocked-scalar reference's bits on
//! every path, and the i8 kernels the same exact integers.  The parity
//! suite (`rust/tests/kernels.rs`) therefore has to run every property
//! against every ISA **on one machine**, which requires overriding
//! detection; CI forces each leg via `BMOE_KERNEL_ISA`.  [`Isa`]
//! deliberately exists (and parses) on every target so an unavailable
//! path is a *reported skip*, never a silently vacuous pass.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use anyhow::{bail, Result};

/// A selectable kernel instruction-set path.  `Scalar` is the blocked
/// reference the determinism contract is defined against; the SIMD
/// paths are pinned bit-identical (f32) / exactly-equal (i8) to it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Blocked-scalar reference kernels (autovectorized by LLVM).
    Scalar,
    /// Explicit `std::arch` AVX2 kernels (x86_64, runtime-detected).
    Avx2,
    /// Explicit `std::arch` NEON kernels (aarch64 baseline).
    Neon,
}

impl Isa {
    /// Every path the binary knows about, availability aside — the
    /// parity suite iterates this so unavailable ISAs surface as
    /// explicit skips.
    pub const ALL: [Isa; 3] = [Isa::Scalar, Isa::Avx2, Isa::Neon];

    /// Canonical spelling (what `BMOE_KERNEL_ISA` / `--kernel-isa`
    /// accept and what `BENCH_hotpath.json` records).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a spec string (case-insensitive; empty/`auto` = `None`,
    /// meaning "use detection").
    pub fn parse(spec: &str) -> Result<Option<Isa>> {
        match spec.to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(None),
            "scalar" => Ok(Some(Isa::Scalar)),
            "avx2" => Ok(Some(Isa::Avx2)),
            "neon" => Ok(Some(Isa::Neon)),
            other => bail!("unknown kernel ISA {other:?} (scalar|avx2|neon|auto)"),
        }
    }

    /// Whether this path can run on the current machine.  `Scalar` is
    /// always available; `Avx2` needs x86_64 *and* runtime CPUID
    /// support; `Neon` is baseline on every aarch64.
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => false,
            Isa::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Widest available path on this machine (never fails: falls back
    /// to `Scalar`).
    pub fn detect() -> Isa {
        if Isa::Avx2.available() {
            Isa::Avx2
        } else if Isa::Neon.available() {
            Isa::Neon
        } else {
            Isa::Scalar
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 2,
            Isa::Neon => 3,
        }
    }

    fn from_u8(v: u8) -> Option<Isa> {
        match v {
            1 => Some(Isa::Scalar),
            2 => Some(Isa::Avx2),
            3 => Some(Isa::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// 0 = unresolved; else `Isa::to_u8`.  Relaxed everywhere: resolution
/// is idempotent and the value never coordinates other memory.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// The ISA the dispatched kernel entries run on.  Resolves lazily on
/// first use (force → `BMOE_KERNEL_ISA` → detection) and then costs one
/// atomic load.  An invalid or unavailable env spec panics — a serving
/// process silently falling back to a different ISA than the operator
/// pinned would defeat the point of pinning.
#[inline]
pub fn active() -> Isa {
    match Isa::from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => resolve(),
    }
}

#[cold]
fn resolve() -> Isa {
    let isa = match std::env::var("BMOE_KERNEL_ISA") {
        Ok(spec) => match Isa::parse(&spec) {
            Ok(Some(isa)) if isa.available() => isa,
            Ok(Some(isa)) => {
                panic!("BMOE_KERNEL_ISA={spec}: {} unavailable on this machine", isa.name())
            }
            Ok(None) => Isa::detect(),
            Err(e) => panic!("BMOE_KERNEL_ISA: {e}"),
        },
        Err(_) => Isa::detect(),
    };
    ACTIVE.store(isa.to_u8(), Ordering::Relaxed);
    isa
}

/// Force the dispatched path from a spec string (the `--kernel-isa`
/// flag).  `""`/`"auto"` re-runs env + detection.  Errors on an unknown
/// or unavailable ISA; re-forcing is allowed (tests and benches cycle
/// paths within one process).
pub fn force(spec: &str) -> Result<Isa> {
    match Isa::parse(spec)? {
        Some(isa) => {
            force_isa(isa)?;
            Ok(isa)
        }
        None => {
            ACTIVE.store(0, Ordering::Relaxed);
            Ok(active())
        }
    }
}

/// Force a specific [`Isa`].  Errors if the path cannot run here.
pub fn force_isa(isa: Isa) -> Result<()> {
    if !isa.available() {
        bail!("kernel ISA {} unavailable on this machine", isa.name());
    }
    ACTIVE.store(isa.to_u8(), Ordering::Relaxed);
    Ok(())
}

/// How many W1.58A8 substrate GEMMs (`BitplaneTernary::gemm_a8*`) have
/// run in this process — the non-vacuity witness for the a8-default
/// accuracy gate (`rust/tests/determinism.rs`): a test bounding a8
/// error must also prove the a8 path executed, or a silent fallback to
/// the exact path would pass it trivially.
static A8_GEMM_CALLS: AtomicU64 = AtomicU64::new(0);

/// Process-lifetime count of a8 substrate GEMM calls.
pub fn a8_gemm_calls() -> u64 {
    A8_GEMM_CALLS.load(Ordering::Relaxed)
}

/// Recorded by `BitplaneTernary::gemm_a8_with` (one relaxed increment
/// per GEMM call, not per tile — unmeasurable on the hot path).
pub(crate) fn note_a8_gemm() {
    A8_GEMM_CALLS.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()).unwrap(), Some(isa));
            assert_eq!(Isa::parse(&isa.name().to_uppercase()).unwrap(), Some(isa));
        }
        assert_eq!(Isa::parse("").unwrap(), None);
        assert_eq!(Isa::parse("auto").unwrap(), None);
        assert!(Isa::parse("sse9").is_err());
    }

    #[test]
    fn scalar_always_available_and_detect_is_available() {
        assert!(Isa::Scalar.available());
        assert!(Isa::detect().available());
    }

    #[test]
    fn force_unavailable_errors_available_sticks() {
        if let Some(unavail) = Isa::ALL.iter().find(|i| !i.available()) {
            assert!(force_isa(*unavail).is_err());
        }
        force_isa(Isa::Scalar).unwrap();
        assert_eq!(active(), Isa::Scalar);
        // restore detection for the rest of the process
        force("auto").unwrap();
        assert!(active().available());
    }
}
