//! Explicit AVX2 micro-kernels (x86_64), selected at runtime by
//! [`super::dispatch`].
//!
//! # Bit-identity discipline (f32)
//!
//! Every f32 kernel here must reproduce the blocked-scalar reference's
//! bits exactly (the contract in the [`super`] module docs), which
//! pins three choices:
//!
//! * **One 8-lane `__m256` accumulator per output row** — lane `l`
//!   accumulates exactly the products scalar lane `acc[l]` does, in the
//!   same ascending k-chunk order.
//! * **No FMA.**  The scalar reference's `acc += w * x` is an
//!   unfused multiply-then-add (rustc does not contract float
//!   expressions), so these kernels use `_mm256_add_ps(_mm256_mul_ps)`
//!   — never `_mm256_fmadd_ps`, whose single rounding would change
//!   bits.  Same for the butterfly rotation's `c*a - s*b`.
//! * **Scalar reduction tree + tail.**  The 8 lanes are extracted and
//!   reduced with the exact `dot_f32` tree
//!   `(a0+a1) + (a2+a3) + ((a4+a5) + (a6+a7))`, and the `nl..cols`
//!   remainder runs as scalar adds — no horizontal-add instructions,
//!   which associate differently.
//!
//! The i8 kernels have no such constraint (i32 accumulation is exact),
//! so they use the natural AVX2 idiom: sign-extend 16 i8 lanes to i16
//! and `_mm256_madd_epi16` pairs into i32 — every intermediate fits
//! (see [`super::MAX_I8_DOT_LEN`]: |products| ≤ 127², pair sums ≤
//! 2·127², and a lane accumulates ≤ `cols/16` of those).
//!
//! # Safety
//!
//! Every fn is `unsafe fn` + `#[target_feature(enable = "avx2")]`: the
//! caller (the dispatch layer) must only select this module when
//! `is_x86_feature_detected!("avx2")` held.  All loads/stores are
//! unaligned-tolerant (`loadu`/`storeu`); indices stay inside the
//! slices per the `debug_assert!`ed shape contracts.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

use super::{LANES, LANES_I8, NR};

/// Extract 8 lanes and reduce with the exact `dot_f32` tree.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn reduce8(v: __m256) -> f32 {
    let mut a = [0.0f32; LANES];
    _mm256_storeu_ps(a.as_mut_ptr(), v);
    (a[0] + a[1]) + (a[2] + a[3]) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// AVX2 `util::dot_f32` — bit-identical single-row dot (the GEMM row
/// tail).
#[target_feature(enable = "avx2")]
pub unsafe fn dot1_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let nl = n - n % LANES;
    let mut acc = _mm256_setzero_ps();
    let mut k = 0;
    while k < nl {
        let av = _mm256_loadu_ps(a.as_ptr().add(k));
        let bv = _mm256_loadu_ps(b.as_ptr().add(k));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        k += LANES;
    }
    let mut s = reduce8(acc);
    for j in nl..n {
        s += a[j] * b[j];
    }
    s
}

/// AVX2 [`super::dot_nr_x1`]: `NR` rows × one token, activation chunk
/// loaded once per k-step.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_nr_x1(w: &[f32], cols: usize, x: &[f32]) -> [f32; NR] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x.len(), cols);
    let nl = cols - cols % LANES;
    let mut acc = [_mm256_setzero_ps(); NR];
    let mut k = 0;
    while k < nl {
        let xv = _mm256_loadu_ps(x.as_ptr().add(k));
        for r in 0..NR {
            let wv = _mm256_loadu_ps(w.as_ptr().add(r * cols + k));
            acc[r] = _mm256_add_ps(acc[r], _mm256_mul_ps(wv, xv));
        }
        k += LANES;
    }
    let mut out = [0.0f32; NR];
    for r in 0..NR {
        let mut s = reduce8(acc[r]);
        for j in nl..cols {
            s += w[r * cols + j] * x[j];
        }
        out[r] = s;
    }
    out
}

/// AVX2 [`super::dot_nr_x2`]: `NR` rows × two tokens sharing every
/// weight-chunk load.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_nr_x2(w: &[f32], cols: usize, x0: &[f32], x1: &[f32]) -> [[f32; NR]; 2] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x0.len(), cols);
    debug_assert_eq!(x1.len(), cols);
    let nl = cols - cols % LANES;
    let mut acc = [[_mm256_setzero_ps(); NR]; 2];
    let mut k = 0;
    while k < nl {
        let x0v = _mm256_loadu_ps(x0.as_ptr().add(k));
        let x1v = _mm256_loadu_ps(x1.as_ptr().add(k));
        for r in 0..NR {
            let wv = _mm256_loadu_ps(w.as_ptr().add(r * cols + k));
            acc[0][r] = _mm256_add_ps(acc[0][r], _mm256_mul_ps(wv, x0v));
            acc[1][r] = _mm256_add_ps(acc[1][r], _mm256_mul_ps(wv, x1v));
        }
        k += LANES;
    }
    let mut out = [[0.0f32; NR]; 2];
    for (m, xm) in [x0, x1].into_iter().enumerate() {
        for r in 0..NR {
            let mut s = reduce8(acc[m][r]);
            for j in nl..cols {
                s += w[r * cols + j] * xm[j];
            }
            out[m][r] = s;
        }
    }
    out
}

/// Sum a `__m256i` of 8 i32 lanes (exact, association-free).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn reduce8_i32(v: __m256i) -> i32 {
    let mut a = [0i32; 8];
    _mm256_storeu_si256(a.as_mut_ptr() as *mut __m256i, v);
    a.iter().sum()
}

/// AVX2 widening i8 dot: 16 i8 lanes sign-extended to i16,
/// `madd_epi16` pairs into 8 i32 lanes.  Exactly equal to
/// [`super::dot_i8`] for any input within [`super::MAX_I8_DOT_LEN`].
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let nl = n - n % LANES_I8;
    let mut acc = _mm256_setzero_si256();
    let mut i = 0;
    while i < nl {
        let av = _mm256_cvtepi8_epi16(_mm_loadu_si128(a.as_ptr().add(i) as *const __m128i));
        let bv = _mm256_cvtepi8_epi16(_mm_loadu_si128(b.as_ptr().add(i) as *const __m128i));
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, bv));
        i += LANES_I8;
    }
    let mut s = reduce8_i32(acc);
    for j in nl..n {
        s += a[j] as i32 * b[j] as i32;
    }
    s
}

/// AVX2 [`super::dot_nr_x1_i8`]-equivalent: `NR` widening i8 dots
/// sharing each activation-chunk load.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_nr_x1_i8(w: &[i8], cols: usize, x: &[i8]) -> [i32; NR] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x.len(), cols);
    let nl = cols - cols % LANES_I8;
    let mut acc = [_mm256_setzero_si256(); NR];
    let mut k = 0;
    while k < nl {
        let xv = _mm256_cvtepi8_epi16(_mm_loadu_si128(x.as_ptr().add(k) as *const __m128i));
        for r in 0..NR {
            let wv = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                w.as_ptr().add(r * cols + k) as *const __m128i
            ));
            acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(wv, xv));
        }
        k += LANES_I8;
    }
    let mut out = [0i32; NR];
    for r in 0..NR {
        let mut s = reduce8_i32(acc[r]);
        for j in nl..cols {
            s += w[r * cols + j] as i32 * x[j] as i32;
        }
        out[r] = s;
    }
    out
}

/// AVX2 butterfly pair rotation over `rb` contiguous lanes:
/// `lo' = c·lo − s·hi`, `hi' = s·lo + c·hi` — unfused mul/sub/add,
/// bit-identical per element to the scalar rotation.
#[target_feature(enable = "avx2")]
pub unsafe fn rotate_lanes(c: f32, s: f32, lo: &mut [f32], hi: &mut [f32]) {
    debug_assert_eq!(lo.len(), hi.len());
    let n = lo.len();
    let vc = _mm256_set1_ps(c);
    let vs = _mm256_set1_ps(s);
    let mut k = 0;
    while k + LANES <= n {
        let va = _mm256_loadu_ps(lo.as_ptr().add(k));
        let vb = _mm256_loadu_ps(hi.as_ptr().add(k));
        let na = _mm256_sub_ps(_mm256_mul_ps(vc, va), _mm256_mul_ps(vs, vb));
        let nb = _mm256_add_ps(_mm256_mul_ps(vs, va), _mm256_mul_ps(vc, vb));
        _mm256_storeu_ps(lo.as_mut_ptr().add(k), na);
        _mm256_storeu_ps(hi.as_mut_ptr().add(k), nb);
        k += LANES;
    }
    while k < n {
        let (a, b) = (lo[k], hi[k]);
        lo[k] = c * a - s * b;
        hi[k] = s * a + c * b;
        k += 1;
    }
}
