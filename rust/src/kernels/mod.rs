//! Blocked SIMD micro-kernels for the expert-synthesis hot path
//! (§Perf iteration 6).
//!
//! Every decode step is made of three native kernels — butterfly apply,
//! ternary GEMM, dense down projection — and before this module each of
//! them ran at the wrong loop order for the cache: `apply_batch` walked
//! one row at a time re-streaming the whole (cos, sin) table per row,
//! and the GEMMs computed one [`dot_f32`](crate::util::dot_f32) per
//! (row, token) pair, re-reading the activation block from memory `rows`
//! times per batch.  This module is the shared kernel layer the hot path
//! is rewritten on top of:
//!
//! * [`butterfly_apply_blocked`] — **stage-outer blocked butterfly**.
//!   A block of up to [`RB`] rows is transposed into a column-major
//!   scratch; stages iterate outermost, so each stage's (cos, sin) table
//!   is read **once per block** (and stays L1-resident across the pair
//!   loop), and the per-pair two-FMA rotation runs over `RB` *contiguous*
//!   lanes — it vectorizes across rows for every stride, including the
//!   stride-1 stage that defeats vectorization in the per-row walk.
//! * [`gemm_f32_strided`] / [`gemm_i8_strided`] — **register-blocked
//!   GEMM micro-kernels**: per k-chunk, the activation chunk is loaded
//!   once and fused against [`NR`] weight rows, so activations are
//!   re-read `rows/NR` times instead of `rows` times and the weight
//!   block streams exactly once.  The f32 kernel additionally blocks
//!   [`MC`] tokens per weight-chunk load; the i8 kernel stays `NR × 1`
//!   — its 16-lane i32 accumulators already fill the register budget,
//!   and an `MC = 2` tile (128 live accumulators) would spill.
//!
//! # Bit-identity contract (the reason this layer is *shared*)
//!
//! The serving stack's parity invariants are path-vs-path, not
//! golden-value: decoded-cache vs synthesis forwards, and parallel vs
//! sequential schedules, must agree **bit-for-bit**
//! (`rust/tests/determinism.rs`, `rust/tests/expert_cache.rs`).  Two
//! properties make that hold by construction:
//!
//! * Every f32 GEMM output is computed with the **exact lane association
//!   of [`dot_f32`](crate::util::dot_f32)** — same 8-lane accumulators
//!   over ascending k-chunks, same fixed reduction tree, same scalar
//!   tail.  An output's bits therefore do not depend on where a tile
//!   boundary fell (row tails, token tails, worker-range splits all
//!   reduce to the same per-output arithmetic), and the blocked kernels
//!   are drop-in bit-identical replacements for the per-dot loops they
//!   retire.  `rust/tests/kernels.rs` pins this across shapes.
//! * The blocked butterfly applies, per element, exactly the same
//!   two-FMA chain as the per-row
//!   [`Butterfly::apply`](crate::butterfly::Butterfly::apply): stages
//!   are barriers, pairs within a stage are disjoint, and the transpose
//!   in/out is pure data movement — so stage-outer vs row-outer order
//!   cannot change a bit.
//!
//! All ternary/dense GEMM call sites (`BitplaneTernary::{gemm, gemm_a8}`,
//! `DecodedExpert::gemm`, the shared down projection in
//! `MoeLayer::forward`) route through this one layer, so the cached and
//! uncached serving streams keep producing identical bits.
//!
//! # Memory accounting
//!
//! Kernel scratch ([`TernaryScratch`], the butterfly transpose block) is
//! **working-set** memory, like the residency cache's decoded sets and
//! the dispatch-block gather buffers — it never counts toward Table-1
//! expert-identity bytes (`MoeLayer::expert_bytes`); see
//! `crate::memmodel`.
//!
//! # Runtime ISA dispatch (§Perf iteration 8)
//!
//! Each hot kernel has three implementations: the blocked-scalar
//! reference in this file, explicit AVX2 (`x86.rs`, x86_64) and
//! explicit NEON (`neon.rs`, aarch64).  [`dispatch::active`] selects
//! one at startup — CPU detection, overridable by `BMOE_KERNEL_ISA` or
//! `--kernel-isa` — and the public entry points below dispatch on it
//! (one relaxed atomic load per call).  Every entry also has an
//! `*_on(isa, …)` variant taking the path explicitly, which is what
//! the cross-ISA parity suite (`rust/tests/kernels.rs`) and the
//! per-ISA bench curves drive.
//!
//! Dispatch does not weaken the bit-identity contract: the SIMD f32
//! kernels reproduce the scalar reference's bits *by construction*
//! (one vector lane per scalar accumulator lane, unfused mul/add —
//! never FMA — and the same scalar reduction tree and tails; see the
//! `x86`/`neon` module docs), and the i8 kernels are exactly equal
//! because i32 accumulation is associative.  So the ISA choice, like
//! tile and worker-range placement, never changes decoded bits — the
//! parity suite pins every property per force-selected ISA.

pub mod dispatch;
#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use dispatch::Isa;

use crate::util::dot_f32;

/// GEMM row-block: weight rows fused per activation chunk.  4 rows × 8
/// f32 lanes × 2 tokens = 64 live accumulators — the AVX2 register
/// budget; wider blocks spill.
pub const NR: usize = 4;

/// GEMM token-block: tokens sharing one weight-chunk load.
pub const MC: usize = 2;

/// Butterfly row-block: rows rotated per transposed scratch block.  The
/// per-pair rotation runs over `RB` contiguous lanes; 16 keeps the
/// scratch (`d * RB * 4` bytes) L2-resident at the paper's `d_ff = 2048`.
pub const RB: usize = 16;

/// f32 accumulator lanes — must match [`dot_f32`]'s lane count, which
/// the bit-identity contract is defined against.
pub const LANES: usize = 8;

/// Reusable scratch for the ternary GEMM hot path: decoded sign blocks
/// and (for the W1.58A8 path) quantized activations.  Hoisted out of
/// `gemm`/`gemm_a8` so steady-state decode does **zero allocation** —
/// the vectors are resized in place and retained by the caller (the
/// layer keeps one per dispatch block); `rust/tests/alloc_guard.rs`
/// asserts the zero-allocation property under a counting allocator.
///
/// These are *working-set* bytes (see module docs), bounded by
/// `NR·cols·5 + t·(cols + 4)` — independent of expert count.
#[derive(Default)]
pub struct TernaryScratch {
    /// `NR × cols` decoded f32 sign rows (exact-path GEMM).
    pub signs_f32: Vec<f32>,
    /// `NR × cols` decoded i8 sign rows (W1.58A8 GEMM).
    pub signs_i8: Vec<i8>,
    /// `t × cols` per-token absmax-quantized activations.
    pub xq: Vec<i8>,
    /// `t` per-token dequantization scales (gamma folded in).
    pub scales: Vec<f32>,
}

// ---------------------------------------------------------------------------
// ISA-dispatched entry points
// ---------------------------------------------------------------------------

/// Soundness gate for the `*_on` entry points: the SIMD modules are
/// `#[target_feature]` fns whose callers must guarantee the feature is
/// present, and these entries are *safe* — so an unavailable ISA must
/// fail loudly here, not reach an `unsafe` call.  One cached-atomic
/// feature load; the hot path pays it once per kernel call, not per
/// tile.
#[inline]
fn vouch(isa: Isa) {
    assert!(
        isa.available(),
        "kernel ISA {} unavailable on this machine",
        isa.name()
    );
}

/// `NR` dot products of contiguous weight rows against one token on
/// the active ISA: `out[r] = dot_f32(w[r*cols..][..cols], x)` — the
/// same bits on every path, with the activation chunk loaded once per
/// k-step instead of once per row.
#[inline]
pub fn dot_nr_x1(w: &[f32], cols: usize, x: &[f32]) -> [f32; NR] {
    dot_nr_x1_on(dispatch::active(), w, cols, x)
}

/// [`dot_nr_x1`] on an explicit ISA (parity tests / per-ISA benches).
#[inline]
pub fn dot_nr_x1_on(isa: Isa, w: &[f32], cols: usize, x: &[f32]) -> [f32; NR] {
    vouch(isa);
    match isa {
        Isa::Scalar => dot_nr_x1_scalar(w, cols, x),
        // SAFETY: `vouch` proved the feature is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_nr_x1(w, cols, x) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot_nr_x1(w, cols, x) },
        #[allow(unreachable_patterns)] // ISAs of other target_archs
        other => unreachable!("{} not compiled for this target", other.name()),
    }
}

/// [`dot_nr_x2`] on an explicit ISA.
#[inline]
pub fn dot_nr_x2_on(isa: Isa, w: &[f32], cols: usize, x0: &[f32], x1: &[f32]) -> [[f32; NR]; 2] {
    vouch(isa);
    match isa {
        Isa::Scalar => dot_nr_x2_scalar(w, cols, x0, x1),
        // SAFETY: `vouch` proved the feature is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_nr_x2(w, cols, x0, x1) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot_nr_x2(w, cols, x0, x1) },
        #[allow(unreachable_patterns)]
        other => unreachable!("{} not compiled for this target", other.name()),
    }
}

/// [`crate::util::dot_f32`] on an explicit ISA — bit-identical single
/// row dot (the GEMM row-tail primitive).
#[inline]
pub fn dot1_f32_on(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    vouch(isa);
    match isa {
        Isa::Scalar => dot_f32(a, b),
        // SAFETY: `vouch` proved the feature is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot1_f32(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot1_f32(a, b) },
        #[allow(unreachable_patterns)]
        other => unreachable!("{} not compiled for this target", other.name()),
    }
}

/// [`dot_i8`] on an explicit ISA.
#[inline]
pub fn dot_i8_on(isa: Isa, a: &[i8], b: &[i8]) -> i32 {
    vouch(isa);
    debug_assert!(a.len() <= MAX_I8_DOT_LEN, "dot_i8 depth {} > 2^16", a.len());
    match isa {
        Isa::Scalar => dot_i8_scalar(a, b),
        // SAFETY: `vouch` proved the feature is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_i8(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot_i8(a, b) },
        #[allow(unreachable_patterns)]
        other => unreachable!("{} not compiled for this target", other.name()),
    }
}

/// `dot_nr_x1_i8` on an explicit ISA (the i8 GEMM's row tile).
#[inline]
fn dot_nr_x1_i8_on(isa: Isa, w: &[i8], cols: usize, x: &[i8]) -> [i32; NR] {
    vouch(isa);
    debug_assert!(cols <= MAX_I8_DOT_LEN, "dot_nr_x1_i8 depth {cols} > 2^16");
    match isa {
        Isa::Scalar => dot_nr_x1_i8_scalar(w, cols, x),
        // SAFETY: `vouch` proved the feature is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { x86::dot_nr_x1_i8(w, cols, x) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot_nr_x1_i8(w, cols, x) },
        #[allow(unreachable_patterns)]
        other => unreachable!("{} not compiled for this target", other.name()),
    }
}

// ---------------------------------------------------------------------------
// f32 dot tiles, blocked-scalar reference — bit-identical to
// util::dot_f32 per output
// ---------------------------------------------------------------------------

/// Blocked-scalar [`dot_nr_x1`] — the reference the SIMD paths are
/// pinned against.
#[inline]
fn dot_nr_x1_scalar(w: &[f32], cols: usize, x: &[f32]) -> [f32; NR] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x.len(), cols);
    let nl = cols - cols % LANES;
    let mut acc = [[0.0f32; LANES]; NR];
    let mut k = 0;
    while k < nl {
        let xv = &x[k..k + LANES];
        for r in 0..NR {
            let wv = &w[r * cols + k..r * cols + k + LANES];
            for l in 0..LANES {
                acc[r][l] += wv[l] * xv[l];
            }
        }
        k += LANES;
    }
    let mut out = [0.0f32; NR];
    for r in 0..NR {
        let a = &acc[r];
        // identical reduction tree and tail to util::dot_f32
        let mut s = (a[0] + a[1]) + (a[2] + a[3]) + ((a[4] + a[5]) + (a[6] + a[7]));
        for j in nl..cols {
            s += w[r * cols + j] * x[j];
        }
        out[r] = s;
    }
    out
}

/// [`dot_nr_x1`] over two tokens sharing every weight-chunk load:
/// `out[m][r] = dot_f32(w_row_r, x_m)`, bit-identical per output.
#[inline]
pub fn dot_nr_x2(w: &[f32], cols: usize, x0: &[f32], x1: &[f32]) -> [[f32; NR]; 2] {
    dot_nr_x2_on(dispatch::active(), w, cols, x0, x1)
}

/// Blocked-scalar [`dot_nr_x2`] reference.
#[inline]
fn dot_nr_x2_scalar(w: &[f32], cols: usize, x0: &[f32], x1: &[f32]) -> [[f32; NR]; 2] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x0.len(), cols);
    debug_assert_eq!(x1.len(), cols);
    let nl = cols - cols % LANES;
    let mut acc = [[[0.0f32; LANES]; NR]; 2];
    let mut k = 0;
    while k < nl {
        let x0v = &x0[k..k + LANES];
        let x1v = &x1[k..k + LANES];
        for r in 0..NR {
            let wv = &w[r * cols + k..r * cols + k + LANES];
            for l in 0..LANES {
                acc[0][r][l] += wv[l] * x0v[l];
                acc[1][r][l] += wv[l] * x1v[l];
            }
        }
        k += LANES;
    }
    let mut out = [[0.0f32; NR]; 2];
    for (m, xm) in [x0, x1].into_iter().enumerate() {
        for r in 0..NR {
            let a = &acc[m][r];
            let mut s = (a[0] + a[1]) + (a[2] + a[3]) + ((a[4] + a[5]) + (a[6] + a[7]));
            for j in nl..cols {
                s += w[r * cols + j] * xm[j];
            }
            out[m][r] = s;
        }
    }
    out
}

/// Register-blocked GEMM over a strided output window, generic in the
/// output sink: `write(i*y_stride + y0 + r, gamma * dot_f32(w_row_r,
/// x_token_i))` for `r in 0..nrows`, `i in 0..t`.  Full `NR` row tiles
/// and `MC` token tiles run through the fused dot tiles above; tails
/// fall back to [`dot_f32`] — which produces the same bits, so tile
/// placement never shows in the output (the property worker-range
/// sharding relies on).
///
/// The sink exists so the *one* tile schedule serves both plain slices
/// ([`gemm_f32_strided`]) and disjoint-index parallel writes (the down
/// projection's `DisjointSliceMut`) — the sink is monomorphized away,
/// and a schedule change can never desynchronize the two paths.
#[allow(clippy::too_many_arguments)] // strided-output kernel: shape + window params are irreducible
pub fn gemm_f32_sink(
    w: &[f32],
    nrows: usize,
    cols: usize,
    x: &[f32],
    t: usize,
    gamma: f32,
    y0: usize,
    y_stride: usize,
    write: impl FnMut(usize, f32),
) {
    gemm_f32_sink_on(
        dispatch::active(),
        w,
        nrows,
        cols,
        x,
        t,
        gamma,
        y0,
        y_stride,
        write,
    );
}

/// [`gemm_f32_sink`] on an explicit ISA.  One tile schedule for every
/// path — only the dot tiles change, and those are bit-identical, so
/// the ISA is as invisible in the output as a tile boundary.
#[allow(clippy::too_many_arguments)] // see gemm_f32_sink
pub fn gemm_f32_sink_on(
    isa: Isa,
    w: &[f32],
    nrows: usize,
    cols: usize,
    x: &[f32],
    t: usize,
    gamma: f32,
    y0: usize,
    y_stride: usize,
    mut write: impl FnMut(usize, f32),
) {
    debug_assert_eq!(w.len(), nrows * cols);
    debug_assert_eq!(x.len(), t * cols);
    vouch(isa);
    let mut r = 0;
    while r + NR <= nrows {
        let wblk = &w[r * cols..(r + NR) * cols];
        let mut i = 0;
        while i + MC <= t {
            let tile = dot_nr_x2_on(
                isa,
                wblk,
                cols,
                &x[i * cols..(i + 1) * cols],
                &x[(i + 1) * cols..(i + 2) * cols],
            );
            for (m, lanes) in tile.iter().enumerate() {
                for (rr, &v) in lanes.iter().enumerate() {
                    write((i + m) * y_stride + y0 + r + rr, v * gamma);
                }
            }
            i += MC;
        }
        if i < t {
            let lanes = dot_nr_x1_on(isa, wblk, cols, &x[i * cols..(i + 1) * cols]);
            for (rr, &v) in lanes.iter().enumerate() {
                write(i * y_stride + y0 + r + rr, v * gamma);
            }
        }
        r += NR;
    }
    while r < nrows {
        let wr = &w[r * cols..(r + 1) * cols];
        for i in 0..t {
            write(
                i * y_stride + y0 + r,
                dot1_f32_on(isa, wr, &x[i * cols..(i + 1) * cols]) * gamma,
            );
        }
        r += 1;
    }
}

/// [`gemm_f32_sink`] writing into a plain slice:
/// `y[i*y_stride + y0 + r] = gamma * dot_f32(w_row_r, x_token_i)`.
#[allow(clippy::too_many_arguments)] // see gemm_f32_sink
pub fn gemm_f32_strided(
    w: &[f32],
    nrows: usize,
    cols: usize,
    x: &[f32],
    t: usize,
    gamma: f32,
    y: &mut [f32],
    y0: usize,
    y_stride: usize,
) {
    debug_assert!(t == 0 || (t - 1) * y_stride + y0 + nrows <= y.len());
    gemm_f32_sink(w, nrows, cols, x, t, gamma, y0, y_stride, |i, v| y[i] = v);
}

/// [`gemm_f32_strided`] on an explicit ISA.
#[allow(clippy::too_many_arguments)] // see gemm_f32_sink
pub fn gemm_f32_strided_on(
    isa: Isa,
    w: &[f32],
    nrows: usize,
    cols: usize,
    x: &[f32],
    t: usize,
    gamma: f32,
    y: &mut [f32],
    y0: usize,
    y_stride: usize,
) {
    debug_assert!(t == 0 || (t - 1) * y_stride + y0 + nrows <= y.len());
    gemm_f32_sink_on(isa, w, nrows, cols, x, t, gamma, y0, y_stride, |i, v| {
        y[i] = v
    });
}

/// Dense-output convenience wrapper: `y[i*rows + r]`, token-major —
/// the layout of `BitplaneTernary::gemm` / `DecodedExpert::gemm`.
pub fn gemm_f32(
    w: &[f32],
    rows: usize,
    cols: usize,
    x: &[f32],
    t: usize,
    gamma: f32,
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), t * rows);
    gemm_f32_strided(w, rows, cols, x, t, gamma, y, 0, rows);
}

// ---------------------------------------------------------------------------
// i8 dot tiles — the W1.58A8 path (i32 accumulation is exact, so tiling
// cannot change bits regardless of association)
// ---------------------------------------------------------------------------

/// i8 accumulator lanes — matches the widening [`dot_i8`] reference.
pub const LANES_I8: usize = 16;

/// Maximum supported depth (vector length) for the i8 dot kernels.
///
/// The i32 accumulator bound: with `|a[j]|, |b[j]| ≤ 127` every
/// product is ≤ 127² = 16 129, so a length-`2^16` dot sums to at most
/// 16 129 · 65 536 = 1 057 030 144 < 2³¹ − 1 — no lane or total can
/// overflow, on any ISA path (the AVX2 `madd_epi16` pair-sums are
/// ≤ 2·127² and each of its 8 lanes accumulates ≤ `len/16` of those:
/// ≤ 132 M at this bound; NEON's `vpadalq_s16` lanes likewise).
/// Beyond this length `i32` accumulation may wrap; the kernels
/// `debug_assert!` the bound and callers gate on it
/// (`BitplaneTernary::gemm_a8_with` — `d_model ≤ 65 536` covers every
/// model shape this engine can serve, 32× the paper's largest).
pub const MAX_I8_DOT_LEN: usize = 1 << 16;

/// Widening i8 dot on the active ISA (§Perf iteration 5).
///
/// Integer accumulation is exact, so every ISA path returns the same
/// `i32` bit-for-bit — the blocked tiles and SIMD paths are pinned
/// exactly-equal to this reference by `rust/tests/kernels.rs`.
///
/// **Range contract:** `a.len() ≤ 2^16` ([`MAX_I8_DOT_LEN`]) at
/// `|a[j]|, |b[j]| ≤ 127`; longer inputs may overflow the i32
/// accumulation (checked by `debug_assert!`, documented at call
/// sites).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    dot_i8_on(dispatch::active(), a, b)
}

/// Blocked-scalar [`dot_i8`] reference: 16 lanes of i32 accumulation
/// (autovectorizes).
#[inline]
fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let nl = n - n % LANES_I8;
    let mut acc = [0i32; LANES_I8];
    let mut i = 0;
    while i < nl {
        let (av, bv) = (&a[i..i + LANES_I8], &b[i..i + LANES_I8]);
        for l in 0..LANES_I8 {
            acc[l] += av[l] as i32 * bv[l] as i32;
        }
        i += LANES_I8;
    }
    let mut s: i32 = acc.iter().sum();
    for j in nl..n {
        s += a[j] as i32 * b[j] as i32;
    }
    s
}

/// Blocked-scalar `NR` widening i8 dots sharing each activation-chunk
/// load.
#[inline]
fn dot_nr_x1_i8_scalar(w: &[i8], cols: usize, x: &[i8]) -> [i32; NR] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x.len(), cols);
    let nl = cols - cols % LANES_I8;
    let mut acc = [[0i32; LANES_I8]; NR];
    let mut k = 0;
    while k < nl {
        let xv = &x[k..k + LANES_I8];
        for r in 0..NR {
            let wv = &w[r * cols + k..r * cols + k + LANES_I8];
            for l in 0..LANES_I8 {
                acc[r][l] += wv[l] as i32 * xv[l] as i32;
            }
        }
        k += LANES_I8;
    }
    let mut out = [0i32; NR];
    for r in 0..NR {
        let mut s: i32 = acc[r].iter().sum();
        for j in nl..cols {
            s += w[r * cols + j] as i32 * x[j] as i32;
        }
        out[r] = s;
    }
    out
}

/// Register-blocked i8 GEMM over a strided output window:
/// `y[i*y_stride + y0 + r] = dot_i8(w_row_r, xq_token_i) as f32 *
/// scales[i]` — the per-token scale carries the activation absmax and
/// the ternary gamma.  `NR × 1` blocking only (no `MC` token tile): the
/// 16-lane i32 accumulators per row already saturate the register file
/// (see module docs); the decoded sign block is small enough to stay
/// L1-resident across the token loop regardless.
///
/// Inherits [`dot_i8`]'s range contract: `cols ≤ 2^16`
/// ([`MAX_I8_DOT_LEN`]).
#[allow(clippy::too_many_arguments)] // see gemm_f32_strided
pub fn gemm_i8_strided(
    w: &[i8],
    nrows: usize,
    cols: usize,
    xq: &[i8],
    t: usize,
    scales: &[f32],
    y: &mut [f32],
    y0: usize,
    y_stride: usize,
) {
    gemm_i8_strided_on(
        dispatch::active(),
        w,
        nrows,
        cols,
        xq,
        t,
        scales,
        y,
        y0,
        y_stride,
    );
}

/// [`gemm_i8_strided`] on an explicit ISA.
#[allow(clippy::too_many_arguments)] // see gemm_f32_strided
pub fn gemm_i8_strided_on(
    isa: Isa,
    w: &[i8],
    nrows: usize,
    cols: usize,
    xq: &[i8],
    t: usize,
    scales: &[f32],
    y: &mut [f32],
    y0: usize,
    y_stride: usize,
) {
    debug_assert_eq!(w.len(), nrows * cols);
    debug_assert_eq!(xq.len(), t * cols);
    debug_assert_eq!(scales.len(), t);
    vouch(isa);
    let mut r = 0;
    while r + NR <= nrows {
        let wblk = &w[r * cols..(r + NR) * cols];
        for i in 0..t {
            let lanes = dot_nr_x1_i8_on(isa, wblk, cols, &xq[i * cols..(i + 1) * cols]);
            let dst = &mut y[i * y_stride + y0 + r..][..NR];
            for (d, &v) in dst.iter_mut().zip(&lanes) {
                *d = v as f32 * scales[i];
            }
        }
        r += NR;
    }
    while r < nrows {
        let wr = &w[r * cols..(r + 1) * cols];
        for i in 0..t {
            y[i * y_stride + y0 + r] =
                dot_i8_on(isa, wr, &xq[i * cols..(i + 1) * cols]) as f32 * scales[i];
        }
        r += 1;
    }
}

// ---------------------------------------------------------------------------
// Stage-outer blocked butterfly
// ---------------------------------------------------------------------------

/// Stage-outer blocked butterfly apply over a row-major `(rows, d)`
/// batch, `rows = x.len() / d`.
///
/// Per block of up to [`RB`] rows: transpose into a column-major scratch
/// (`scratch[c*rb + row]`), run every stage over the whole block, and
/// transpose back.  Stage `l`'s (cos, sin) slice is read once per block
/// and stays L1-resident across its pair loop; each pair's rotation is
/// two FMAs over `rb` contiguous lanes — vectorized across rows at every
/// stride.  `transpose = true` runs the stages in reverse order with
/// negated sines (`B^T`), exactly like the per-row transpose apply.
///
/// Bit-identical to applying [`crate::butterfly::Butterfly::apply`] per
/// row: stages are barriers, pairs within a stage touch disjoint
/// coordinates, and each element goes through the same two-FMA chain
/// with the same `(c, s)` — loop order cannot change a bit.  Pinned by
/// the property tests in `rust/tests/kernels.rs` and the butterfly unit
/// tests.
///
/// `scratch` is resized to at most `d * RB` and retained by the caller
/// (working-set bytes; zero steady-state allocation).
///
/// `cs` is the interleaved `[cos, sin]` table (`depth * d` floats, the
/// layout of `Butterfly::cs_table` — also the exact bytes a model
/// artifact stores, so a mapping-borrowed table feeds this kernel with
/// no translation).
pub fn butterfly_apply_blocked(
    cs: &[f32],
    d: usize,
    depth: usize,
    transpose: bool,
    x: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    butterfly_apply_blocked_on(dispatch::active(), cs, d, depth, transpose, x, scratch);
}

/// [`butterfly_apply_blocked`] on an explicit ISA.  The block/stage
/// schedule is written once ([`butterfly_blocked_impl`], monomorphized
/// per rotation kernel); only the per-pair lane rotation differs, and
/// that is bit-identical per element on every path (unfused
/// `c·a − s·b` / `s·a + c·b` — see the `x86`/`neon` module docs).
pub fn butterfly_apply_blocked_on(
    isa: Isa,
    cs: &[f32],
    d: usize,
    depth: usize,
    transpose: bool,
    x: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    vouch(isa);
    match isa {
        Isa::Scalar => {
            butterfly_blocked_impl(cs, d, depth, transpose, x, scratch, rotate_lanes_scalar)
        }
        // SAFETY: `vouch` proved the feature is present.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => butterfly_blocked_impl(cs, d, depth, transpose, x, scratch, |c, s, lo, hi| {
            unsafe { x86::rotate_lanes(c, s, lo, hi) }
        }),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => butterfly_blocked_impl(cs, d, depth, transpose, x, scratch, |c, s, lo, hi| {
            unsafe { neon::rotate_lanes(c, s, lo, hi) }
        }),
        #[allow(unreachable_patterns)]
        other => unreachable!("{} not compiled for this target", other.name()),
    }
}

/// Scalar per-pair rotation over `rb` contiguous lanes — exactly the
/// two-FMA chain of `Butterfly::apply`, per element.
#[inline]
fn rotate_lanes_scalar(c: f32, s: f32, lo_lane: &mut [f32], hi_lane: &mut [f32]) {
    for (pa, pb) in lo_lane.iter_mut().zip(hi_lane.iter_mut()) {
        let (a, b) = (*pa, *pb);
        *pa = c * a - s * b;
        *pb = s * a + c * b;
    }
}

/// The shared stage-outer block schedule (see
/// [`butterfly_apply_blocked`] for the full contract), generic over
/// the per-pair lane rotation so each ISA's kernel monomorphizes into
/// the same loop structure.
fn butterfly_blocked_impl(
    cs: &[f32],
    d: usize,
    depth: usize,
    transpose: bool,
    x: &mut [f32],
    scratch: &mut Vec<f32>,
    rotate: impl Fn(f32, f32, &mut [f32], &mut [f32]) + Copy,
) {
    debug_assert_eq!(x.len() % d, 0);
    debug_assert_eq!(cs.len(), depth * d);
    let rows = x.len() / d;
    scratch.resize(d * RB.min(rows), 0.0);
    let mut done = 0;
    while done < rows {
        let rb = (rows - done).min(RB);
        let blk = &mut x[done * d..(done + rb) * d];
        // transpose in: scratch[c*rb + r] = blk[r*d + c]
        for (r, row) in blk.chunks_exact(d).enumerate() {
            for (c, &v) in row.iter().enumerate() {
                scratch[c * rb + r] = v;
            }
        }
        for li in 0..depth {
            let l = if transpose { depth - 1 - li } else { li };
            let stride = 1usize << l;
            let table = &cs[l * d..(l + 1) * d];
            let mut j = 0;
            let mut base = 0;
            while base < d {
                for off in 0..stride {
                    let lo = (base + off) * rb;
                    let hi = lo + stride * rb;
                    let (c, s0) = (table[2 * j], table[2 * j + 1]);
                    let s = if transpose { -s0 } else { s0 };
                    let (head, tail) = scratch.split_at_mut(hi);
                    let lo_lane = &mut head[lo..lo + rb];
                    let hi_lane = &mut tail[..rb];
                    rotate(c, s, lo_lane, hi_lane);
                    j += 1;
                }
                base += 2 * stride;
            }
        }
        // transpose out
        for (r, row) in blk.chunks_exact_mut(d).enumerate() {
            for (c, v) in row.iter_mut().enumerate() {
                *v = scratch[c * rb + r];
            }
        }
        done += rb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{dot_f32, Rng};

    fn vecs(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32(1.0)).collect()
    }

    #[test]
    fn dot_tiles_bit_identical_to_dot_f32() {
        for cols in [1usize, 7, 8, 9, 64, 200, 513] {
            let w = vecs(NR * cols, cols as u64);
            let x0 = vecs(cols, cols as u64 + 100);
            let x1 = vecs(cols, cols as u64 + 200);
            let one = dot_nr_x1(&w, cols, &x0);
            let two = dot_nr_x2(&w, cols, &x0, &x1);
            for r in 0..NR {
                let want0 = dot_f32(&w[r * cols..(r + 1) * cols], &x0);
                let want1 = dot_f32(&w[r * cols..(r + 1) * cols], &x1);
                assert_eq!(one[r], want0, "x1 tile cols={cols} r={r}");
                assert_eq!(two[0][r], want0, "x2 tile cols={cols} r={r}");
                assert_eq!(two[1][r], want1, "x2 tile cols={cols} r={r}");
            }
        }
    }

    #[test]
    fn gemm_f32_matches_per_dot_loop_all_tail_shapes() {
        // rows exercise full tiles + 1..NR-1 tails; t exercises MC tails
        for (rows, cols) in [(1usize, 16usize), (3, 24), (4, 33), (9, 64), (13, 100)] {
            for t in [1usize, 2, 3, 5] {
                let w = vecs(rows * cols, (rows * cols) as u64);
                let x = vecs(t * cols, (t * cols) as u64 + 7);
                let gamma = 0.37f32;
                let mut y = vec![0.0f32; t * rows];
                gemm_f32(&w, rows, cols, &x, t, gamma, &mut y);
                for i in 0..t {
                    for r in 0..rows {
                        let want =
                            dot_f32(&w[r * cols..(r + 1) * cols], &x[i * cols..(i + 1) * cols])
                                * gamma;
                        assert_eq!(y[i * rows + r], want, "({rows},{cols}) t={t} i={i} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn strided_window_only_touches_its_rows() {
        let (rows, cols, t) = (6usize, 32usize, 3usize);
        let w = vecs(rows * cols, 1);
        let x = vecs(t * cols, 2);
        let full_stride = rows + 4; // wider output with guard columns
        let mut y = vec![f32::NAN; t * full_stride];
        // fill the window in two calls, split mid-tile
        gemm_f32_strided(&w[..4 * cols], 4, cols, &x, t, 1.0, &mut y, 0, full_stride);
        gemm_f32_strided(&w[4 * cols..], 2, cols, &x, t, 1.0, &mut y, 4, full_stride);
        for i in 0..t {
            for r in 0..rows {
                let want = dot_f32(&w[r * cols..(r + 1) * cols], &x[i * cols..(i + 1) * cols]);
                assert_eq!(y[i * full_stride + r], want, "split tile i={i} r={r}");
            }
            for g in rows..full_stride {
                assert!(y[i * full_stride + g].is_nan(), "guard column clobbered");
            }
        }
    }

    #[test]
    fn split_position_does_not_change_bits() {
        // the property the worker-range down-projection sharding relies
        // on: any row-range split yields the same bits as one call
        let (rows, cols, t) = (11usize, 48usize, 4usize);
        let w = vecs(rows * cols, 3);
        let x = vecs(t * cols, 4);
        let mut whole = vec![0.0f32; t * rows];
        gemm_f32_strided(&w, rows, cols, &x, t, 1.0, &mut whole, 0, rows);
        for split in 1..rows {
            let mut parts = vec![0.0f32; t * rows];
            gemm_f32_strided(&w[..split * cols], split, cols, &x, t, 1.0, &mut parts, 0, rows);
            gemm_f32_strided(
                &w[split * cols..],
                rows - split,
                cols,
                &x,
                t,
                1.0,
                &mut parts,
                split,
                rows,
            );
            assert_eq!(parts, whole, "split at {split}");
        }
    }

    #[test]
    fn gemm_i8_matches_per_dot_loop() {
        let mut rng = Rng::new(9);
        for (rows, cols, t) in [(5usize, 40usize, 3usize), (8, 16, 1), (3, 100, 4)] {
            let w: Vec<i8> = (0..rows * cols)
                .map(|_| (rng.normal_f32(1.0) as i32).clamp(-1, 1) as i8)
                .collect();
            let xq: Vec<i8> = (0..t * cols)
                .map(|_| (rng.normal_f32(40.0) as i32).clamp(-127, 127) as i8)
                .collect();
            let scales: Vec<f32> = (0..t).map(|i| 0.01 + i as f32 * 0.003).collect();
            let mut y = vec![0.0f32; t * rows];
            gemm_i8_strided(&w, rows, cols, &xq, t, &scales, &mut y, 0, rows);
            for i in 0..t {
                for r in 0..rows {
                    let want = dot_i8(&w[r * cols..(r + 1) * cols], &xq[i * cols..(i + 1) * cols])
                        as f32
                        * scales[i];
                    assert_eq!(y[i * rows + r], want, "({rows},{cols},{t}) i={i} r={r}");
                }
            }
        }
    }

    #[test]
    fn ternary_scratch_reuse_does_not_reallocate() {
        let mut s = TernaryScratch::default();
        s.signs_f32.resize(NR * 64, 0.0);
        s.xq.resize(8 * 64, 0);
        s.scales.resize(8, 0.0);
        let caps = (s.signs_f32.capacity(), s.xq.capacity(), s.scales.capacity());
        // steady state: shrink then grow back within capacity
        for t in [8usize, 3, 1, 8] {
            s.signs_f32.resize(NR * 64, 0.0);
            s.xq.resize(t * 64, 0);
            s.scales.resize(t, 0.0);
        }
        assert_eq!(
            caps,
            (s.signs_f32.capacity(), s.xq.capacity(), s.scales.capacity()),
            "capacities must be stable across steady-state resizes"
        );
    }
}
