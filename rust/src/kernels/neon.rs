//! Explicit NEON micro-kernels (aarch64), selected at runtime by
//! [`super::dispatch`].  NEON is baseline on aarch64, so availability
//! is a compile-target fact — but the fns stay `unsafe` +
//! `#[target_feature]` for symmetry with the AVX2 module and so the
//! dispatch layer is the single place that vouches for selection.
//!
//! # Bit-identity discipline (f32)
//!
//! Same contract as `kernels::x86` (see its module docs), adapted to
//! 128-bit registers: the scalar reference accumulates 8 f32 lanes per
//! k-chunk, so each output row keeps **two** `float32x4_t` accumulators
//! — `lo` holds scalar lanes 0–3, `hi` lanes 4–7 — accumulated with
//! unfused `vaddq_f32(acc, vmulq_f32(w, x))` (never `vmlaq_f32`, which
//! may lower to a fused `fmla`).  Reduction extracts all 8 lanes and
//! applies the exact `dot_f32` tree; tails are scalar.  The butterfly
//! rotation is the same unfused mul/sub/add per element.
//!
//! The i8 kernels use the natural NEON idiom (exact integer math needs
//! no lane discipline): `vmull_s8` widens 8×8-bit products to i16
//! (|p| ≤ 127² fits), `vpadalq_s16` pairwise-accumulates into i32
//! lanes, `vaddvq_s32` sums — exactly equal to [`super::dot_i8`]
//! within [`super::MAX_I8_DOT_LEN`].

#![cfg(target_arch = "aarch64")]

use std::arch::aarch64::*;

use super::{LANES, LANES_I8, NR};

/// Extract two 4-lane halves as scalar lanes 0–7 and reduce with the
/// exact `dot_f32` tree.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn reduce8(lo: float32x4_t, hi: float32x4_t) -> f32 {
    let mut a = [0.0f32; LANES];
    vst1q_f32(a.as_mut_ptr(), lo);
    vst1q_f32(a.as_mut_ptr().add(4), hi);
    (a[0] + a[1]) + (a[2] + a[3]) + ((a[4] + a[5]) + (a[6] + a[7]))
}

/// NEON `util::dot_f32` — bit-identical single-row dot (the GEMM row
/// tail).
#[target_feature(enable = "neon")]
pub unsafe fn dot1_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let nl = n - n % LANES;
    let mut acc_lo = vdupq_n_f32(0.0);
    let mut acc_hi = vdupq_n_f32(0.0);
    let mut k = 0;
    while k < nl {
        let a_lo = vld1q_f32(a.as_ptr().add(k));
        let a_hi = vld1q_f32(a.as_ptr().add(k + 4));
        let b_lo = vld1q_f32(b.as_ptr().add(k));
        let b_hi = vld1q_f32(b.as_ptr().add(k + 4));
        acc_lo = vaddq_f32(acc_lo, vmulq_f32(a_lo, b_lo));
        acc_hi = vaddq_f32(acc_hi, vmulq_f32(a_hi, b_hi));
        k += LANES;
    }
    let mut s = reduce8(acc_lo, acc_hi);
    for j in nl..n {
        s += a[j] * b[j];
    }
    s
}

/// NEON [`super::dot_nr_x1`]: `NR` rows × one token.
#[target_feature(enable = "neon")]
pub unsafe fn dot_nr_x1(w: &[f32], cols: usize, x: &[f32]) -> [f32; NR] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x.len(), cols);
    let nl = cols - cols % LANES;
    let mut acc_lo = [vdupq_n_f32(0.0); NR];
    let mut acc_hi = [vdupq_n_f32(0.0); NR];
    let mut k = 0;
    while k < nl {
        let x_lo = vld1q_f32(x.as_ptr().add(k));
        let x_hi = vld1q_f32(x.as_ptr().add(k + 4));
        for r in 0..NR {
            let w_lo = vld1q_f32(w.as_ptr().add(r * cols + k));
            let w_hi = vld1q_f32(w.as_ptr().add(r * cols + k + 4));
            acc_lo[r] = vaddq_f32(acc_lo[r], vmulq_f32(w_lo, x_lo));
            acc_hi[r] = vaddq_f32(acc_hi[r], vmulq_f32(w_hi, x_hi));
        }
        k += LANES;
    }
    let mut out = [0.0f32; NR];
    for r in 0..NR {
        let mut s = reduce8(acc_lo[r], acc_hi[r]);
        for j in nl..cols {
            s += w[r * cols + j] * x[j];
        }
        out[r] = s;
    }
    out
}

/// NEON [`super::dot_nr_x2`]: `NR` rows × two tokens sharing every
/// weight-chunk load.
#[target_feature(enable = "neon")]
pub unsafe fn dot_nr_x2(w: &[f32], cols: usize, x0: &[f32], x1: &[f32]) -> [[f32; NR]; 2] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x0.len(), cols);
    debug_assert_eq!(x1.len(), cols);
    let nl = cols - cols % LANES;
    let mut acc_lo = [[vdupq_n_f32(0.0); NR]; 2];
    let mut acc_hi = [[vdupq_n_f32(0.0); NR]; 2];
    let mut k = 0;
    while k < nl {
        let x0_lo = vld1q_f32(x0.as_ptr().add(k));
        let x0_hi = vld1q_f32(x0.as_ptr().add(k + 4));
        let x1_lo = vld1q_f32(x1.as_ptr().add(k));
        let x1_hi = vld1q_f32(x1.as_ptr().add(k + 4));
        for r in 0..NR {
            let w_lo = vld1q_f32(w.as_ptr().add(r * cols + k));
            let w_hi = vld1q_f32(w.as_ptr().add(r * cols + k + 4));
            acc_lo[0][r] = vaddq_f32(acc_lo[0][r], vmulq_f32(w_lo, x0_lo));
            acc_hi[0][r] = vaddq_f32(acc_hi[0][r], vmulq_f32(w_hi, x0_hi));
            acc_lo[1][r] = vaddq_f32(acc_lo[1][r], vmulq_f32(w_lo, x1_lo));
            acc_hi[1][r] = vaddq_f32(acc_hi[1][r], vmulq_f32(w_hi, x1_hi));
        }
        k += LANES;
    }
    let mut out = [[0.0f32; NR]; 2];
    for (m, xm) in [x0, x1].into_iter().enumerate() {
        for r in 0..NR {
            let mut s = reduce8(acc_lo[m][r], acc_hi[m][r]);
            for j in nl..cols {
                s += w[r * cols + j] * xm[j];
            }
            out[m][r] = s;
        }
    }
    out
}

/// Widen-multiply one 16-byte chunk and pairwise-accumulate into an
/// i32x4 accumulator (exact integer math).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn mac_i8_chunk(acc: int32x4_t, a: int8x16_t, b: int8x16_t) -> int32x4_t {
    let p_lo = vmull_s8(vget_low_s8(a), vget_low_s8(b));
    let p_hi = vmull_s8(vget_high_s8(a), vget_high_s8(b));
    vpadalq_s16(vpadalq_s16(acc, p_lo), p_hi)
}

/// NEON widening i8 dot — exactly equal to [`super::dot_i8`].
#[target_feature(enable = "neon")]
pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let nl = n - n % LANES_I8;
    let mut acc = vdupq_n_s32(0);
    let mut i = 0;
    while i < nl {
        let av = vld1q_s8(a.as_ptr().add(i));
        let bv = vld1q_s8(b.as_ptr().add(i));
        acc = mac_i8_chunk(acc, av, bv);
        i += LANES_I8;
    }
    let mut s = vaddvq_s32(acc);
    for j in nl..n {
        s += a[j] as i32 * b[j] as i32;
    }
    s
}

/// NEON [`super::dot_nr_x1_i8`]-equivalent: `NR` widening i8 dots
/// sharing each activation-chunk load.
#[target_feature(enable = "neon")]
pub unsafe fn dot_nr_x1_i8(w: &[i8], cols: usize, x: &[i8]) -> [i32; NR] {
    debug_assert_eq!(w.len(), NR * cols);
    debug_assert_eq!(x.len(), cols);
    let nl = cols - cols % LANES_I8;
    let mut acc = [vdupq_n_s32(0); NR];
    let mut k = 0;
    while k < nl {
        let xv = vld1q_s8(x.as_ptr().add(k));
        for r in 0..NR {
            let wv = vld1q_s8(w.as_ptr().add(r * cols + k));
            acc[r] = mac_i8_chunk(acc[r], wv, xv);
        }
        k += LANES_I8;
    }
    let mut out = [0i32; NR];
    for r in 0..NR {
        let mut s = vaddvq_s32(acc[r]);
        for j in nl..cols {
            s += w[r * cols + j] as i32 * x[j] as i32;
        }
        out[r] = s;
    }
    out
}

/// NEON butterfly pair rotation over `rb` contiguous lanes:
/// `lo' = c·lo − s·hi`, `hi' = s·lo + c·hi` — unfused mul/sub/add,
/// bit-identical per element to the scalar rotation.
#[target_feature(enable = "neon")]
pub unsafe fn rotate_lanes(c: f32, s: f32, lo: &mut [f32], hi: &mut [f32]) {
    debug_assert_eq!(lo.len(), hi.len());
    let n = lo.len();
    let vc = vdupq_n_f32(c);
    let vs = vdupq_n_f32(s);
    let mut k = 0;
    while k + 4 <= n {
        let va = vld1q_f32(lo.as_ptr().add(k));
        let vb = vld1q_f32(hi.as_ptr().add(k));
        let na = vsubq_f32(vmulq_f32(vc, va), vmulq_f32(vs, vb));
        let nb = vaddq_f32(vmulq_f32(vs, va), vmulq_f32(vc, vb));
        vst1q_f32(lo.as_mut_ptr().add(k), na);
        vst1q_f32(hi.as_mut_ptr().add(k), nb);
        k += 4;
    }
    while k < n {
        let (a, b) = (lo[k], hi[k]);
        lo[k] = c * a - s * b;
        hi[k] = s * a + c * b;
        k += 1;
    }
}
