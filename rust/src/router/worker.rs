//! Worker lifecycle: how the router launches, watches, and reaps the
//! `bmoe serve` processes behind it.
//!
//! The supervisor logic (health checks, restart with backoff, drain) is
//! written against two small traits so the whole router can be
//! exercised hermetically in unit tests: [`ProcessLauncher`] spawns
//! real `bmoe serve --port 0` child processes and discovers their
//! ephemeral port from the machine-parseable `[listening]` stdout line,
//! while the test-only [`InProcessLauncher`] boots the same TCP serving
//! stack as threads inside the test binary (over the deterministic
//! `CountBackend` fixture) — same wire protocol, same supervision
//! paths, no fork/exec.

use std::net::SocketAddr;
use std::time::Duration;

use anyhow::{Context, Result};

/// A launched worker the router can watch and stop.
pub trait WorkerHandle: Send {
    /// Is the worker still running?  (Liveness of the *process/thread*;
    /// responsiveness is probed separately via `STATS` polls.)
    fn is_alive(&mut self) -> bool;
    /// Block up to `timeout` for a voluntary exit; true when it exited.
    fn wait_exit(&mut self, timeout: Duration) -> bool;
    /// Forcibly terminate and reap the worker.
    fn kill(&mut self);
    /// OS pid for RSS accounting, when the worker is a real process.
    fn pid(&self) -> Option<u32>;
}

/// Launch worker `index`, returning the address it serves on plus its
/// lifecycle handle.  Called at startup and again on every restart.
pub trait WorkerLauncher: Send + Sync {
    fn launch(&self, index: usize) -> Result<(SocketAddr, Box<dyn WorkerHandle>)>;
}

/// Spawns real `bmoe serve` child processes: `<bin> serve <args>` with
/// stdout piped so the `[listening] <addr>` line can be parsed (the
/// workers run `--port 0`, so the kernel picks their ports and this
/// line is the only way to learn them).  Stderr is inherited — worker
/// logs interleave with the router's, prefixed by serve itself.
pub struct ProcessLauncher {
    /// Path to the `bmoe` binary (usually `std::env::current_exe()`).
    pub bin: std::path::PathBuf,
    /// Arguments after `serve` — model path, `--load mmap`, shape flags.
    /// `--port 0` is appended automatically.
    pub args: Vec<String>,
    /// How long to wait for the `[listening]` line before declaring the
    /// launch failed.
    pub startup_timeout: Duration,
}

impl ProcessLauncher {
    pub fn new(bin: std::path::PathBuf, args: Vec<String>) -> Self {
        ProcessLauncher {
            bin,
            args,
            startup_timeout: Duration::from_secs(30),
        }
    }
}

/// Parse the machine-parseable announce line: `[listening] 127.0.0.1:N`.
pub fn parse_listening_line(line: &str) -> Option<SocketAddr> {
    line.trim().strip_prefix("[listening] ")?.trim().parse().ok()
}

struct ProcessHandle {
    child: std::process::Child,
}

impl WorkerHandle for ProcessHandle {
    fn is_alive(&mut self) -> bool {
        matches!(self.child.try_wait(), Ok(None))
    }

    fn wait_exit(&mut self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match self.child.try_wait() {
                Ok(Some(_)) => return true,
                Err(_) => return true, // already reaped
                Ok(None) => {}
            }
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait(); // reap; never leave a zombie
    }

    fn pid(&self) -> Option<u32> {
        Some(self.child.id())
    }
}

impl WorkerLauncher for ProcessLauncher {
    fn launch(&self, index: usize) -> Result<(SocketAddr, Box<dyn WorkerHandle>)> {
        use std::io::BufRead;
        if crate::faults::spawn_failure(index) {
            anyhow::bail!("injected spawn failure for worker {index}");
        }
        let mut cmd = std::process::Command::new(&self.bin);
        cmd.arg("serve")
            .args(&self.args)
            .args(["--port", "0"])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::inherit());
        let mut child = cmd
            .spawn()
            .with_context(|| format!("spawn worker {index}: {}", self.bin.display()))?;
        let stdout = child.stdout.take().context("worker stdout")?;
        let (tx, rx) = std::sync::mpsc::channel::<SocketAddr>();
        // Reader thread: forward the announce line, then keep draining
        // stdout forever so the child can never block on a full pipe.
        std::thread::Builder::new()
            .name(format!("bmoe-worker{index}-stdout"))
            .spawn(move || {
                let reader = std::io::BufReader::new(stdout);
                for line in reader.lines() {
                    let Ok(line) = line else { break };
                    if let Some(addr) = parse_listening_line(&line) {
                        let _ = tx.send(addr);
                    }
                }
            })
            .context("spawn stdout reader")?;
        match rx.recv_timeout(self.startup_timeout) {
            Ok(addr) => Ok((addr, Box::new(ProcessHandle { child }))),
            Err(_) => {
                let mut h = ProcessHandle { child };
                h.kill();
                anyhow::bail!(
                    "worker {index} did not announce [listening] within {:?}",
                    self.startup_timeout
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// In-process worker for hermetic tests
// ---------------------------------------------------------------------------

/// Test-only launcher: each "worker" is a real TCP serving stack
/// (`serve_on` over a [`crate::testutil::CountBackend`] coordinator)
/// running as threads in this process.  Same wire protocol as a child
/// process, so placement, shedding, health, restart, and drain are all
/// testable without fork/exec.  `fail_next_launches` makes the next N
/// launch attempts error, for restart-backoff tests.
#[cfg(any(test, feature = "testutil"))]
pub struct InProcessLauncher {
    /// Per-step artificial delay of each worker's backend (slow workers
    /// make in-flight sessions observable).
    pub step_delay: Duration,
    /// `max_batch` of each worker's scheduler.
    pub max_batch: usize,
    pub fail_next_launches: std::sync::atomic::AtomicUsize,
    /// Make the next N launches announce their address and then die
    /// immediately — the crash-loop shape where a worker comes up just
    /// long enough to be marked Up before exiting (backoff-reset tests).
    die_next_launches: std::sync::atomic::AtomicUsize,
    /// Every launch ever made, for `launch_count` assertions.
    launches: std::sync::atomic::AtomicUsize,
}

#[cfg(any(test, feature = "testutil"))]
impl InProcessLauncher {
    pub fn new(step_delay: Duration, max_batch: usize) -> Self {
        InProcessLauncher {
            step_delay,
            max_batch,
            fail_next_launches: std::sync::atomic::AtomicUsize::new(0),
            die_next_launches: std::sync::atomic::AtomicUsize::new(0),
            launches: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn launch_count(&self) -> usize {
        self.launches.load(std::sync::atomic::Ordering::SeqCst)
    }

    /// Make the next `n` launch attempts fail (restart-backoff tests).
    pub fn fail_next(&self, n: usize) {
        self.fail_next_launches
            .store(n, std::sync::atomic::Ordering::SeqCst);
    }

    /// Make the next `n` launches succeed but die right after announcing
    /// — a crash-looping worker.  `usize::MAX` means "die forever".
    pub fn die_next(&self, n: usize) {
        self.die_next_launches
            .store(n, std::sync::atomic::Ordering::SeqCst);
    }
}

#[cfg(any(test, feature = "testutil"))]
pub struct InProcessHandle {
    coord: std::sync::Arc<crate::coordinator::Coordinator>,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

#[cfg(any(test, feature = "testutil"))]
impl WorkerHandle for InProcessHandle {
    fn is_alive(&mut self) -> bool {
        self.thread.as_ref().is_some_and(|t| !t.is_finished())
    }

    fn wait_exit(&mut self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.is_alive() {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        true
    }

    fn kill(&mut self) {
        // Abrupt from the clients' point of view: the coordinator aborts
        // every in-flight session (terminal events on the wire), the
        // accept loop stops, and the serve thread exits.
        self.coord.shutdown();
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    fn pid(&self) -> Option<u32> {
        None
    }
}

#[cfg(any(test, feature = "testutil"))]
impl WorkerLauncher for InProcessLauncher {
    fn launch(&self, index: usize) -> Result<(SocketAddr, Box<dyn WorkerHandle>)> {
        use std::sync::atomic::Ordering;
        self.launches.fetch_add(1, Ordering::SeqCst);
        if crate::faults::spawn_failure(index) {
            anyhow::bail!("injected spawn failure for worker {index}");
        }
        let failures = self.fail_next_launches.load(Ordering::SeqCst);
        if failures > 0 {
            self.fail_next_launches.store(failures - 1, Ordering::SeqCst);
            anyhow::bail!("injected launch failure for worker {index}");
        }
        let backend = crate::testutil::CountBackend::new().with_delay(self.step_delay);
        let backend = std::sync::Arc::new(crate::testutil::CountBackend {
            max_batch: self.max_batch,
            ..backend
        });
        let coord = crate::coordinator::Coordinator::start(
            backend,
            crate::coordinator::SchedulerConfig::new(self.max_batch, Duration::from_millis(1)),
        );
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (listener, addr) = crate::util::net::listen_reuse(0)?;
        let thread = {
            let coord = coord.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name(format!("bmoe-test-worker{index}"))
                .spawn(move || {
                    let _ = crate::coordinator::serve_on(listener, coord, stop);
                })?
        };
        let mut handle = InProcessHandle {
            coord,
            stop,
            thread: Some(thread),
        };
        let die = self.die_next_launches.load(Ordering::SeqCst);
        if die > 0 {
            if die != usize::MAX {
                self.die_next_launches.store(die - 1, Ordering::SeqCst);
            }
            // Announce-then-die: the caller gets a valid (addr, handle)
            // pair — exactly what a real crash-looping child looks like
            // from the supervisor's side — but the worker is already gone.
            handle.kill();
        }
        Ok((addr, Box::new(handle)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listening_line_parses_and_rejects() {
        assert_eq!(
            parse_listening_line("[listening] 127.0.0.1:41523"),
            Some("127.0.0.1:41523".parse().unwrap())
        );
        assert_eq!(
            parse_listening_line("  [listening] 127.0.0.1:7070\n"),
            Some("127.0.0.1:7070".parse().unwrap())
        );
        assert_eq!(parse_listening_line("[serve] listening on 127.0.0.1:7070"), None);
        assert_eq!(parse_listening_line("[listening] nonsense"), None);
    }

    #[test]
    fn in_process_worker_serves_and_dies_on_kill() {
        use std::io::{BufRead, BufReader, Write};
        let launcher = InProcessLauncher::new(Duration::ZERO, 4);
        let (addr, mut handle) = launcher.launch(0).unwrap();
        assert!(handle.is_alive());
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 2 0 0 0 -1 1 2 3").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut lines = Vec::new();
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let done = line.starts_with("END");
            lines.push(line);
            if done {
                break;
            }
        }
        assert_eq!(lines.len(), 3, "2 TOK + END: {lines:?}");
        handle.kill();
        assert!(!handle.is_alive());
        assert!(handle.wait_exit(Duration::from_millis(100)));
        assert!(
            std::net::TcpStream::connect(addr).is_err()
                || std::io::Read::read(
                    &mut std::net::TcpStream::connect(addr).unwrap(),
                    &mut [0u8; 1]
                )
                .map(|n| n == 0)
                .unwrap_or(true),
            "killed worker must stop serving"
        );
    }

    #[test]
    fn injected_launch_failures_consume_then_recover() {
        let launcher = InProcessLauncher::new(Duration::ZERO, 4);
        launcher.fail_next(2);
        assert!(launcher.launch(0).is_err());
        assert!(launcher.launch(0).is_err());
        let (_, mut h) = launcher.launch(0).unwrap();
        assert_eq!(launcher.launch_count(), 3);
        h.kill();
    }
}
