//! Worker health: periodic `STATS` polling, crash detection, and
//! restart with exponential backoff.
//!
//! Per-worker state machine (state lives in [`super::balance::Fleet`]):
//!
//! ```text
//!            launch ok                       poll ok
//!   ┌──────────────────────►  Up  ───────────────────────┐
//!   │                          │                         │
//!   │    process dead, or      │ 2 consecutive           │
//!   │    STATS failed twice    ▼ failures / not alive    │
//!  Down{next_attempt}  ◄───────┘                         │
//!   │         ▲                                          │
//!   │         │ relaunch failed (backoff doubles)        │
//!   └─────────┴──── backoff expired: relaunch ───────────┘
//! ```
//!
//! A worker that dies is detected two ways: its [`WorkerHandle`] stops
//! reporting alive (immediate), or `STATS` polls fail twice in a row
//! (covers a live-but-wedged process).  After every sweep the admission
//! capacity is recomputed as `healthy x sessions_per_worker`, so a
//! degraded fleet admits less instead of queueing blindly.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs;

use super::admission::Admission;
use super::balance::Fleet;
use super::worker::{WorkerHandle, WorkerLauncher};

/// Consecutive `STATS` failures before a live process is declared dead.
pub const POLL_FAILURE_LIMIT: u32 = 2;

/// Everything one health sweep needs; shared with the router front-end.
pub struct HealthCtx {
    pub fleet: Arc<Fleet>,
    pub admission: Arc<Admission>,
    pub launcher: Arc<dyn WorkerLauncher>,
    /// Slot-indexed lifecycle handles; `None` while a slot is down.
    pub handles: Mutex<Vec<Option<Box<dyn WorkerHandle>>>>,
    pub sessions_per_worker: usize,
    pub poll_timeout: Duration,
}

/// Poll one worker's `STATS` line; returns `(queue_depth, inflight)`.
pub fn poll_stats(addr: SocketAddr, timeout: Duration) -> Result<(u64, u64)> {
    let mut stream = TcpStream::connect_timeout(&addr, timeout).context("connect")?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    writeln!(stream, "STATS").context("send STATS")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("read STATS reply")?;
    anyhow::ensure!(line.starts_with("STATS "), "unexpected reply: {line:?}");
    let field = |key: &str| -> Result<u64> {
        line.split_whitespace()
            .find_map(|kv| kv.strip_prefix(key)?.strip_prefix('=')?.parse().ok())
            .with_context(|| format!("STATS line missing {key}: {line:?}"))
    };
    Ok((field("queue_depth")?, field("inflight")?))
}

/// One supervision sweep: poll every Up worker, reap the dead, relaunch
/// the due, then recompute admission capacity.  Factored out of the
/// loop so tests can drive sweeps deterministically.
pub fn health_sweep(ctx: &HealthCtx) {
    let n = ctx.fleet.len();
    for idx in 0..n {
        let Some(addr) = ctx.fleet.addr(idx) else { continue };
        // liveness first: a dead process needs no poll to be declared
        let alive = {
            let mut handles = ctx.handles.lock().unwrap();
            handles[idx].as_mut().map(|h| h.is_alive()).unwrap_or(false)
        };
        if !alive {
            declare_down(ctx, idx, "process exited");
            continue;
        }
        match poll_stats(addr, ctx.poll_timeout) {
            Ok((queue_depth, inflight)) => ctx.fleet.record_poll(idx, queue_depth, inflight),
            Err(e) => {
                let failures = ctx.fleet.record_poll_failure(idx);
                if failures >= POLL_FAILURE_LIMIT {
                    declare_down(ctx, idx, &format!("STATS failed {failures}x: {e:#}"));
                }
            }
        }
    }
    for idx in ctx.fleet.due_for_restart(Instant::now()) {
        match ctx.launcher.launch(idx) {
            Ok((addr, handle)) => {
                ctx.handles.lock().unwrap()[idx] = Some(handle);
                ctx.fleet.mark_up(idx, addr, false);
                obs::log("route", &format!("worker {idx} restarted on {addr}"));
                obs::Event::new("worker_restart")
                    .u64("worker", idx as u64)
                    .str("addr", addr.to_string())
                    .emit();
            }
            Err(e) => {
                let backoff = ctx.fleet.mark_down(idx);
                obs::log(
                    "route",
                    &format!("worker {idx} relaunch failed ({e:#}); retry in {backoff:?}"),
                );
                obs::Event::new("worker_spawn_failed")
                    .u64("worker", idx as u64)
                    .u64("backoff_ms", backoff.as_millis() as u64)
                    .str("error", format!("{e:#}"))
                    .emit();
            }
        }
    }
    ctx.admission
        .set_capacity(ctx.fleet.healthy() * ctx.sessions_per_worker);
}

fn declare_down(ctx: &HealthCtx, idx: usize, why: &str) {
    // reap whatever is left of the worker before scheduling the retry
    if let Some(mut h) = ctx.handles.lock().unwrap()[idx].take() {
        h.kill();
    }
    let backoff = ctx.fleet.mark_down(idx);
    obs::log("route", &format!("worker {idx} down ({why}); restart in {backoff:?}"));
    obs::Event::new("worker_down")
        .u64("worker", idx as u64)
        .u64("backoff_ms", backoff.as_millis() as u64)
        .str("why", why)
        .emit();
    // a worker death is one of the flight recorder's dump triggers
    // (DESIGN.md §7): preserve the recent event window for post-mortems
    obs::flight::dump("worker down");
}

/// Run sweeps every `interval` until `stop`.
pub fn health_loop(ctx: Arc<HealthCtx>, interval: Duration, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        health_sweep(&ctx);
        // sleep in small slices so shutdown isn't delayed by `interval`
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline && !stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(10).min(interval));
        }
    }
}
