//! Admission control: bounded queueing, load shedding, per-client
//! fairness, and the drain barrier.
//!
//! The contract the router's wire behaviour is built on:
//!
//! * **Accepted means completed.**  Once `acquire` returns `Admitted`
//!   the session runs to a terminal event, even if a drain begins while
//!   it is queued — drain waits for accepted sessions, it never aborts
//!   them.
//! * **Never a stall.**  Every other outcome is an immediate, explicit
//!   terminal (`END shed` / `END shutdown` on the wire): the queue is
//!   bounded, per-client counts are capped, and a queued waiter that
//!   outlives `queue_timeout` (e.g. the whole fleet died under it) is
//!   shed rather than left hanging.
//!
//! Capacity is `healthy_workers x sessions_per_worker`, updated by the
//! health thread as workers die and restart, so admission tightens
//! automatically when the fleet degrades.

use std::collections::HashMap;
use std::net::IpAddr;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Outcome of [`Admission::acquire`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ticket {
    /// Run the session now; the caller must call [`Admission::release`].
    Admitted,
    /// Over capacity / queue full / client cap / wait timed out — reply
    /// `END shed` immediately.
    Shed,
    /// The router is draining; reply `END shutdown` immediately.
    Draining,
}

struct State {
    /// `healthy_workers * sessions_per_worker`; 0 while the fleet is
    /// entirely down (everything queues or sheds).
    capacity: usize,
    /// Admitted sessions not yet released.
    inflight: usize,
    /// Waiters blocked in `acquire`.
    queued: usize,
    /// Admitted + queued per client IP (the fairness denominator).
    per_client: HashMap<IpAddr, usize>,
    draining: bool,
}

/// Shared admission gate (proxy threads + health thread + drain).
pub struct Admission {
    state: Mutex<State>,
    cv: Condvar,
    max_queue: usize,
    /// Max concurrent sessions per client IP; 0 = unlimited.
    client_cap: usize,
    /// Upper bound on time a waiter may sit queued before being shed.
    queue_timeout: Duration,
}

impl Admission {
    pub fn new(
        capacity: usize,
        max_queue: usize,
        client_cap: usize,
        queue_timeout: Duration,
    ) -> Admission {
        Admission {
            state: Mutex::new(State {
                capacity,
                inflight: 0,
                queued: 0,
                per_client: HashMap::new(),
                draining: false,
            }),
            cv: Condvar::new(),
            max_queue,
            client_cap,
            queue_timeout,
        }
    }

    /// Try to start a session for `client`.  Blocks (bounded) while
    /// queued; every return is prompt-or-terminal per the module
    /// contract.
    pub fn acquire(&self, client: IpAddr) -> Ticket {
        let mut st = self.state.lock().unwrap();
        if st.draining {
            return Ticket::Draining;
        }
        let held = *st.per_client.get(&client).unwrap_or(&0);
        if self.client_cap > 0 && held >= self.client_cap {
            return Ticket::Shed;
        }
        if st.inflight < st.capacity {
            st.inflight += 1;
            *st.per_client.entry(client).or_insert(0) += 1;
            return Ticket::Admitted;
        }
        if st.queued >= self.max_queue {
            return Ticket::Shed;
        }
        // Queue (this also counts against the client's cap, so one
        // client cannot fill the whole queue past its share).
        st.queued += 1;
        *st.per_client.entry(client).or_insert(0) += 1;
        let deadline = Instant::now() + self.queue_timeout;
        loop {
            if st.inflight < st.capacity {
                st.queued -= 1;
                st.inflight += 1;
                self.cv.notify_all();
                return Ticket::Admitted;
            }
            let now = Instant::now();
            if now >= deadline {
                // the bounded-stall guarantee: give up explicitly
                st.queued -= 1;
                Self::dec_client(&mut st, client);
                self.cv.notify_all();
                return Ticket::Shed;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// An admitted session reached its terminal outcome.
    pub fn release(&self, client: IpAddr) {
        let mut st = self.state.lock().unwrap();
        st.inflight = st.inflight.saturating_sub(1);
        Self::dec_client(&mut st, client);
        self.cv.notify_all();
    }

    fn dec_client(st: &mut State, client: IpAddr) {
        if let Some(n) = st.per_client.get_mut(&client) {
            *n -= 1;
            if *n == 0 {
                st.per_client.remove(&client);
            }
        }
    }

    /// Health thread: capacity follows the healthy-worker count.
    pub fn set_capacity(&self, capacity: usize) {
        let mut st = self.state.lock().unwrap();
        st.capacity = capacity;
        self.cv.notify_all();
    }

    /// Stop admitting new sessions; queued (accepted) waiters still run.
    pub fn begin_drain(&self) {
        let mut st = self.state.lock().unwrap();
        st.draining = true;
        self.cv.notify_all();
    }

    /// Block until every admitted and queued session has resolved, or
    /// `timeout`.  True = fully idle (the loss-free drain succeeded).
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.inflight == 0 && st.queued == 0 {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// `(inflight, queued, capacity, draining)` for STATS.
    pub fn counts(&self) -> (usize, usize, usize, bool) {
        let st = self.state.lock().unwrap();
        (st.inflight, st.queued, st.capacity, st.draining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ip(last: u8) -> IpAddr {
        IpAddr::from([127, 0, 0, last])
    }

    #[test]
    fn admits_to_capacity_then_queues_then_sheds() {
        let a = Admission::new(2, 1, 0, Duration::from_millis(50));
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        // capacity full, queue depth 1: the third acquire would block,
        // so probe from a thread while the fourth is shed immediately
        let a = Arc::new(a);
        let a2 = a.clone();
        let waiter = std::thread::spawn(move || a2.acquire(ip(1)));
        // wait until the waiter is actually queued
        while a.counts().1 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(a.acquire(ip(1)), Ticket::Shed, "queue is bounded");
        a.release(ip(1));
        assert_eq!(waiter.join().unwrap(), Ticket::Admitted);
    }

    #[test]
    fn queued_waiter_times_out_as_shed_not_stall() {
        let a = Admission::new(1, 4, 0, Duration::from_millis(30));
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        let t0 = Instant::now();
        assert_eq!(a.acquire(ip(1)), Ticket::Shed);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert!(t0.elapsed() < Duration::from_secs(5), "bounded, not a stall");
        // the timed-out waiter must not leak queue or client accounting
        let (inflight, queued, _, _) = a.counts();
        assert_eq!((inflight, queued), (1, 0));
    }

    #[test]
    fn per_client_cap_sheds_the_greedy_client_only() {
        let a = Admission::new(8, 8, 2, Duration::from_millis(50));
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        assert_eq!(a.acquire(ip(1)), Ticket::Shed, "client 1 hit its cap");
        // a different client is unaffected
        assert_eq!(a.acquire(ip(2)), Ticket::Admitted);
        // and releasing frees the greedy client's share
        a.release(ip(1));
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
    }

    #[test]
    fn drain_rejects_new_but_finishes_queued() {
        let a = Arc::new(Admission::new(1, 4, 0, Duration::from_secs(10)));
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        let a2 = a.clone();
        let queued = std::thread::spawn(move || a2.acquire(ip(2)));
        while a.counts().1 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        a.begin_drain();
        // new arrivals get the draining terminal...
        assert_eq!(a.acquire(ip(3)), Ticket::Draining);
        // ...but the already-queued waiter is still admitted once the
        // running session releases (accepted means completed)
        a.release(ip(1));
        assert_eq!(queued.join().unwrap(), Ticket::Admitted);
        // idle only after that one also finishes
        assert!(!a.wait_idle(Duration::from_millis(20)));
        a.release(ip(2));
        assert!(a.wait_idle(Duration::from_secs(1)));
    }

    #[test]
    fn capacity_drop_gates_new_admissions() {
        let a = Admission::new(2, 2, 0, Duration::from_millis(20));
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
        a.set_capacity(0); // the whole fleet just died
        assert_eq!(a.acquire(ip(1)), Ticket::Shed, "no capacity => bounded wait, then shed");
        a.set_capacity(2);
        assert_eq!(a.acquire(ip(1)), Ticket::Admitted);
    }
}
