//! Client-facing protocol handling and the per-session relay.
//!
//! The router speaks the exact `GEN`/`TOK`/`END` line protocol the
//! workers do, so existing clients (`bench-client`, the CI bash smoke)
//! point at the router unchanged.  Each admitted `GEN` opens a fresh
//! TCP connection to its placed worker and relays lines verbatim —
//! session-granular proxying, no re-framing, so streams through the
//! router are byte-identical to direct streams (pinned by
//! `rust/tests/serving.rs`).
//!
//! Router-specific terminals, all explicit and immediate:
//!
//! * `END shed 0 <us> 0` — admission shed the session (queue full,
//!   client cap, or a bounded queue wait expired).
//! * `END shutdown 0 <us> 0` — the router is draining.
//!
//! (The trailing field mirrors the worker END line's truncated count —
//! always 0 here, since a shed session never reached a model window.)
//! * `ERR worker lost` — the placed worker died mid-stream; the session
//!   is over (generation state died with the worker) but the client got
//!   a terminal event, not a hung stream.
//!
//! Control verbs: `STATS` (one key=value line, format unchanged),
//! `DRAIN` (loss-free shutdown), and `METRICS` — the fleet-aggregated
//! Prometheus exposition from [`Router::metrics_text`], framed by a
//! trailing `# EOF` line (DESIGN.md §7).

use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::parse_gen_line;
use crate::obs;

use super::admission::Ticket;
use super::Router;

/// Worker-side per-event read budget while relaying (generous: a step
/// may warm caches on first use, mirroring the server's own timeout).
const RELAY_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// What became of one relayed session.
#[derive(Debug, PartialEq, Eq)]
pub(super) enum RelayOutcome {
    /// Worker delivered a terminal line (`END` or `ERR`).
    Done { tokens: u64 },
    /// Worker connection failed or went EOF before a terminal line.
    WorkerLost { tokens: u64 },
    /// The client stopped accepting writes; session abandoned (dropping
    /// the worker connection cancels the session worker-side).
    ClientGone,
}

/// Relay one `GEN` line to `addr`, forwarding every reply line to
/// `client` until the worker's terminal line.
pub(super) fn relay_session(
    client: &mut TcpStream,
    addr: SocketAddr,
    gen_line: &str,
    connect_timeout: Duration,
) -> RelayOutcome {
    let worker = (|| -> Result<TcpStream> {
        let s = TcpStream::connect_timeout(&addr, connect_timeout)?;
        s.set_read_timeout(Some(RELAY_READ_TIMEOUT))?;
        s.set_nodelay(true).ok();
        Ok(s)
    })();
    let Ok(mut worker) = worker else {
        return RelayOutcome::WorkerLost { tokens: 0 };
    };
    if writeln!(worker, "{gen_line}").is_err() {
        return RelayOutcome::WorkerLost { tokens: 0 };
    }
    let mut reader = BufReader::new(worker);
    let mut tokens = 0u64;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return RelayOutcome::WorkerLost { tokens },
            Ok(_) => {}
        }
        if client.write_all(line.as_bytes()).is_err() {
            return RelayOutcome::ClientGone;
        }
        if line.starts_with("TOK ") {
            tokens += 1;
        } else if line.starts_with("END ") || line.starts_with("ERR") {
            return RelayOutcome::Done { tokens };
        }
        // anything else (future protocol lines) is forwarded verbatim
    }
}

/// Run one admitted-or-rejected session for `client_ip`.
pub(super) fn proxy_session(
    router: &Router,
    writer: &mut TcpStream,
    gen_line: &str,
    client_ip: IpAddr,
) -> Result<()> {
    let t0 = Instant::now();
    match router.admission.acquire(client_ip) {
        Ticket::Shed => {
            router.stats.shed.fetch_add(1, Ordering::Relaxed);
            obs::Event::new("session_shed")
                .str("client", client_ip.to_string())
                .emit();
            writeln!(writer, "END shed 0 {} 0", t0.elapsed().as_micros())?;
            return Ok(());
        }
        Ticket::Draining => {
            writeln!(writer, "END shutdown 0 {} 0", t0.elapsed().as_micros())?;
            return Ok(());
        }
        Ticket::Admitted => {}
    }
    let Some((idx, addr)) = router.fleet.place() else {
        // capacity said yes but every worker died in between — terminal
        // error, never a hang
        router.admission.release(client_ip);
        router.stats.worker_lost.fetch_add(1, Ordering::Relaxed);
        obs::Event::new("session_error")
            .str("error", "no healthy worker")
            .emit();
        obs::flight::dump("no healthy worker");
        writeln!(writer, "ERR no healthy worker")?;
        return Ok(());
    };
    let outcome = relay_session(writer, addr, gen_line, router.cfg.connect_timeout);
    let (tokens, client_gone) = match outcome {
        RelayOutcome::Done { tokens } => {
            router.stats.routed.fetch_add(1, Ordering::Relaxed);
            (tokens, false)
        }
        RelayOutcome::WorkerLost { tokens } => {
            router.stats.worker_lost.fetch_add(1, Ordering::Relaxed);
            obs::Event::new("session_error")
                .u64("worker", idx as u64)
                .u64("tokens", tokens)
                .str("error", "worker lost")
                .emit();
            // a protocol ERR is a flight-recorder dump trigger
            // (DESIGN.md §7): the ring holds the events leading here
            obs::flight::dump("worker lost");
            // terminal event for the client; the health thread will
            // notice the corpse and schedule the restart
            let _ = writeln!(writer, "ERR worker lost");
            (tokens, false)
        }
        RelayOutcome::ClientGone => (0, true),
    };
    router.stats.tokens.fetch_add(tokens, Ordering::Relaxed);
    router.fleet.complete(idx, tokens);
    router.admission.release(client_ip);
    if client_gone {
        anyhow::bail!("client disconnected mid-stream");
    }
    Ok(())
}

/// One client connection: commands and sessions until QUIT/EOF/stop.
/// Mirrors the worker server's loop — stop-aware reads so a drain is
/// never wedged by an idle client, one `ERR` then close on garbage.
pub(super) fn handle_client(stream: TcpStream, router: Arc<Router>) -> Result<()> {
    let client_ip = stream
        .peer_addr()
        .map(|a| a.ip())
        .unwrap_or_else(|_| IpAddr::from([127, 0, 0, 1]));
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if router.stopping() {
            // drain/stop between sessions: close rather than accept more
            return Ok(());
        }
        // read one line, waking on the timeout to observe stop/drain;
        // bytes read before a timeout stay in `line` (read_until's
        // contract), so slow lines are never truncated
        line.clear();
        let eof = loop {
            match reader.read_line(&mut line) {
                Ok(0) => break true,
                Ok(_) => break false,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if router.stopping() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        };
        if eof && line.trim().is_empty() {
            return Ok(());
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "QUIT" {
            return Ok(());
        }
        if line == "STATS" {
            writeln!(writer, "{}", router.stats_line())?;
            continue;
        }
        if line == "METRICS" {
            // fleet-aggregated Prometheus exposition, framed by `# EOF`
            write!(writer, "{}", router.metrics_text())?;
            writer.flush()?;
            continue;
        }
        if line == "DRAIN" {
            writeln!(writer, "OK draining")?;
            router.request_drain();
            return Ok(());
        }
        // validate before consuming admission or a worker slot: garbage
        // must not count against capacity or the client's fairness cap
        if let Err(e) = parse_gen_line(line) {
            writeln!(writer, "ERR bad request: {e:#}")?;
            return Ok(());
        }
        proxy_session(&router, &mut writer, line, client_ip)?;
    }
}
