//! Client-facing protocol handling, the per-session relay, and
//! deterministic mid-stream failover.
//!
//! The router speaks the exact `GEN`/`TOK`/`END` line protocol the
//! workers do, so existing clients (`bench-client`, the CI bash smoke)
//! point at the router unchanged.  Each admitted `GEN` opens a fresh
//! TCP connection to its placed worker and relays lines verbatim —
//! session-granular proxying, no re-framing, so streams through the
//! router are byte-identical to direct streams (pinned by
//! `rust/tests/serving.rs`).
//!
//! **Failover** (DESIGN.md §8): when the placed worker dies mid-stream
//! (connection EOF, read timeout, or a worker-side `END shutdown`
//! abort), the session is *not* over.  The router holds the full seeded
//! `GEN` line and the engine's determinism contract pins bit-identical
//! token streams across workers and loaders (`rust/tests/
//! determinism.rs`), so the relay re-places the session on a healthy
//! worker, replays the same `GEN` line, verifies the already-delivered
//! token prefix byte-for-byte against the recorded payloads, suppresses
//! the duplicate prefix, and resumes the client's stream seamlessly.
//! Replays are bounded by `--failover-retries`; only when they are
//! exhausted (or no replacement worker appears) does the client see the
//! terminal `ERR worker lost`.  A replay whose prefix does not match is
//! terminated with `ERR replay diverged` — the client must never
//! silently receive wrong bits.
//!
//! Router-specific terminals, all explicit and immediate:
//!
//! * `END shed 0 <us> 0` — admission shed the session (queue full,
//!   client cap, or a bounded queue wait expired).
//! * `END shutdown 0 <us> 0` — the router is draining.
//!
//! (The trailing field mirrors the worker END line's truncated count —
//! always 0 here, since a shed session never reached a model window.)
//! * `ERR worker lost` — the placed worker died mid-stream **and**
//!   failover could not complete the session (retries exhausted, or no
//!   healthy replacement within the failover window).  Still a terminal
//!   event, never a hung stream.
//! * `ERR replay diverged` — a failover replay produced a token prefix
//!   that differs from what the client already received; the session is
//!   aborted rather than continued with wrong bits.
//!
//! Control verbs: `STATS` (one key=value line, format unchanged),
//! `DRAIN` (loss-free shutdown), and `METRICS` — the fleet-aggregated
//! Prometheus exposition from [`Router::metrics_text`], framed by a
//! trailing `# EOF` line (DESIGN.md §7).

use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::parse_gen_line;
use crate::faults;
use crate::obs;

use super::admission::Ticket;
use super::Router;

/// Timeouts one relay attempt runs under.
pub(super) struct RelayOpts {
    /// Per-worker connect timeout when starting (or failing over) a relay.
    pub connect_timeout: Duration,
    /// Worker-side per-event read budget (generous: a step may warm
    /// caches on first use, mirroring the server's own timeout).  A
    /// stalled worker trips this and enters the failover path.
    pub read_timeout: Duration,
    /// Client-side write budget: a client that stops reading its socket
    /// cancels the session like a disconnect, instead of pinning this
    /// relay thread, its worker connection, and a batch slot forever.
    pub write_timeout: Duration,
}

/// What became of one relay attempt.
#[derive(Debug, PartialEq, Eq)]
pub(super) enum RelayOutcome {
    /// Worker delivered this session's terminal line (`END`/`ERR`).
    Done,
    /// Worker connection failed, timed out, went EOF, or the worker
    /// aborted the session with a mid-stream `END shutdown` — the
    /// stream is incomplete and a replay elsewhere can finish it.
    WorkerLost,
    /// The client stopped accepting writes; session abandoned (dropping
    /// the worker connection cancels the session worker-side).
    ClientGone,
    /// A failover replay's token prefix differs from what the client
    /// already received — determinism was violated somewhere, and the
    /// session must die loudly rather than resume with wrong bits.
    ReplayDiverged { at: usize, want: String, got: String },
}

/// Relay one `GEN` line to `addr`, forwarding reply lines to `client`
/// until the worker's terminal line.
///
/// `delivered` carries the payloads of every `TOK` line already
/// forwarded to the client by earlier attempts of this session (see
/// [`tok_payload`]).  The first `delivered.len()` tokens from this
/// worker are verified against it and suppressed instead of forwarded —
/// the failover replay — and each newly forwarded token's payload is
/// appended, so the caller can retry with a longer verified prefix.
/// `on_token` fires after each *newly* forwarded token with the
/// cumulative delivered count (the chaos kill-after-N injection point).
pub(super) fn relay_session(
    client: &mut TcpStream,
    addr: SocketAddr,
    gen_line: &str,
    opts: &RelayOpts,
    delivered: &mut Vec<String>,
    mut on_token: impl FnMut(u64),
) -> RelayOutcome {
    let worker = (|| -> Result<TcpStream> {
        let s = TcpStream::connect_timeout(&addr, opts.connect_timeout)?;
        s.set_read_timeout(Some(opts.read_timeout))?;
        s.set_nodelay(true).ok();
        Ok(s)
    })();
    let Ok(mut worker) = worker else {
        return RelayOutcome::WorkerLost;
    };
    if writeln!(worker, "{gen_line}").is_err() {
        return RelayOutcome::WorkerLost;
    }
    client.set_write_timeout(Some(opts.write_timeout)).ok();
    let mut reader = BufReader::new(worker);
    // prefix tokens verified + suppressed so far in THIS attempt
    let mut replayed = 0usize;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return RelayOutcome::WorkerLost,
            Ok(_) => {}
        }
        if let Some(rest) = line.strip_prefix("TOK ") {
            let payload = tok_payload(rest);
            if replayed < delivered.len() {
                // failover replay: verify byte-for-byte, don't re-send
                if payload != delivered[replayed] {
                    return RelayOutcome::ReplayDiverged {
                        at: replayed,
                        want: delivered[replayed].clone(),
                        got: payload,
                    };
                }
                replayed += 1;
                continue;
            }
            if client.write_all(line.as_bytes()).is_err() {
                return RelayOutcome::ClientGone;
            }
            delivered.push(payload);
            on_token(delivered.len() as u64);
        } else if line.starts_with("END shutdown") {
            // the worker aborted the session on its own kill/drain path;
            // the stream is incomplete — same as losing the connection.
            // (A router-drain never SHUTDOWNs a worker with sessions in
            // flight, so this is always a worker dying under us.)
            return RelayOutcome::WorkerLost;
        } else if line.starts_with("ERR") && replayed < delivered.len() {
            // a worker-side error before the prefix was reproduced is a
            // transient failure of THIS worker (the original accepted
            // and streamed the same request) — retry elsewhere
            return RelayOutcome::WorkerLost;
        } else if line.starts_with("END ") {
            if replayed < delivered.len() {
                // terminal before the already-delivered prefix was
                // reproduced: the replay fell short — wrong bits by
                // omission, never forwarded silently
                return RelayOutcome::ReplayDiverged {
                    at: replayed,
                    want: delivered[replayed].clone(),
                    got: line.trim().to_string(),
                };
            }
            if client.write_all(line.as_bytes()).is_err() {
                return RelayOutcome::ClientGone;
            }
            return RelayOutcome::Done;
        } else if line.starts_with("ERR") {
            if client.write_all(line.as_bytes()).is_err() {
                return RelayOutcome::ClientGone;
            }
            return RelayOutcome::Done;
        } else {
            // anything else (future protocol lines) is forwarded verbatim
            if client.write_all(line.as_bytes()).is_err() {
                return RelayOutcome::ClientGone;
            }
        }
    }
}

/// The deterministic payload of a `TOK` line: `<index> <token>`.  The
/// third field (per-token latency µs) varies run to run by nature, so
/// "byte-for-byte" prefix verification applies to the fields the
/// determinism contract actually pins.
fn tok_payload(rest: &str) -> String {
    let mut it = rest.split_whitespace();
    match (it.next(), it.next()) {
        (Some(i), Some(t)) => format!("{i} {t}"),
        _ => rest.trim().to_string(),
    }
}

/// Wait (bounded) for a healthy worker to place a failover replay on.
/// Polls rather than subscribes: the health loop's relaunch cadence is
/// tens of milliseconds, and failover is rare.
fn wait_for_replacement(router: &Router) -> Option<(usize, SocketAddr)> {
    let deadline = Instant::now() + router.cfg.failover_wait;
    loop {
        if let Some(p) = router.fleet.place() {
            return Some(p);
        }
        if Instant::now() >= deadline || router.stopping() {
            return None;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Run one admitted-or-rejected session for `client_ip`.
pub(super) fn proxy_session(
    router: &Router,
    writer: &mut TcpStream,
    gen_line: &str,
    client_ip: IpAddr,
) -> Result<()> {
    let t0 = Instant::now();
    match router.admission.acquire(client_ip) {
        Ticket::Shed => {
            router.stats.shed.fetch_add(1, Ordering::Relaxed);
            obs::Event::new("session_shed")
                .str("client", client_ip.to_string())
                .emit();
            writeln!(writer, "END shed 0 {} 0", t0.elapsed().as_micros())?;
            return Ok(());
        }
        Ticket::Draining => {
            writeln!(writer, "END shutdown 0 {} 0", t0.elapsed().as_micros())?;
            return Ok(());
        }
        Ticket::Admitted => {}
    }
    let Some((mut idx, mut addr)) = router.fleet.place() else {
        // capacity said yes but every worker died in between — terminal
        // error, never a hang
        router.admission.release(client_ip);
        router.stats.worker_lost.fetch_add(1, Ordering::Relaxed);
        obs::Event::new("session_error")
            .str("error", "no healthy worker")
            .emit();
        obs::flight::dump("no healthy worker");
        writeln!(writer, "ERR no healthy worker")?;
        return Ok(());
    };
    let opts = RelayOpts {
        connect_timeout: router.cfg.connect_timeout,
        read_timeout: router.cfg.relay_read_timeout,
        write_timeout: router.cfg.client_write_timeout,
    };
    // every TOK payload the client has received, across all attempts
    let mut delivered: Vec<String> = Vec::new();
    // chaos injection: SIGKILL the placed worker after N relayed tokens
    let kill_after = faults::session_kill_after();
    let mut kill_fired = false;
    let mut failovers = 0u32;
    let mut client_gone = false;
    loop {
        let before = delivered.len();
        let cur_idx = idx;
        let outcome = relay_session(writer, addr, gen_line, &opts, &mut delivered, |n| {
            if !kill_fired && kill_after == Some(n) {
                kill_fired = true;
                router.kill_worker(cur_idx);
            }
        });
        let new_tokens = (delivered.len() - before) as u64;
        router.stats.tokens.fetch_add(new_tokens, Ordering::Relaxed);
        // pairs with this attempt's place()/wait_for_replacement();
        // per-worker token credit is what the worker newly streamed to
        // the client (suppressed replay prefixes are not client tokens)
        router.fleet.complete(idx, new_tokens);
        match outcome {
            RelayOutcome::Done => {
                router.stats.routed.fetch_add(1, Ordering::Relaxed);
                break;
            }
            RelayOutcome::ClientGone => {
                client_gone = true;
                break;
            }
            RelayOutcome::ReplayDiverged { at, want, got } => {
                router.stats.replay_diverged.fetch_add(1, Ordering::Relaxed);
                obs::Event::new("session_error")
                    .u64("worker", idx as u64)
                    .u64("at", at as u64)
                    .str("want", want)
                    .str("got", got)
                    .str("error", "replay diverged")
                    .emit();
                obs::flight::dump("replay diverged");
                let _ = writeln!(writer, "ERR replay diverged");
                break;
            }
            RelayOutcome::WorkerLost => {
                // declare the corpse down right now (addr-guarded) so
                // the replacement placement can't land back on it
                router.note_worker_lost(idx, addr);
                if failovers >= router.cfg.failover_retries {
                    fail_session(router, writer, idx, delivered.len(), "retries exhausted");
                    break;
                }
                let Some((ni, na)) = wait_for_replacement(router) else {
                    fail_session(router, writer, idx, delivered.len(), "no replacement worker");
                    break;
                };
                failovers += 1;
                router.stats.failovers.fetch_add(1, Ordering::Relaxed);
                router
                    .stats
                    .replayed_tokens
                    .lock()
                    .unwrap()
                    .record(delivered.len() as f64);
                obs::Event::new("session_failover")
                    .u64("from", idx as u64)
                    .u64("to", ni as u64)
                    .u64("replayed", delivered.len() as u64)
                    .u64("attempt", failovers as u64)
                    .emit();
                idx = ni;
                addr = na;
            }
        }
    }
    router.admission.release(client_ip);
    if client_gone {
        anyhow::bail!("client disconnected mid-stream");
    }
    Ok(())
}

/// Terminal `ERR worker lost`: failover could not complete the session.
fn fail_session(router: &Router, writer: &mut TcpStream, idx: usize, tokens: usize, why: &str) {
    router.stats.worker_lost.fetch_add(1, Ordering::Relaxed);
    obs::Event::new("session_error")
        .u64("worker", idx as u64)
        .u64("tokens", tokens as u64)
        .str("why", why)
        .str("error", "worker lost")
        .emit();
    // a protocol ERR is a flight-recorder dump trigger (DESIGN.md §7):
    // the ring holds the events leading here
    obs::flight::dump("worker lost");
    let _ = writeln!(writer, "ERR worker lost");
}

/// One client connection: commands and sessions until QUIT/EOF/stop.
/// Mirrors the worker server's loop — stop-aware reads so a drain is
/// never wedged by an idle client, one `ERR` then close on garbage.
pub(super) fn handle_client(stream: TcpStream, router: Arc<Router>) -> Result<()> {
    let client_ip = stream
        .peer_addr()
        .map(|a| a.ip())
        .unwrap_or_else(|_| IpAddr::from([127, 0, 0, 1]));
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if router.stopping() {
            // drain/stop between sessions: close rather than accept more
            return Ok(());
        }
        // read one line, waking on the timeout to observe stop/drain;
        // bytes read before a timeout stay in `line` (read_until's
        // contract), so slow lines are never truncated
        line.clear();
        let eof = loop {
            match reader.read_line(&mut line) {
                Ok(0) => break true,
                Ok(_) => break false,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if router.stopping() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        };
        if eof && line.trim().is_empty() {
            return Ok(());
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "QUIT" {
            return Ok(());
        }
        if line == "STATS" {
            writeln!(writer, "{}", router.stats_line())?;
            continue;
        }
        if line == "METRICS" {
            // fleet-aggregated Prometheus exposition, framed by `# EOF`
            write!(writer, "{}", router.metrics_text())?;
            writer.flush()?;
            continue;
        }
        if line == "DRAIN" {
            writeln!(writer, "OK draining")?;
            router.request_drain();
            return Ok(());
        }
        // validate before consuming admission or a worker slot: garbage
        // must not count against capacity or the client's fairness cap
        if let Err(e) = parse_gen_line(line) {
            writeln!(writer, "ERR bad request: {e:#}")?;
            return Ok(());
        }
        proxy_session(&router, &mut writer, line, client_ip)?;
    }
}
