//! `bmoe route` — the fleet front door over one shared mmap substrate.
//!
//! PR 5 made `--load mmap` workers share the packed model's pages
//! through the kernel page cache: N serving processes, one resident
//! copy of the O(d² + N·d·log d) substrate.  This module is the
//! production piece that exploits it — a single TCP front door that
//! spawns and supervises a fleet of `bmoe serve --native --model X
//! --load mmap --port 0` workers on the same box and proxies streaming
//! generation sessions to the least-loaded healthy one:
//!
//! ```text
//!                        ┌──────────── bmoe route ────────────┐
//!  clients ──GEN/TOK──►  │ admission ─► balancer ─► relay     │
//!                        │  (shed /     (least-    (1 TCP conn│
//!                        │   queue /     loaded,    per       │
//!                        │   fairness)   rr ties)   session)  │
//!                        │        health thread               │
//!                        │  (STATS polls, restart w/ backoff) │
//!                        └───┬───────────┬───────────┬────────┘
//!                          serve       serve       serve      (children,
//!                         :ephem      :ephem      :ephem     --port 0)
//!                            └───── shared mmap pages ─┘
//! ```
//!
//! Submodules: [`admission`] (bounded queue, shedding, per-client
//! fairness, drain barrier), [`balance`] (fleet state, least-loaded
//! placement), [`worker`] (launch/supervise, real processes or
//! in-process test workers), [`health`] (poll/restart state machine),
//! [`proxy`] (wire handling and per-session relay).
//!
//! Shutdown reuses PR 1's loss-free semantics end-to-end: a `DRAIN`
//! command stops admission (`END shutdown` terminals for new arrivals),
//! waits for every accepted session — including queued ones — to reach
//! its terminal event, then sends each worker the wire `SHUTDOWN` (the
//! worker's own loss-free path) and reaps them.  No accepted session is
//! ever dropped without a terminal line.
//!
//! Design rationale (topology, session-granular balancing, the health
//! state machine): DESIGN.md §2.

pub mod admission;
pub mod balance;
pub mod health;
pub mod proxy;
pub mod worker;

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs;

use admission::Admission;
use balance::Fleet;
use health::HealthCtx;
use worker::{WorkerHandle, WorkerLauncher};

/// Router knobs (`bmoe route` flags map onto these).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Front-door port (0 = ephemeral, announced via `[listening]`).
    pub port: u16,
    /// Worker processes to spawn and supervise.
    pub fleet: usize,
    /// Concurrent sessions the router sends each worker before queueing
    /// (admission capacity = healthy × this).
    pub sessions_per_worker: usize,
    /// Bounded admission queue; arrivals beyond it are shed.
    pub max_queue: usize,
    /// Max concurrent sessions per client IP (0 = unlimited).
    pub client_cap: usize,
    /// Health sweep interval.
    pub health_interval: Duration,
    /// First restart backoff (doubles per failed attempt).
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Per-worker connect timeout when starting a relay.
    pub connect_timeout: Duration,
    /// Longest a queued session may wait before being shed.
    pub queue_timeout: Duration,
    /// Drain barrier: how long to wait for in-flight sessions before a
    /// forced teardown.
    pub drain_timeout: Duration,
    /// Mid-stream failovers attempted per session before the terminal
    /// `ERR worker lost` (0 = the pre-failover behavior).
    pub failover_retries: u32,
    /// How long a failing-over session waits for a healthy replacement
    /// worker (covers a fleet-of-one waiting out restart backoff).
    pub failover_wait: Duration,
    /// Worker-side per-event read budget while relaying; a stalled
    /// worker trips this and enters the failover path.
    pub relay_read_timeout: Duration,
    /// Client-side write budget: a client that stops reading cancels
    /// its session like a disconnect instead of pinning the relay.
    pub client_write_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            port: 7070,
            fleet: 2,
            sessions_per_worker: 16,
            max_queue: 64,
            client_cap: 0,
            health_interval: Duration::from_millis(500),
            backoff_base: Duration::from_millis(200),
            backoff_cap: Duration::from_secs(10),
            connect_timeout: Duration::from_secs(5),
            queue_timeout: Duration::from_secs(60),
            drain_timeout: Duration::from_secs(60),
            failover_retries: 2,
            failover_wait: Duration::from_secs(30),
            relay_read_timeout: Duration::from_secs(120),
            client_write_timeout: Duration::from_secs(30),
        }
    }
}

/// Router-level counters (worker-level ones live in [`balance::Fleet`]).
pub struct RouterStats {
    /// Sessions relayed to a worker terminal (`END`/`ERR` from it).
    pub routed: AtomicU64,
    /// Sessions shed by admission (`END shed`).
    pub shed: AtomicU64,
    /// Sessions that *ended* in `ERR worker lost` / `ERR no healthy
    /// worker` — i.e. a worker death that failover could not absorb.
    pub worker_lost: AtomicU64,
    /// Tokens relayed across all sessions.
    pub tokens: AtomicU64,
    /// Mid-stream failovers where a replacement worker took the replay.
    pub failovers: AtomicU64,
    /// Sessions terminated with `ERR replay diverged` (a replayed
    /// prefix failed byte-for-byte verification — should be zero
    /// forever; nonzero means the determinism contract broke).
    pub replay_diverged: AtomicU64,
    /// Distribution of delivered tokens verified + suppressed per
    /// failover (unit: tokens, power-of-two buckets).
    pub replayed_tokens: Mutex<crate::util::stats::LatencyHistogram>,
}

impl Default for RouterStats {
    fn default() -> Self {
        RouterStats {
            routed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            worker_lost: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            replay_diverged: AtomicU64::new(0),
            replayed_tokens: Mutex::new(crate::util::stats::LatencyHistogram::new(1.0, 2.0, 16)),
        }
    }
}

/// The supervisor: owns the fleet, admission gate, and health thread.
pub struct Router {
    pub cfg: RouterConfig,
    pub fleet: Arc<Fleet>,
    pub admission: Arc<Admission>,
    pub stats: RouterStats,
    health_ctx: Arc<HealthCtx>,
    health_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Stops the health loop and every connection/accept loop.
    stop: Arc<AtomicBool>,
    /// A drain was requested (DRAIN command or programmatic).
    drain_req: AtomicBool,
}

impl Router {
    /// Launch the fleet and start supervision.  Fails unless at least
    /// one worker comes up; failed slots enter the normal restart path.
    pub fn start(cfg: RouterConfig, launcher: Arc<dyn WorkerLauncher>) -> Result<Arc<Router>> {
        anyhow::ensure!(cfg.fleet >= 1, "fleet must be >= 1");
        let fleet = Arc::new(Fleet::new(cfg.fleet, cfg.backoff_base, cfg.backoff_cap));
        let admission = Arc::new(Admission::new(
            0,
            cfg.max_queue,
            cfg.client_cap,
            cfg.queue_timeout,
        ));
        let mut handles: Vec<Option<Box<dyn WorkerHandle>>> = Vec::new();
        for idx in 0..cfg.fleet {
            match launcher.launch(idx) {
                Ok((addr, handle)) => {
                    obs::log("route", &format!("worker {idx} up on {addr}"));
                    obs::Event::new("worker_up")
                        .u64("worker", idx as u64)
                        .str("addr", addr.to_string())
                        .emit();
                    fleet.mark_up(idx, addr, true);
                    handles.push(Some(handle));
                }
                Err(e) => {
                    obs::log("route", &format!("worker {idx} failed to start: {e:#}"));
                    obs::Event::new("worker_spawn_failed")
                        .u64("worker", idx as u64)
                        .str("error", format!("{e:#}"))
                        .emit();
                    fleet.mark_down(idx);
                    handles.push(None);
                }
            }
        }
        anyhow::ensure!(
            fleet.healthy() > 0,
            "no worker came up (fleet of {})",
            cfg.fleet
        );
        admission.set_capacity(fleet.healthy() * cfg.sessions_per_worker);
        let health_ctx = Arc::new(HealthCtx {
            fleet: fleet.clone(),
            admission: admission.clone(),
            launcher,
            handles: Mutex::new(handles),
            sessions_per_worker: cfg.sessions_per_worker,
            poll_timeout: Duration::from_millis(500).max(cfg.health_interval),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let health_thread = {
            let ctx = health_ctx.clone();
            let interval = cfg.health_interval;
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("bmoe-route-health".into())
                .spawn(move || health::health_loop(ctx, interval, stop))
                .context("spawn health loop")?
        };
        Ok(Arc::new(Router {
            cfg,
            fleet,
            admission,
            stats: RouterStats::default(),
            health_ctx,
            health_thread: Mutex::new(Some(health_thread)),
            stop,
            drain_req: AtomicBool::new(false),
        }))
    }

    /// True once a drain or stop has been requested — connection loops
    /// stop reading new requests.
    pub fn stopping(&self) -> bool {
        self.stop.load(Ordering::SeqCst) || self.drain_req.load(Ordering::SeqCst)
    }

    /// Begin a drain: admission closes immediately (new sessions get
    /// `END shutdown`), the accept loop winds down, and `serve` runs
    /// the full teardown before returning.
    pub fn request_drain(&self) {
        if !self.drain_req.swap(true, Ordering::SeqCst) {
            obs::Event::new("router_drain").u64("fleet", self.cfg.fleet as u64).emit();
        }
        self.admission.begin_drain();
    }

    /// Kill worker `idx`'s process outright (chaos testing: sessions on
    /// it fail over to a healthy worker and the health loop restarts it).
    pub fn kill_worker(&self, idx: usize) {
        if let Some(h) = self.health_ctx.handles.lock().unwrap()[idx].as_mut() {
            h.kill();
        }
    }

    /// A relay lost its connection to worker `idx` mid-session: declare
    /// the worker down *now* — addr-guarded, so if the health loop
    /// already restarted the slot on a new address this is a no-op —
    /// and reap the corpse, instead of letting further placements land
    /// on it until the next health sweep.
    pub(crate) fn note_worker_lost(&self, idx: usize, addr: std::net::SocketAddr) {
        if !self.fleet.mark_down_if_up_on(idx, addr) {
            return;
        }
        if let Some(mut h) = self.health_ctx.handles.lock().unwrap()[idx].take() {
            h.kill();
        }
        obs::log("route", &format!("worker {idx} lost mid-relay; marked down"));
        obs::Event::new("worker_down")
            .u64("worker", idx as u64)
            .str("why", "relay lost connection")
            .emit();
    }

    /// OS pids of the live workers, slot-indexed (`None` for down slots
    /// and in-process test workers).  For RSS accounting in benches.
    pub fn worker_pids(&self) -> Vec<Option<u32>> {
        self.health_ctx
            .handles
            .lock()
            .unwrap()
            .iter()
            .map(|h| h.as_ref().and_then(|h| h.pid()))
            .collect()
    }

    /// One `key=value` line for the router's own `STATS` command.
    pub fn stats_line(&self) -> String {
        let (inflight, queued, capacity, draining) = self.admission.counts();
        let views = self.fleet.views();
        let restarts: u64 = views.iter().map(|v| v.restarts).sum();
        let replayed_sum = self.stats.replayed_tokens.lock().unwrap().sum as u64;
        let mut line = format!(
            "STATS fleet={} healthy={} capacity={capacity} inflight={inflight} \
             queued={queued} draining={} routed={} shed={} worker_lost={} \
             failovers={} replayed={replayed_sum} diverged={} tokens={} \
             restarts={restarts}",
            views.len(),
            self.fleet.healthy(),
            draining as u8,
            self.stats.routed.load(Ordering::Relaxed),
            self.stats.shed.load(Ordering::Relaxed),
            self.stats.worker_lost.load(Ordering::Relaxed),
            self.stats.failovers.load(Ordering::Relaxed),
            self.stats.replay_diverged.load(Ordering::Relaxed),
            self.stats.tokens.load(Ordering::Relaxed),
        );
        for (i, v) in views.iter().enumerate() {
            line.push_str(&format!(
                " w{i}_up={} w{i}_sessions={} w{i}_queue={} w{i}_tokens={} w{i}_restarts={}",
                v.up as u8, v.sessions, v.queue_depth, v.tokens_relayed, v.restarts
            ));
        }
        line
    }

    /// Fleet-wide Prometheus exposition for the `METRICS` verb: scrape
    /// every Up worker's own `METRICS`, tag each sample with a
    /// `worker="wN"` label, dedup the `# HELP`/`# TYPE` headers shared
    /// across workers, and append the router's own `bmoe_router_*`
    /// series.  Framed once with `# EOF` (DESIGN.md §7).  Workers that
    /// fail to answer within the connect timeout are skipped — a scrape
    /// must never wedge behind a dying worker.
    pub fn metrics_text(&self) -> String {
        use crate::obs::prom::{self, PromText};
        let views = self.fleet.views();
        let mut merged = String::new();
        let mut seen_headers = std::collections::BTreeSet::new();
        for (i, v) in views.iter().enumerate() {
            if !v.up {
                continue;
            }
            let Some(addr) = self.fleet.addr(i) else { continue };
            let Ok(text) = scrape_metrics(addr, self.cfg.connect_timeout) else {
                continue;
            };
            let labeled = prom::inject_label(&text, "worker", &format!("w{i}"));
            for line in labeled.lines() {
                if line == prom::EOF_LINE {
                    continue;
                }
                if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
                    if !seen_headers.insert(line.to_string()) {
                        continue;
                    }
                }
                merged.push_str(line);
                merged.push('\n');
            }
        }
        let (inflight, queued, capacity, _draining) = self.admission.counts();
        let mut p = PromText::new();
        p.counter(
            "bmoe_router_routed_total",
            "Sessions relayed to a worker terminal.",
            &[],
            self.stats.routed.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "bmoe_router_shed_total",
            "Sessions shed by admission.",
            &[],
            self.stats.shed.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "bmoe_router_worker_lost_total",
            "Sessions whose worker died mid-relay.",
            &[],
            self.stats.worker_lost.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "bmoe_router_relayed_tokens_total",
            "Tokens relayed across all sessions.",
            &[],
            self.stats.tokens.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "bmoe_failover_total",
            "Mid-stream session failovers (replay accepted by a replacement worker).",
            &[],
            self.stats.failovers.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "bmoe_router_replay_diverged_total",
            "Failover replays whose delivered prefix failed verification.",
            &[],
            self.stats.replay_diverged.load(Ordering::Relaxed) as f64,
        );
        p.histogram(
            "bmoe_failover_replayed_tokens",
            "Tokens verified and suppressed per failover replay.",
            &[],
            &self.stats.replayed_tokens.lock().unwrap(),
        );
        p.gauge(
            "bmoe_router_workers_up",
            "Healthy workers in the fleet.",
            &[],
            self.fleet.healthy() as f64,
        );
        p.gauge(
            "bmoe_router_fleet_size",
            "Configured fleet size.",
            &[],
            views.len() as f64,
        );
        p.gauge(
            "bmoe_router_capacity",
            "Admission capacity (healthy workers x sessions per worker).",
            &[],
            capacity as f64,
        );
        p.gauge("bmoe_router_inflight", "Sessions in flight.", &[], inflight as f64);
        p.gauge("bmoe_router_queued", "Sessions queued in admission.", &[], queued as f64);
        for (i, v) in views.iter().enumerate() {
            let labels = [("worker", format!("w{i}"))];
            p.gauge(
                "bmoe_router_worker_up",
                "Per-worker liveness (1 = up).",
                &labels,
                v.up as u8 as f64,
            );
            p.counter(
                "bmoe_router_worker_restarts_total",
                "Per-worker restarts by the health loop.",
                &labels,
                v.restarts as f64,
            );
        }
        merged.push_str(&p.into_unframed());
        merged.push_str(prom::EOF_LINE);
        merged.push('\n');
        merged
    }

    /// Drain and tear the fleet down.  Returns `true` when every
    /// accepted session completed inside the drain window (loss-free).
    pub fn drain(&self) -> bool {
        self.request_drain();
        let lossless = self.admission.wait_idle(self.cfg.drain_timeout);
        if !lossless {
            obs::log(
                "route",
                &format!(
                    "drain window ({:?}) expired with sessions still in flight; forcing",
                    self.cfg.drain_timeout
                ),
            );
        }
        // stop supervision *before* retiring workers so the health loop
        // doesn't resurrect them mid-teardown
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.health_thread.lock().unwrap().take() {
            let _ = t.join();
        }
        let mut handles = self.health_ctx.handles.lock().unwrap();
        for (idx, slot) in handles.iter_mut().enumerate() {
            let Some(handle) = slot.as_mut() else { continue };
            // graceful first: the worker's own loss-free shutdown
            if let Some(addr) = self.fleet.addr(idx) {
                let _ = send_shutdown(addr);
            }
            if !handle.wait_exit(Duration::from_secs(10)) {
                obs::log("route", &format!("worker {idx} ignored SHUTDOWN; killing"));
                handle.kill();
            }
        }
        lossless
    }

    /// Front-door accept loop.  Returns after a drain completes (the
    /// normal exit) or `stop` is set externally.
    pub fn serve(self: Arc<Router>, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        loop {
            if self.stopping() {
                break;
            }
            match listener.accept() {
                Ok((stream, _addr)) => {
                    let router = self.clone();
                    conns.push(std::thread::spawn(move || {
                        let _ = proxy::handle_client(stream, router);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        // connection threads run their in-flight sessions to terminal
        // events (admission is already draining), then exit
        for c in conns {
            let _ = c.join();
        }
        self.drain();
        Ok(())
    }
}

/// Scrape one worker's `METRICS` exposition, reading up to (and
/// swallowing) the `# EOF` frame line.
fn scrape_metrics(addr: std::net::SocketAddr, timeout: Duration) -> Result<String> {
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect_timeout(&addr, timeout)?;
    s.set_read_timeout(Some(timeout))?;
    writeln!(s, "METRICS")?;
    s.flush()?;
    let mut reader = BufReader::new(s);
    let mut out = String::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("worker closed before # EOF");
        }
        if line.trim_end() == crate::obs::prom::EOF_LINE {
            return Ok(out);
        }
        out.push_str(&line);
    }
}

/// Ask a worker to shut down gracefully over the wire.
fn send_shutdown(addr: std::net::SocketAddr) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect_timeout(&addr, Duration::from_secs(2))?;
    s.set_read_timeout(Some(Duration::from_secs(2)))?;
    writeln!(s, "SHUTDOWN")?;
    let mut line = String::new();
    let _ = BufReader::new(s).read_line(&mut line); // best-effort ack
    Ok(())
}

/// `bmoe route` entrypoint: bind the front door, announce it, serve
/// until drained.
pub fn run(cfg: RouterConfig, launcher: Arc<dyn WorkerLauncher>) -> Result<()> {
    let (listener, addr) = crate::util::net::listen_reuse(cfg.port)?;
    let router = Router::start(cfg, launcher)?;
    println!("[listening] {addr}");
    use std::io::Write;
    std::io::stdout().flush().ok();
    obs::log(
        "route",
        &format!(
            "fleet of {} ({} healthy) behind {addr}; DRAIN to shut down",
            router.cfg.fleet,
            router.fleet.healthy()
        ),
    );
    router.serve(listener)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use worker::InProcessLauncher;

    fn test_cfg() -> RouterConfig {
        RouterConfig {
            fleet: 2,
            sessions_per_worker: 4,
            max_queue: 2,
            client_cap: 0,
            health_interval: Duration::from_millis(50),
            backoff_base: Duration::from_millis(30),
            backoff_cap: Duration::from_millis(500),
            connect_timeout: Duration::from_secs(2),
            queue_timeout: Duration::from_secs(5),
            drain_timeout: Duration::from_secs(10),
            ..RouterConfig::default()
        }
    }

    fn start(cfg: RouterConfig, launcher: InProcessLauncher) -> (Arc<Router>, std::net::SocketAddr) {
        let router = Router::start(cfg, Arc::new(launcher)).unwrap();
        let (listener, addr) = crate::util::net::listen_reuse(0).unwrap();
        {
            let router = router.clone();
            std::thread::spawn(move || router.serve(listener));
        }
        (router, addr)
    }

    fn run_session(addr: std::net::SocketAddr, gen: &str) -> (Vec<i32>, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{gen}").unwrap();
        read_session(&mut BufReader::new(s))
    }

    /// Read TOK lines until a terminal; returns (tokens, terminal line).
    fn read_session(r: &mut BufReader<TcpStream>) -> (Vec<i32>, String) {
        let mut toks = Vec::new();
        loop {
            let mut line = String::new();
            if r.read_line(&mut line).unwrap_or(0) == 0 {
                return (toks, "EOF".into());
            }
            if let Some(rest) = line.strip_prefix("TOK ") {
                toks.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
            } else {
                return (toks, line.trim().to_string());
            }
        }
    }

    fn stats(addr: std::net::SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "STATS").unwrap();
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).unwrap();
        line
    }

    fn stat_field(line: &str, key: &str) -> u64 {
        line.split_whitespace()
            .find_map(|kv| kv.strip_prefix(key)?.strip_prefix('=')?.parse().ok())
            .unwrap_or_else(|| panic!("missing {key} in {line}"))
    }

    /// Send METRICS and read the framed exposition through `# EOF`.
    fn metrics(addr: std::net::SocketAddr) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "METRICS").unwrap();
        let mut r = BufReader::new(s);
        let mut text = String::new();
        loop {
            let mut line = String::new();
            assert!(r.read_line(&mut line).unwrap() > 0, "EOF before # EOF frame");
            text.push_str(&line);
            if line.trim_end() == crate::obs::prom::EOF_LINE {
                return text;
            }
        }
    }

    #[test]
    fn sessions_stream_through_the_router_and_spread() {
        let (router, addr) = start(test_cfg(), InProcessLauncher::new(Duration::ZERO, 4));
        for i in 0..6 {
            let (toks, end) = run_session(addr, &format!("GEN 3 0 0 0 -1 1 2 {i}"));
            assert_eq!(toks.len(), 3, "session {i}");
            assert!(end.starts_with("END max_tokens 3"), "{end}");
        }
        // round-robin tie-break: sequential sessions land on both
        // workers.  Counters are bumped just after the terminal line is
        // forwarded, so poll briefly rather than racing the bookkeeping.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            let line = stats(addr);
            if stat_field(&line, "routed") == 6 {
                assert!(stat_field(&line, "w0_tokens") > 0, "{line}");
                assert!(stat_field(&line, "w1_tokens") > 0, "{line}");
                assert_eq!(stat_field(&line, "shed"), 0, "{line}");
                break;
            }
            assert!(std::time::Instant::now() < deadline, "routed never hit 6: {line}");
            std::thread::sleep(Duration::from_millis(10));
        }
        router.drain();
    }

    #[test]
    fn shed_at_capacity_is_explicit_and_immediate() {
        // capacity 1x1, queue 0-ish: second concurrent session sheds
        let cfg = RouterConfig {
            fleet: 1,
            sessions_per_worker: 1,
            max_queue: 0,
            queue_timeout: Duration::from_millis(200),
            ..test_cfg()
        };
        // slow steps so the first session is still running when the
        // second arrives
        let (router, addr) =
            start(cfg, InProcessLauncher::new(Duration::from_millis(30), 4));
        let mut s1 = TcpStream::connect(addr).unwrap();
        writeln!(s1, "GEN 20 0 0 0 -1 1 2").unwrap();
        let mut r1 = BufReader::new(s1.try_clone().unwrap());
        let mut first = String::new();
        r1.read_line(&mut first).unwrap();
        assert!(first.starts_with("TOK "), "{first}");
        // second session: must shed promptly, not queue behind 20 slow steps
        let t0 = std::time::Instant::now();
        let (toks, end) = run_session(addr, "GEN 2 0 0 0 -1 3 4");
        assert!(toks.is_empty());
        assert!(end.starts_with("END shed 0"), "{end}");
        assert!(t0.elapsed() < Duration::from_secs(2), "shed must not stall");
        let (rest, end1) = read_session(&mut r1);
        assert_eq!(rest.len(), 19);
        assert!(end1.starts_with("END max_tokens"), "{end1}");
        router.drain();
    }

    #[test]
    fn per_client_fairness_cap_sheds_the_hog() {
        let cfg = RouterConfig {
            fleet: 1,
            sessions_per_worker: 8,
            client_cap: 1,
            ..test_cfg()
        };
        let (router, addr) =
            start(cfg, InProcessLauncher::new(Duration::from_millis(20), 8));
        // all test clients share 127.0.0.1, so with cap 1 a second
        // concurrent session from "the same client" must shed even
        // though worker capacity is plentiful
        let mut s1 = TcpStream::connect(addr).unwrap();
        writeln!(s1, "GEN 30 0 0 0 -1 1 2").unwrap();
        let mut r1 = BufReader::new(s1.try_clone().unwrap());
        let mut first = String::new();
        r1.read_line(&mut first).unwrap();
        let (_, end) = run_session(addr, "GEN 2 0 0 0 -1 3 4");
        assert!(end.starts_with("END shed 0"), "{end}");
        let (_, end1) = read_session(&mut r1);
        assert!(end1.starts_with("END max_tokens"), "{end1}");
        // with the hog gone, the same client is admitted again (the
        // router releases its slot just after forwarding the terminal,
        // so allow it a beat)
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            let (toks, end2) = run_session(addr, "GEN 2 0 0 0 -1 3 4");
            if end2.starts_with("END max_tokens") {
                assert_eq!(toks.len(), 2);
                break;
            }
            assert!(end2.starts_with("END shed"), "{end2}");
            assert!(std::time::Instant::now() < deadline, "cap slot never released");
            std::thread::sleep(Duration::from_millis(10));
        }
        router.drain();
    }

    #[test]
    fn killed_worker_fails_over_mid_stream_seamlessly() {
        // fleet of ONE: the hard case.  The worker dies mid-stream, the
        // relay declares it down, waits out the health loop's relaunch,
        // replays the seeded GEN line on the restarted worker, verifies
        // + suppresses the delivered prefix, and the client receives one
        // complete stream bit-identical to a fault-free run — no ERR.
        let cfg = RouterConfig {
            fleet: 1,
            ..test_cfg()
        };
        let (router, addr) =
            start(cfg, InProcessLauncher::new(Duration::from_millis(25), 4));
        // fault-free baseline of the exact same session (CountBackend
        // streams depend only on prompt length — deterministic)
        let (baseline, base_end) = run_session(addr, "GEN 40 0 0 0 -1 1 2");
        assert_eq!(baseline.len(), 40);
        assert!(base_end.starts_with("END max_tokens 40 "), "{base_end}");
        let mut s1 = TcpStream::connect(addr).unwrap();
        writeln!(s1, "GEN 40 0 0 0 -1 1 2").unwrap();
        let mut r1 = BufReader::new(s1.try_clone().unwrap());
        let mut first = String::new();
        r1.read_line(&mut first).unwrap();
        assert!(first.starts_with("TOK "), "{first}");
        router.kill_worker(0);
        let (rest, end) = read_session(&mut r1);
        let mut full: Vec<i32> = vec![first
            .strip_prefix("TOK ")
            .unwrap()
            .split_whitespace()
            .nth(1)
            .unwrap()
            .parse()
            .unwrap()];
        full.extend(rest);
        assert_eq!(full, baseline, "failover stream must be bit-identical");
        assert!(end.starts_with("END max_tokens 40 "), "no ERR on failover: {end}");
        let line = stats(addr);
        assert!(stat_field(&line, "failovers") >= 1, "{line}");
        assert_eq!(stat_field(&line, "worker_lost"), 0, "{line}");
        assert_eq!(stat_field(&line, "diverged"), 0, "{line}");
        assert!(stat_field(&line, "restarts") >= 1, "{line}");
        router.drain();
    }

    #[test]
    fn failover_disabled_gives_terminal_err() {
        // failover_retries = 0 restores the old contract: the client
        // gets the explicit terminal ERR, never a hung stream
        let cfg = RouterConfig {
            fleet: 1,
            failover_retries: 0,
            ..test_cfg()
        };
        let (router, addr) =
            start(cfg, InProcessLauncher::new(Duration::from_millis(25), 4));
        let mut s1 = TcpStream::connect(addr).unwrap();
        writeln!(s1, "GEN 1000 0 0 0 -1 1 2").unwrap();
        let mut r1 = BufReader::new(s1.try_clone().unwrap());
        let mut first = String::new();
        r1.read_line(&mut first).unwrap();
        assert!(first.starts_with("TOK "), "{first}");
        router.kill_worker(0);
        let (_, end) = read_session(&mut r1);
        assert!(end.starts_with("ERR worker lost"), "{end}");
        let line = stats(addr);
        assert!(stat_field(&line, "worker_lost") >= 1, "{line}");
        router.drain();
    }

    #[test]
    fn crash_looping_relaunch_keeps_escalating_backoff() {
        // regression for the mark_up reset bug: a worker that announces
        // and dies instantly must escalate `attempts`, not restart in a
        // tight loop at backoff_base forever
        let cfg = RouterConfig {
            fleet: 1,
            health_interval: Duration::from_millis(20),
            backoff_base: Duration::from_millis(30),
            backoff_cap: Duration::from_secs(60),
            ..test_cfg()
        };
        let launcher = Arc::new(InProcessLauncher::new(Duration::ZERO, 4));
        let router = Router::start(cfg, launcher.clone()).unwrap();
        launcher.die_next(usize::MAX);
        router.kill_worker(0);
        // every relaunch reports in (mark_up) then dies before its first
        // poll; with the bug, attempts oscillates 0/1 and never grows
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while router.fleet.views()[0].attempts < 3 {
            assert!(
                std::time::Instant::now() < deadline,
                "backoff never escalated past attempts={} (crash loop at backoff_base?)",
                router.fleet.views()[0].attempts
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        // stop the scripted deaths: the next relaunch survives, answers
        // a poll, and the slot's probation ends (attempts back to 0)
        launcher.die_next(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while router.fleet.healthy() == 0 || router.fleet.views()[0].attempts != 0 {
            assert!(std::time::Instant::now() < deadline, "worker never recovered");
            std::thread::sleep(Duration::from_millis(20));
        }
        router.drain();
    }

    fn relay_opts() -> proxy::RelayOpts {
        proxy::RelayOpts {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
        }
    }

    /// Fake worker: answers the first line with the given reply lines,
    /// then closes.  Returns the address to relay to.
    fn fake_worker(lines: &'static [&'static str]) -> std::net::SocketAddr {
        let (listener, waddr) = crate::util::net::listen_reuse(0).unwrap();
        std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut line = String::new();
            BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
            for l in lines {
                writeln!(s, "{l}").unwrap();
            }
        });
        waddr
    }

    #[test]
    fn relay_reports_worker_lost_on_mid_stream_eof() {
        // a raw fake worker that streams two TOKs then slams the door —
        // the relay must surface the loss (for failover), not hang
        let waddr = fake_worker(&["TOK 0 7 100", "TOK 1 8 100"]);
        let (client_listener, caddr) = crate::util::net::listen_reuse(0).unwrap();
        let client = std::thread::spawn(move || {
            let s = TcpStream::connect(caddr).unwrap();
            read_session(&mut BufReader::new(s))
        });
        let (mut server_side, _) = client_listener.accept().unwrap();
        let mut delivered = Vec::new();
        let outcome = proxy::relay_session(
            &mut server_side,
            waddr,
            "GEN 5 0 0 0 -1 1",
            &relay_opts(),
            &mut delivered,
            |_| {},
        );
        assert_eq!(outcome, proxy::RelayOutcome::WorkerLost);
        assert_eq!(delivered, vec!["0 7".to_string(), "1 8".to_string()]);
        writeln!(server_side, "ERR worker lost").unwrap();
        drop(server_side);
        let (toks, end) = client.join().unwrap();
        assert_eq!(toks, vec![7, 8]);
        assert!(end.starts_with("ERR worker lost"), "{end}");
    }

    #[test]
    fn relay_replay_suppresses_verified_prefix_and_resumes() {
        // second attempt of a failed-over session: worker replays the
        // full stream; the two delivered tokens are verified+suppressed
        // (latency fields may differ — they are not part of the
        // deterministic payload) and only the continuation reaches the
        // client
        let waddr = fake_worker(&[
            "TOK 0 7 999",
            "TOK 1 8 5",
            "TOK 2 9 100",
            "END max_tokens 3 1234 0",
        ]);
        let (client_listener, caddr) = crate::util::net::listen_reuse(0).unwrap();
        let client = std::thread::spawn(move || {
            let s = TcpStream::connect(caddr).unwrap();
            read_session(&mut BufReader::new(s))
        });
        let (mut server_side, _) = client_listener.accept().unwrap();
        let mut delivered = vec!["0 7".to_string(), "1 8".to_string()];
        let outcome = proxy::relay_session(
            &mut server_side,
            waddr,
            "GEN 3 0 0 0 -1 1",
            &relay_opts(),
            &mut delivered,
            |_| {},
        );
        assert_eq!(outcome, proxy::RelayOutcome::Done);
        assert_eq!(delivered.len(), 3, "continuation appended: {delivered:?}");
        drop(server_side);
        let (toks, end) = client.join().unwrap();
        assert_eq!(toks, vec![9], "prefix suppressed, only new tokens forwarded");
        assert!(end.starts_with("END max_tokens 3"), "{end}");
    }

    #[test]
    fn relay_replay_divergence_is_detected_not_forwarded() {
        // the replay's second token differs from what the client got:
        // the relay must abort with ReplayDiverged and forward NOTHING
        let waddr = fake_worker(&["TOK 0 7 100", "TOK 1 999 100", "TOK 2 9 100"]);
        let (client_listener, caddr) = crate::util::net::listen_reuse(0).unwrap();
        let client = std::thread::spawn(move || {
            let s = TcpStream::connect(caddr).unwrap();
            read_session(&mut BufReader::new(s))
        });
        let (mut server_side, _) = client_listener.accept().unwrap();
        let mut delivered = vec!["0 7".to_string(), "1 8".to_string()];
        let outcome = proxy::relay_session(
            &mut server_side,
            waddr,
            "GEN 3 0 0 0 -1 1",
            &relay_opts(),
            &mut delivered,
            |_| {},
        );
        match outcome {
            proxy::RelayOutcome::ReplayDiverged { at, want, got } => {
                assert_eq!(at, 1);
                assert_eq!(want, "1 8");
                assert_eq!(got, "1 999");
            }
            other => panic!("expected ReplayDiverged, got {other:?}"),
        }
        writeln!(server_side, "ERR replay diverged").unwrap();
        drop(server_side);
        let (toks, end) = client.join().unwrap();
        assert!(toks.is_empty(), "diverged replay must forward no tokens: {toks:?}");
        assert!(end.starts_with("ERR replay diverged"), "{end}");
    }

    #[test]
    fn relay_short_replay_is_divergence_too() {
        // replay ends (END) before reproducing the delivered prefix:
        // wrong bits by omission — also a divergence, never silent
        let waddr = fake_worker(&["TOK 0 7 100", "END max_tokens 1 50 0"]);
        let (client_listener, caddr) = crate::util::net::listen_reuse(0).unwrap();
        let _client = TcpStream::connect(caddr).unwrap();
        let (mut server_side, _) = client_listener.accept().unwrap();
        let mut delivered = vec!["0 7".to_string(), "1 8".to_string()];
        let outcome = proxy::relay_session(
            &mut server_side,
            waddr,
            "GEN 3 0 0 0 -1 1",
            &relay_opts(),
            &mut delivered,
            |_| {},
        );
        assert!(
            matches!(outcome, proxy::RelayOutcome::ReplayDiverged { at: 1, .. }),
            "short replay must diverge, got {outcome:?}"
        );
    }

    #[test]
    fn stalled_client_reader_is_cancelled_by_write_timeout() {
        // a fake worker pumps TOK lines forever; the client socket is
        // deliberately never read.  Once the kernel buffers fill, the
        // relay's write must trip the client write timeout and cancel
        // the session like a disconnect — not pin the thread forever.
        let (listener, waddr) = crate::util::net::listen_reuse(0).unwrap();
        std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut line = String::new();
            BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
            let mut i = 0u64;
            // stops when the relay drops the worker connection
            while writeln!(s, "TOK {i} 7 100").is_ok() {
                i += 1;
            }
        });
        let (client_listener, caddr) = crate::util::net::listen_reuse(0).unwrap();
        let _client = TcpStream::connect(caddr).unwrap(); // never read
        let (mut server_side, _) = client_listener.accept().unwrap();
        let opts = proxy::RelayOpts {
            write_timeout: Duration::from_millis(250),
            ..relay_opts()
        };
        let mut delivered = Vec::new();
        let t0 = std::time::Instant::now();
        let outcome = proxy::relay_session(
            &mut server_side,
            waddr,
            "GEN 5 0 0 0 -1 1",
            &opts,
            &mut delivered,
            |_| {},
        );
        assert_eq!(outcome, proxy::RelayOutcome::ClientGone);
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "write timeout never fired ({:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn restart_backoff_retries_after_launch_failures() {
        let cfg = RouterConfig {
            fleet: 1,
            ..test_cfg()
        };
        let launcher = Arc::new(InProcessLauncher::new(Duration::ZERO, 4));
        let router = Router::start(cfg, launcher.clone()).unwrap();
        // make the next relaunch fail once, then kill the worker: the
        // health loop must eat the failure, back off, and retry until
        // one launch sticks
        launcher.fail_next(1);
        router.kill_worker(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while router.fleet.healthy() == 0 {
            assert!(std::time::Instant::now() < deadline, "restart never happened");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(router.fleet.views()[0].restarts >= 1);
        assert!(
            launcher.launch_count() >= 3,
            "boot + injected failure + successful retry, got {}",
            launcher.launch_count()
        );
        router.drain();
    }

    #[test]
    fn drain_under_load_loses_no_accepted_session() {
        let cfg = RouterConfig {
            fleet: 2,
            sessions_per_worker: 2,
            max_queue: 8,
            ..test_cfg()
        };
        let (router, addr) =
            start(cfg, InProcessLauncher::new(Duration::from_millis(10), 2));
        // saturate: 4 admitted + several queued
        let clients: Vec<_> = (0..8)
            .map(|i| {
                std::thread::spawn(move || {
                    run_session(addr, &format!("GEN 12 0 0 0 -1 1 {i}"))
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(60));
        router.request_drain();
        // every accepted session still reaches a terminal event; nobody
        // hangs and nobody gets a silently-closed stream mid-session
        let mut completed = 0;
        for c in clients {
            let (toks, end) = c.join().unwrap();
            if end.starts_with("END max_tokens") {
                assert_eq!(toks.len(), 12);
                completed += 1;
            } else {
                assert!(
                    end.starts_with("END shutdown") || end.starts_with("END shed"),
                    "non-terminal outcome {end}"
                );
            }
        }
        assert!(completed >= 4, "the admitted sessions must complete, got {completed}");
        assert!(router.drain(), "drain must report loss-free");
    }

    #[test]
    fn drain_command_over_the_wire_stops_the_router() {
        let (router, addr) = start(test_cfg(), InProcessLauncher::new(Duration::ZERO, 4));
        let (toks, _) = run_session(addr, "GEN 2 0 0 0 -1 1 2");
        assert_eq!(toks.len(), 2);
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "DRAIN").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "OK draining");
        // new sessions now get the draining terminal (until the accept
        // loop fully winds down) or a refused connect after it does
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !router.stopping() {
            assert!(std::time::Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(5));
        }
        match TcpStream::connect(addr) {
            Ok(mut s) => {
                if writeln!(s, "GEN 2 0 0 0 -1 1 2").is_ok() {
                    let (_, end) = read_session(&mut BufReader::new(s));
                    assert!(
                        end.starts_with("END shutdown") || end == "EOF",
                        "draining router must terminate new sessions: {end}"
                    );
                }
            }
            Err(_) => {} // listener already down — also a clean outcome
        }
    }

    #[test]
    fn metrics_verb_aggregates_fleet_with_worker_labels() {
        let (router, addr) = start(test_cfg(), InProcessLauncher::new(Duration::ZERO, 4));
        let (toks, end) = run_session(addr, "GEN 2 0 0 0 -1 1 2");
        assert_eq!(toks.len(), 2, "{end}");
        let text = metrics(addr);
        // every worker's series carries its slot label
        assert!(text.contains("worker=\"w0\""), "{text}");
        assert!(text.contains("worker=\"w1\""), "{text}");
        assert!(text.contains("bmoe_requests_total{worker=\"w0\"}"), "{text}");
        // shared HELP/TYPE headers are deduped across workers
        assert_eq!(text.matches("# HELP bmoe_requests_total ").count(), 1, "{text}");
        assert_eq!(text.matches("# TYPE bmoe_requests_total counter").count(), 1, "{text}");
        // the router's own fleet-level series are appended
        assert!(text.contains("# TYPE bmoe_router_routed_total counter"), "{text}");
        assert!(text.contains("bmoe_router_workers_up 2"), "{text}");
        assert!(text.contains("bmoe_router_fleet_size 2"), "{text}");
        assert!(text.contains("bmoe_router_worker_up{worker=\"w0\"} 1"), "{text}");
        // framed exactly once, at the very end
        assert_eq!(text.matches("# EOF").count(), 1, "{text}");
        assert!(text.ends_with("# EOF\n"), "{text}");
        // STATS is unchanged next to METRICS on the same front door
        assert!(stats(addr).starts_with("STATS fleet=2 "), "{}", stats(addr));
        router.drain();
    }

    #[test]
    fn worker_death_dumps_flight_recorder() {
        // ring + dump dir are process-global; serialize with the other
        // flight tests
        let _g = crate::obs::flight::TEST_MUTEX
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("bmoe_route_flight_test");
        std::fs::create_dir_all(&dir).unwrap();
        crate::obs::flight::set_dir(Some(dir));
        let dump = crate::obs::flight::dump_path();
        let _ = std::fs::remove_file(&dump);
        let cfg = RouterConfig {
            fleet: 1,
            ..test_cfg()
        };
        let (router, addr) = start(cfg, InProcessLauncher::new(Duration::ZERO, 4));
        let (toks, _) = run_session(addr, "GEN 2 0 0 0 -1 1 2");
        assert_eq!(toks.len(), 2);
        router.kill_worker(0);
        // the health loop declares the worker down and dumps the ring
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        let text = loop {
            if let Ok(text) = std::fs::read_to_string(&dump) {
                if text.contains("worker down") || text.contains("worker_down") {
                    break text;
                }
            }
            assert!(
                std::time::Instant::now() < deadline,
                "no flight dump at {} after worker kill",
                dump.display()
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        let first = text.lines().next().unwrap();
        assert!(first.contains("\"event\":\"flight_dump\""), "{first}");
        crate::obs::flight::set_dir(None);
        router.drain();
        let _ = std::fs::remove_file(&dump);
    }

    #[test]
    fn malformed_line_gets_err_and_close_without_burning_capacity() {
        let (router, addr) = start(test_cfg(), InProcessLauncher::new(Duration::ZERO, 4));
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN not a request").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR bad request:"), "{line}");
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "close after ERR");
        let line = stats(addr);
        assert_eq!(stat_field(&line, "routed"), 0, "{line}");
        assert_eq!(stat_field(&line, "shed"), 0, "garbage must not shed-count: {line}");
        router.drain();
    }
}
