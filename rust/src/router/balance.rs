//! Fleet state and least-loaded session placement.
//!
//! Placement is **session-granular**: a `GEN` session is pinned to one
//! worker for its whole lifetime, because the worker's scheduler holds
//! the session's decode state (resident sequence, sampler RNG, KV-style
//! context) — tokens of one session cannot be split across processes.
//! The balancer therefore only decides *where a session starts*: it
//! scores each healthy worker by `router-placed sessions + last-polled
//! queue_depth` and picks the minimum, breaking ties round-robin so a
//! strictly sequential client still spreads across the fleet instead of
//! camping on worker 0.

use std::net::SocketAddr;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One worker's supervision state.
#[derive(Clone, Debug)]
pub enum SlotState {
    Up { addr: SocketAddr },
    Down { next_attempt: Instant },
}

/// One worker slot: state plus load/health bookkeeping.
pub struct Slot {
    pub state: SlotState,
    /// Sessions the router currently has open against this worker.
    pub sessions: usize,
    /// Last `STATS` poll: requests queued behind the worker's batch.
    pub queue_depth: u64,
    /// Last `STATS` poll: sequences resident in the worker's batch.
    pub inflight: u64,
    /// Tokens relayed through this worker since launch (router-side).
    pub tokens_relayed: u64,
    /// Successful relaunches after a crash.
    pub restarts: u64,
    /// Consecutive failed relaunch attempts while Down (drives backoff).
    pub attempts: u32,
    /// Consecutive failed `STATS` polls while Up.
    pub stats_failures: u32,
}

/// Read-only view of a slot for STATS reporting.
#[derive(Clone, Debug)]
pub struct SlotView {
    pub up: bool,
    pub addr: Option<SocketAddr>,
    pub sessions: usize,
    pub queue_depth: u64,
    pub tokens_relayed: u64,
    pub restarts: u64,
    /// Consecutive relaunches without a surviving poll (backoff driver).
    pub attempts: u32,
}

struct Inner {
    slots: Vec<Slot>,
    /// Round-robin cursor for tie-breaking among equally-loaded workers.
    rr: usize,
}

/// Shared fleet state (balancer + health thread + proxy threads).
pub struct Fleet {
    inner: Mutex<Inner>,
    backoff_base: Duration,
    backoff_cap: Duration,
}

impl Fleet {
    pub fn new(n: usize, backoff_base: Duration, backoff_cap: Duration) -> Fleet {
        let slots = (0..n)
            .map(|_| Slot {
                // placeholder until the first launch reports in
                state: SlotState::Down { next_attempt: Instant::now() },
                sessions: 0,
                queue_depth: 0,
                inflight: 0,
                tokens_relayed: 0,
                restarts: 0,
                attempts: 0,
                stats_failures: 0,
            })
            .collect();
        Fleet {
            inner: Mutex::new(Inner { slots, rr: 0 }),
            backoff_base,
            backoff_cap,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn healthy(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .slots
            .iter()
            .filter(|s| matches!(s.state, SlotState::Up { .. }))
            .count()
    }

    /// Worker `idx` is serving on `addr`.  `initial` distinguishes the
    /// fleet boot from a crash recovery (which counts as a restart).
    ///
    /// Deliberately does **not** reset the backoff counter: a relaunch
    /// that merely announces proves nothing — a crash-looping worker
    /// (boots, then dies instantly) would otherwise restart in a tight
    /// loop at `backoff_base` forever.  `attempts` resets in
    /// [`Fleet::record_poll`], i.e. only once the worker survives its
    /// first successful post-restart health poll.
    pub fn mark_up(&self, idx: usize, addr: SocketAddr, initial: bool) {
        let mut inner = self.inner.lock().unwrap();
        let s = &mut inner.slots[idx];
        s.state = SlotState::Up { addr };
        s.stats_failures = 0;
        s.queue_depth = 0;
        s.inflight = 0;
        if !initial {
            s.restarts += 1;
        }
    }

    /// Worker `idx` died (or a relaunch failed): schedule the next
    /// attempt with exponential backoff `base * 2^attempts`, capped.
    /// Returns the delay chosen, for logging.
    pub fn mark_down(&self, idx: usize) -> Duration {
        let mut inner = self.inner.lock().unwrap();
        self.down_slot(&mut inner.slots[idx])
    }

    /// Declare `idx` down only if it is still `Up` on `addr`.  The
    /// relay's view of a worker can be stale — between losing the
    /// connection and reporting it, the health loop may have already
    /// declared the death and restarted the slot on a new address.  The
    /// guard makes the relay's report a no-op in that race instead of
    /// downing a freshly restarted worker.  Returns whether the
    /// transition happened.
    pub fn mark_down_if_up_on(&self, idx: usize, addr: SocketAddr) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.slots[idx].state {
            SlotState::Up { addr: cur } if cur == addr => {
                self.down_slot(&mut inner.slots[idx]);
                true
            }
            _ => false,
        }
    }

    fn down_slot(&self, s: &mut Slot) -> Duration {
        let exp = s.attempts.min(16);
        let backoff = self
            .backoff_base
            .saturating_mul(1u32 << exp)
            .min(self.backoff_cap);
        s.state = SlotState::Down { next_attempt: Instant::now() + backoff };
        s.attempts = s.attempts.saturating_add(1);
        s.stats_failures = 0;
        backoff
    }

    /// Down slots whose backoff has expired — candidates for relaunch.
    pub fn due_for_restart(&self, now: Instant) -> Vec<usize> {
        let inner = self.inner.lock().unwrap();
        inner
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s.state {
                SlotState::Down { next_attempt } if next_attempt <= now => Some(i),
                _ => None,
            })
            .collect()
    }

    /// Record a successful `STATS` poll of worker `idx`.  Answering a
    /// poll is the proof-of-life that ends a restart's probation: the
    /// backoff schedule (`attempts`) resets here rather than at
    /// [`Fleet::mark_up`], so a worker that announces and immediately
    /// dies keeps escalating its backoff instead of crash-looping at
    /// `backoff_base`.
    pub fn record_poll(&self, idx: usize, queue_depth: u64, inflight: u64) {
        let mut inner = self.inner.lock().unwrap();
        let s = &mut inner.slots[idx];
        s.queue_depth = queue_depth;
        s.inflight = inflight;
        s.stats_failures = 0;
        s.attempts = 0;
    }

    /// Record a failed `STATS` poll; returns the consecutive-failure
    /// count so the health loop can decide when to declare death.
    pub fn record_poll_failure(&self, idx: usize) -> u32 {
        let mut inner = self.inner.lock().unwrap();
        let s = &mut inner.slots[idx];
        s.stats_failures = s.stats_failures.saturating_add(1);
        s.stats_failures
    }

    /// Pick the least-loaded healthy worker and reserve a session slot
    /// on it.  Score = router-placed sessions + polled queue depth; ties
    /// break round-robin from a rotating cursor.  `None` when no worker
    /// is up.  The caller owns the reservation and must pair it with
    /// [`Fleet::complete`].
    pub fn place(&self) -> Option<(usize, SocketAddr)> {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.slots.len();
        if n == 0 {
            return None;
        }
        let start = inner.rr % n;
        let mut best: Option<(usize, SocketAddr, u64)> = None;
        for off in 0..n {
            let i = (start + off) % n;
            let s = &inner.slots[i];
            if let SlotState::Up { addr } = s.state {
                let score = s.sessions as u64 + s.queue_depth;
                // strict < keeps the first (cursor-closest) minimum — the
                // round-robin tie-break
                if best.map(|(_, _, b)| score < b).unwrap_or(true) {
                    best = Some((i, addr, score));
                }
            }
        }
        let (idx, addr, _) = best?;
        inner.slots[idx].sessions += 1;
        inner.rr = (idx + 1) % n;
        Some((idx, addr))
    }

    /// A session placed on `idx` finished (any terminal outcome);
    /// `tokens` were relayed through it.
    pub fn complete(&self, idx: usize, tokens: u64) {
        let mut inner = self.inner.lock().unwrap();
        let s = &mut inner.slots[idx];
        s.sessions = s.sessions.saturating_sub(1);
        s.tokens_relayed += tokens;
    }

    pub fn addr(&self, idx: usize) -> Option<SocketAddr> {
        let inner = self.inner.lock().unwrap();
        match inner.slots[idx].state {
            SlotState::Up { addr } => Some(addr),
            SlotState::Down { .. } => None,
        }
    }

    pub fn views(&self) -> Vec<SlotView> {
        let inner = self.inner.lock().unwrap();
        inner
            .slots
            .iter()
            .map(|s| SlotView {
                up: matches!(s.state, SlotState::Up { .. }),
                addr: match s.state {
                    SlotState::Up { addr } => Some(addr),
                    SlotState::Down { .. } => None,
                },
                sessions: s.sessions,
                queue_depth: s.queue_depth,
                tokens_relayed: s.tokens_relayed,
                restarts: s.restarts,
                attempts: s.attempts,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        SocketAddr::from(([127, 0, 0, 1], port))
    }

    fn fleet(n: usize) -> Fleet {
        let f = Fleet::new(n, Duration::from_millis(10), Duration::from_millis(500));
        for i in 0..n {
            f.mark_up(i, addr(9000 + i as u16), true);
        }
        f
    }

    #[test]
    fn sequential_sessions_spread_round_robin() {
        // equal scores: the cursor must rotate, not camp on worker 0 —
        // this is what makes the CI "tokens on >= 2 workers" gate pass
        // even for a strictly sequential client
        let f = fleet(3);
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                let (i, _) = f.place().unwrap();
                f.complete(i, 1); // session done before the next arrives
                i
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn placement_prefers_least_loaded() {
        let f = fleet(3);
        // three concurrent sessions: one per worker
        let a = f.place().unwrap().0;
        let b = f.place().unwrap().0;
        let c = f.place().unwrap().0;
        assert_eq!(
            {
                let mut v = vec![a, b, c];
                v.sort();
                v
            },
            vec![0, 1, 2]
        );
        // finish worker b's session: the next placement must land there
        f.complete(b, 5);
        assert_eq!(f.place().unwrap().0, b);
    }

    #[test]
    fn polled_queue_depth_steers_placement() {
        let f = fleet(2);
        // worker 0 reports a deep queue (e.g. direct-connected clients
        // the router can't see): placement must avoid it
        f.record_poll(0, 10, 4);
        for _ in 0..3 {
            let (i, _) = f.place().unwrap();
            assert_eq!(i, 1);
            f.complete(i, 0);
        }
    }

    #[test]
    fn down_workers_are_never_placed() {
        let f = fleet(2);
        f.mark_down(0);
        for _ in 0..4 {
            let (i, a) = f.place().unwrap();
            assert_eq!(i, 1);
            assert_eq!(a, addr(9001));
            f.complete(i, 0);
        }
        f.mark_down(1);
        assert!(f.place().is_none(), "no healthy worker => no placement");
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let f = Fleet::new(1, Duration::from_millis(10), Duration::from_millis(45));
        assert_eq!(f.mark_down(0), Duration::from_millis(10));
        assert_eq!(f.mark_down(0), Duration::from_millis(20));
        assert_eq!(f.mark_down(0), Duration::from_millis(40));
        assert_eq!(f.mark_down(0), Duration::from_millis(45), "capped");
        assert_eq!(f.mark_down(0), Duration::from_millis(45));
        // a relaunch that merely announces counts a restart but does NOT
        // reset the schedule: if it dies again the backoff keeps growing
        f.mark_up(0, addr(9000), false);
        assert_eq!(f.views()[0].restarts, 1);
        assert_eq!(f.mark_down(0), Duration::from_millis(45), "still capped");
        // only surviving a health poll ends probation
        f.mark_up(0, addr(9000), false);
        f.record_poll(0, 0, 0);
        assert_eq!(f.mark_down(0), Duration::from_millis(10));
    }

    #[test]
    fn crash_loop_announce_without_poll_keeps_escalating() {
        // regression: mark_up used to reset `attempts`, so a worker that
        // boots and dies instantly retried at backoff_base forever
        let f = Fleet::new(1, Duration::from_millis(10), Duration::from_secs(60));
        let mut backoffs = Vec::new();
        for _ in 0..4 {
            backoffs.push(f.mark_down(0));
            f.mark_up(0, addr(9000), false); // announces...
                                             // ...and dies before any poll
        }
        assert_eq!(
            backoffs,
            vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(40),
                Duration::from_millis(80),
            ],
            "backoff must escalate across announce-then-die cycles"
        );
        assert_eq!(f.views()[0].attempts, 4);
    }

    #[test]
    fn mark_down_if_up_on_is_addr_guarded() {
        let f = fleet(1);
        let stale = addr(9999);
        assert!(!f.mark_down_if_up_on(0, stale), "wrong addr: no-op");
        assert_eq!(f.healthy(), 1);
        assert!(f.mark_down_if_up_on(0, addr(9000)));
        assert_eq!(f.healthy(), 0);
        // already down: a second (racing) report is a no-op too
        assert!(!f.mark_down_if_up_on(0, addr(9000)));
        assert_eq!(f.views()[0].attempts, 1, "one transition, one attempt");
    }

    #[test]
    fn due_for_restart_respects_next_attempt() {
        let f = Fleet::new(2, Duration::from_secs(60), Duration::from_secs(60));
        f.mark_up(0, addr(9000), true);
        f.mark_up(1, addr(9001), true);
        f.mark_down(0);
        // worker 0's first retry is 60s out: not due now
        assert!(f.due_for_restart(Instant::now()).is_empty());
        assert_eq!(
            f.due_for_restart(Instant::now() + Duration::from_secs(120)),
            vec![0]
        );
    }

    #[test]
    fn poll_failures_count_consecutively_and_reset() {
        let f = fleet(1);
        assert_eq!(f.record_poll_failure(0), 1);
        assert_eq!(f.record_poll_failure(0), 2);
        f.record_poll(0, 0, 0); // a good poll resets the streak
        assert_eq!(f.record_poll_failure(0), 1);
    }
}
