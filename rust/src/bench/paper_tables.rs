//! Analytic paper tables/figures (the ones derivable from the memory and
//! energy models alone).  Shared by `bmoe tables` and the bench targets;
//! each function prints paper-style rows and writes a CSV.

use std::path::Path;

use anyhow::Result;

use crate::bench::Table;
use crate::devices::ALL_DEVICES;
use crate::energy::table3_row;
use crate::memmodel::{
    asymptotic_ratio, butterfly_bytes, per_expert_bytes, substrate_bytes, LayerShape, Method,
    ALL_METHODS,
};
use crate::util::human_bytes;

const MIB: f64 = 1024.0 * 1024.0;

/// Table 1: compression comparison at 64 experts (d=512, d_ff=2048).
pub fn table1(out: &Path) -> Result<Table> {
    let s = LayerShape::paper();
    let n = 64;
    let mut t = Table::new(
        "Table 1 — MoE compression methods (64 experts, d=512, d_ff=2048)",
        &["Method", "Memory Scaling", "Compression (64)", "Edge Deployment"],
    );
    for m in ALL_METHODS {
        t.row(&[
            m.name().to_string(),
            m.scaling().to_string(),
            format!("{:.1}x", m.ratio(n, s)),
            human_bytes(m.bytes(n, s)),
        ]);
    }
    t.print();
    t.write_csv(&out.join("table1_compression.csv"))?;
    println!(
        "  (Prop. 1 formula at 64 experts: substrate {} + 64 x {} angles = {}; paper prints 1.9 MB / '150x')",
        human_bytes(substrate_bytes(s)),
        human_bytes(per_expert_bytes(s)),
        human_bytes(butterfly_bytes(64, s)),
    );
    Ok(t)
}

/// Device deployability table: max experts per device per method.
pub fn table_devices(out: &Path) -> Result<Table> {
    let s = LayerShape::paper();
    let mut t = Table::new(
        "Table (devices) — max experts within device memory budget",
        &["Method", "RPi 5", "Jetson", "ESP32"],
    );
    for m in [
        Method::StandardMoe,
        Method::Qmoe,
        Method::Moqe,
        Method::ButterflyMoe,
    ] {
        let cells: Vec<String> = ALL_DEVICES
            .iter()
            .map(|d| d.max_experts(m, s).to_string())
            .collect();
        t.row(&[
            m.name().to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
        ]);
    }
    t.print();
    t.write_csv(&out.join("table_devices.csv"))?;
    Ok(t)
}

/// Table 3: energy per inference across expert counts.
pub fn table3(out: &Path) -> Result<Table> {
    let s = LayerShape::paper();
    let mut t = Table::new(
        "Table 3 — energy cost per inference (d=512, d_ff=2048, top-2)",
        &["Experts", "Standard MoE (nJ)", "ButterflyMoE (nJ)", "Savings (%)"],
    );
    for n in [8usize, 16, 32, 64, 128, 256] {
        let r = table3_row(n, 2, s);
        t.row(&[
            n.to_string(),
            format!("{:.2}", r.standard_nj),
            format!("{:.2}", r.butterfly_nj),
            format!("{:.1}", r.savings_pct),
        ]);
    }
    t.print();
    t.write_csv(&out.join("table3_energy.csv"))?;
    Ok(t)
}

/// Fig. 3: memory vs expert count series (MB), standard vs butterfly.
pub fn fig3(out: &Path) -> Result<Table> {
    let s = LayerShape::paper();
    let mut t = Table::new(
        "Fig. 3 — memory vs expert count (d=512, d_ff=2048)",
        &["Experts", "Standard (MB)", "ButterflyMoE (MB)", "Ratio"],
    );
    let mut n = 8usize;
    while n <= 1024 {
        t.row(&[
            n.to_string(),
            format!("{:.2}", Method::StandardMoe.bytes(n, s) / MIB),
            format!("{:.3}", butterfly_bytes(n, s) / MIB),
            format!("{:.1}x", Method::ButterflyMoe.ratio(n, s)),
        ]);
        n *= 2;
    }
    t.print();
    println!(
        "  asymptotic ratio (Prop. 2): {:.1}x",
        asymptotic_ratio(s)
    );
    t.write_csv(&out.join("fig3_memory.csv"))?;
    Ok(t)
}

/// Print everything (the `bmoe tables` command).
pub fn print_all(out: &Path) -> Result<()> {
    std::fs::create_dir_all(out)?;
    table1(out)?;
    table_devices(out)?;
    table3(out)?;
    fig3(out)?;
    println!("\nCSV output in {}", out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_render() {
        let dir = std::env::temp_dir().join("bmoe_tables_test");
        print_all(&dir).unwrap();
        for f in [
            "table1_compression.csv",
            "table_devices.csv",
            "table3_energy.csv",
            "fig3_memory.csv",
        ] {
            assert!(dir.join(f).exists(), "{f}");
        }
    }

    #[test]
    fn table1_butterfly_row_dominates() {
        let dir = std::env::temp_dir().join("bmoe_tables_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let t = table1(&dir).unwrap();
        let _ = t;
    }
}
