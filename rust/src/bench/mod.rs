//! Micro-benchmark harness (criterion is not in the offline vendor set —
//! DESIGN.md §4).  Warmup + calibrated iteration count + robust stats,
//! plus the table printers every paper-table bench target uses.

pub mod paper_tables;

use std::time::Instant;

use crate::util::stats;

/// One benchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// per-iteration wall seconds
    pub samples: Vec<f64>,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn mean_secs(&self) -> f64 {
        stats::mean(&self.samples)
    }
    pub fn p95_secs(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }
    pub fn stddev_secs(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    /// items/sec given items processed per iteration (e.g. tokens).
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median_secs()
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12} {:>12} {:>10}",
            self.name,
            format_secs(self.median_secs()),
            format_secs(self.p95_secs()),
            format!("±{:.1}%", 100.0 * self.stddev_secs() / self.median_secs().max(1e-12)),
        )
    }
}

pub fn format_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Benchmark runner: calibrates an iteration count so each sample takes
/// ≥ `min_sample_secs`, then records `n_samples` samples.
pub struct Bencher {
    pub warmup_secs: f64,
    pub min_sample_secs: f64,
    pub n_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_secs: 0.2,
            min_sample_secs: 0.05,
            n_samples: 12,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_secs: 0.05,
            min_sample_secs: 0.02,
            n_samples: 6,
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0usize;
        while t0.elapsed().as_secs_f64() < self.warmup_secs {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.min_sample_secs / per_iter).ceil() as usize).max(1);

        let mut samples = Vec::with_capacity(self.n_samples);
        for _ in 0..self.n_samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        BenchResult {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        }
    }
}

/// Black-box: defeat dead-code elimination of a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Table printing (paper-style rows)
// ---------------------------------------------------------------------------

/// Fixed-width markdown-ish table writer used by all paper-table benches.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n## {}", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("| {} |", cells.join(" | "));
        }
    }

    /// CSV alongside the pretty print (for EXPERIMENTS.md tooling).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher {
            warmup_secs: 0.01,
            min_sample_secs: 0.002,
            n_samples: 4,
        };
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.median_secs() > 0.0);
        assert_eq!(r.samples.len(), 4);
        assert!(r.throughput(1000.0) > 0.0);
    }

    #[test]
    fn format_secs_units() {
        assert!(format_secs(2e-9).contains("ns"));
        assert!(format_secs(2e-6).contains("µs"));
        assert!(format_secs(2e-3).contains("ms"));
        assert!(format_secs(2.0).contains(" s"));
    }

    #[test]
    fn table_prints_and_csvs() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(&["1".into(), "x".into()]);
        t.print();
        let p = std::env::temp_dir().join("bmoe_table_test.csv");
        t.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,x\n");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
