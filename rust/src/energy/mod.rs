//! Energy model for Table 3 (energy per inference vs expert count).
//!
//! Two components, both first-order models with published coefficients:
//!
//! * **Memory energy** — DRAM traffic × pJ/bit (the paper cites 6.4
//!   pJ/bit from Horowitz ISSCC'14).  §3.2-F2 frames standard MoE as
//!   bandwidth-bound because every resident expert's weights stream from
//!   DRAM; ButterflyMoE streams the shared ternary substrate once plus
//!   the k active experts' tiny angle tables.
//! * **Compute energy** — op counts × per-op energy (Horowitz 45 nm:
//!   FP32 mult 3.7 pJ, FP32 add 0.9 pJ, INT8 add 0.03 pJ).  The ternary
//!   substrate multiply is add/sub-only (Prop. 3's "~10x lower energy
//!   per operation").

use crate::memmodel::LayerShape;

/// Per-operation energies in picojoules (Horowitz, ISSCC 2014, 45 nm).
pub mod ops {
    pub const FP32_ADD: f64 = 0.9;
    pub const FP32_MULT: f64 = 3.7;
    pub const FP16_ADD: f64 = 0.4;
    pub const FP16_MULT: f64 = 1.1;
    pub const INT8_ADD: f64 = 0.03;
    /// DRAM access energy per bit (the paper's cited constant).
    pub const DRAM_PJ_PER_BIT: f64 = 6.4;
}

/// Breakdown of one forward pass's energy in nanojoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub dram_nj: f64,
    pub compute_nj: f64,
}

impl EnergyBreakdown {
    pub fn total_nj(&self) -> f64 {
        self.dram_nj + self.compute_nj
    }
}

/// Standard MoE, one token, `n` resident experts, top-k active.
///
/// Weight traffic: all `n` expert matrices stream from DRAM (the paper's
/// F2 bandwidth-wall model — no reuse across tokens is assumed for the
/// single-token inference it analyzes).  Compute: k dense GEMVs.
pub fn standard_moe_energy(n: usize, k: usize, s: LayerShape) -> EnergyBreakdown {
    let weights = (s.d_model * s.d_ff) as f64;
    let bits_moved = n as f64 * weights * 32.0;
    let dram_pj = bits_moved * ops::DRAM_PJ_PER_BIT;
    let macs = k as f64 * weights;
    let compute_pj = macs * (ops::FP32_MULT + ops::FP32_ADD);
    EnergyBreakdown {
        dram_nj: dram_pj / 1e3,
        compute_nj: compute_pj / 1e3,
    }
}

/// ButterflyMoE, one token, `n` resident experts, top-k active.
///
/// Weight traffic: the 1.58-bit substrate once + the k active experts'
/// FP16 angle tables.  Compute: k × (two butterfly stacks of FP32
/// rotations + one ternary GEMV of add/sub at INT-add cost).
pub fn butterfly_moe_energy(n: usize, k: usize, s: LayerShape) -> EnergyBreakdown {
    let _ = n; // substrate is shared: resident expert count doesn't add traffic
    let substrate_bits = (s.d_model * s.d_ff) as f64 * 1.58;
    let angle_bits = k as f64 * crate::memmodel::per_expert_bytes(s) * 8.0;
    let dram_pj = (substrate_bits + angle_bits) * ops::DRAM_PJ_PER_BIT;

    let rot_pairs = (s.d_model as f64 / 2.0) * (s.d_model as f64).log2()
        + (s.d_ff as f64 / 2.0) * (s.d_ff as f64).log2();
    // one Givens pair = 4 mults + 2 adds (FP32)
    let rot_pj = k as f64 * rot_pairs * (4.0 * ops::FP32_MULT + 2.0 * ops::FP32_ADD);
    // ternary GEMV: ~2/3 of weights non-zero -> adds only
    let tern_adds = k as f64 * (s.d_model * s.d_ff) as f64 * (2.0 / 3.0);
    let tern_pj = tern_adds * ops::FP32_ADD; // accumulate in fp32
    EnergyBreakdown {
        dram_nj: dram_pj / 1e3,
        compute_nj: (rot_pj + tern_pj) / 1e3,
    }
}

/// One Table 3 row.
#[derive(Clone, Copy, Debug)]
pub struct EnergyRow {
    pub n_experts: usize,
    pub standard_nj: f64,
    pub butterfly_nj: f64,
    pub savings_pct: f64,
}

pub fn table3_row(n: usize, k: usize, s: LayerShape) -> EnergyRow {
    let std = standard_moe_energy(n, k, s).total_nj();
    let bf = butterfly_moe_energy(n, k, s).total_nj();
    EnergyRow {
        n_experts: n,
        standard_nj: std,
        butterfly_nj: bf,
        savings_pct: 100.0 * (1.0 - bf / std),
    }
}

/// Energy for a memory-bound forward at a given *stored* footprint —
/// used for the "99.5% memory bandwidth energy reduction" abstract claim.
pub fn streaming_energy_nj(bytes: f64, pj_per_bit: f64) -> f64 {
    bytes * 8.0 * pj_per_bit / 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::{butterfly_bytes, Method};

    const S: LayerShape = LayerShape::paper();

    #[test]
    fn standard_energy_linear_in_experts() {
        let e8 = standard_moe_energy(8, 2, S).total_nj();
        let e16 = standard_moe_energy(16, 2, S).total_nj();
        let e256 = standard_moe_energy(256, 2, S).total_nj();
        // DRAM dominates, so ~2x per doubling (paper Table 3 doubles
        // exactly: 320 -> 640 -> ... -> 10240)
        assert!((e16 / e8 - 2.0).abs() < 0.3, "{}", e16 / e8);
        assert!(e256 / e8 > 20.0);
    }

    #[test]
    fn butterfly_energy_nearly_flat_in_experts() {
        let e8 = butterfly_moe_energy(8, 2, S).total_nj();
        let e256 = butterfly_moe_energy(256, 2, S).total_nj();
        assert!((e256 / e8 - 1.0).abs() < 1e-9); // resident count free
    }

    #[test]
    fn savings_match_paper_shape() {
        // paper: 98.7% at 8 experts rising to 99.3% at 64+
        let r8 = table3_row(8, 2, S);
        let r64 = table3_row(64, 2, S);
        let r256 = table3_row(256, 2, S);
        assert!(r8.savings_pct > 90.0, "{}", r8.savings_pct);
        assert!(r64.savings_pct > r8.savings_pct);
        assert!(r256.savings_pct > 99.0, "{}", r256.savings_pct);
    }

    #[test]
    fn dram_dominates_standard() {
        let e = standard_moe_energy(64, 2, S);
        assert!(e.dram_nj > 5.0 * e.compute_nj);
    }

    #[test]
    fn abstract_bandwidth_claim() {
        // "up to 99.5% memory bandwidth energy reduction": streaming the
        // ButterflyMoE footprint at 256 experts vs the standard footprint
        let std = streaming_energy_nj(Method::StandardMoe.bytes(256, S), ops::DRAM_PJ_PER_BIT);
        let bf = streaming_energy_nj(butterfly_bytes(256, S), ops::DRAM_PJ_PER_BIT);
        let red = 100.0 * (1.0 - bf / std);
        assert!(red > 99.0, "{red}");
    }
}
