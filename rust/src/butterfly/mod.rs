//! Native butterfly transforms — the L3 mirror of
//! `python/compile/butterfly_lib.py` (same angle layout, same stage
//! order; parity-tested against the jax oracle through PJRT).
//!
//! A transform over `d = 2^m` is `depth <= m` Givens stages; stage `l`
//! (stride `s = 2^l`) pairs coordinates `(lo, lo + s)` where
//! `lo = blk*2s + off` for angle index `j = blk*s + off`.
//!
//! `apply` runs in O(d·depth) with two fused multiply-adds per pair — the
//! paper's O(d log d) expert-synthesis primitive.  Angles are stored with
//! precomputed (cos, sin) so the hot path does no trig.  Batched applies
//! route through the stage-outer blocked kernel
//! ([`crate::kernels::butterfly_apply_blocked`], §Perf iteration 6),
//! which is bit-identical to the per-row walk by construction.
//!
//! Both the raw angles and the serving (cos, sin) table live in
//! [`SharedSlice`] storage: owned for transforms built in memory, or
//! borrowed straight from a model artifact's mapping
//! ([`Butterfly::from_shared`], DESIGN.md §3) — loading a packed model
//! does no trig and no table copy, and serves bit-identically to the
//! in-memory transform the packer wrote.

use crate::artifact::SharedSlice;
use crate::util::{log2_exact, Rng};

/// Butterfly parameters: raw angles plus a (cos, sin) table kept in
/// lockstep.  `d/2 * depth` angles — eq. (3)'s storage; the table is
/// interleaved `[cos0, sin0, cos1, sin1, …]` with the same indexing.
#[derive(Clone, Debug)]
pub struct Butterfly {
    pub d: usize,
    pub depth: usize,
    /// angles[l][j], layout as documented above; len = depth * d/2
    angles: SharedSlice<f32>,
    /// interleaved (cos, sin) per angle; len = depth * d
    cs: SharedSlice<f32>,
}

impl Butterfly {
    pub fn max_depth(d: usize) -> usize {
        log2_exact(d) as usize
    }

    /// Identity transform (all angles zero).
    pub fn identity(d: usize, depth: usize) -> Self {
        assert!(depth >= 1 && depth <= Self::max_depth(d).max(1));
        let n = depth * d / 2;
        let mut cs = Vec::with_capacity(2 * n);
        for _ in 0..n {
            cs.push(1.0);
            cs.push(0.0);
        }
        Butterfly {
            d,
            depth,
            angles: SharedSlice::owned(vec![0.0; n]),
            cs: SharedSlice::owned(cs),
        }
    }

    /// Near-identity random init, eq. (7): angles ~ N(0, std^2).
    pub fn random(d: usize, depth: usize, std: f32, rng: &mut Rng) -> Self {
        let mut angles = vec![0.0f32; depth * d / 2];
        rng.fill_normal(&mut angles, std);
        Self::from_angle_vec(d, depth, angles)
    }

    /// Build from an angle slice laid out [depth, d/2] row-major (the
    /// layout of the exported `theta`/`phi` tensors).
    pub fn from_angles(d: usize, depth: usize, angles: &[f32]) -> Self {
        Self::from_angle_vec(d, depth, angles.to_vec())
    }

    fn from_angle_vec(d: usize, depth: usize, angles: Vec<f32>) -> Self {
        assert_eq!(angles.len(), depth * d / 2, "angle count mismatch");
        let cs = Self::cs_from(&angles);
        Butterfly {
            d,
            depth,
            angles: SharedSlice::owned(angles),
            cs: SharedSlice::owned(cs),
        }
    }

    /// Build from shared storage — the model-artifact loader's path
    /// (DESIGN.md §3): `angles` is the raw [depth, d/2] table, `cs` the
    /// precomputed interleaved (cos, sin) serving table, both typically
    /// borrowed from the file mapping.  No trig happens here, so the
    /// transform reproduces exactly the table the packer wrote.
    pub fn from_shared(
        d: usize,
        depth: usize,
        angles: SharedSlice<f32>,
        cs: SharedSlice<f32>,
    ) -> Self {
        assert_eq!(angles.len(), depth * d / 2, "angle count mismatch");
        assert_eq!(cs.len(), depth * d, "(cos, sin) table length mismatch");
        Butterfly { d, depth, angles, cs }
    }

    fn cs_from(angles: &[f32]) -> Vec<f32> {
        let mut cs = Vec::with_capacity(2 * angles.len());
        for &a in angles {
            cs.push(a.cos());
            cs.push(a.sin());
        }
        cs
    }

    /// The raw angle table (empty-free: always `depth * d/2` values).
    pub fn angles(&self) -> &[f32] {
        self.angles.as_slice()
    }

    /// Replace the angles and recompute the (cos, sin) table (training /
    /// test mutation; the result is always owned storage).
    pub fn set_angles(&mut self, angles: Vec<f32>) {
        assert_eq!(angles.len(), self.depth * self.d / 2, "angle count mismatch");
        self.cs = SharedSlice::owned(Self::cs_from(&angles));
        self.angles = SharedSlice::owned(angles);
    }

    /// The interleaved `[cos, sin]` serving table (what the packer
    /// writes as `*_cs` and the blocked kernel reads).
    pub fn cs_table(&self) -> &[f32] {
        self.cs.as_slice()
    }

    /// True when the tables are borrowed from a model mapping rather
    /// than owned (the zero-copy load path).
    pub fn is_shared(&self) -> bool {
        self.cs.is_borrowed()
    }

    /// Parameter count (what Table 2's "Params/Expert" counts per transform).
    pub fn n_params(&self) -> usize {
        self.depth * self.d / 2
    }

    /// Bytes when angles are stored FP16 (Prop. 1 memory accounting).
    pub fn bytes_fp16(&self) -> usize {
        self.n_params() * 2
    }

    /// In-place forward apply to one vector `x[d]`: x <- B x.
    pub fn apply(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d);
        for l in 0..self.depth {
            self.stage(x, l, false);
        }
    }

    /// In-place transpose (= inverse) apply: x <- B^T x.
    pub fn apply_transpose(&self, x: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d);
        for l in (0..self.depth).rev() {
            self.stage(x, l, true);
        }
    }

    #[inline]
    fn stage(&self, x: &mut [f32], l: usize, transpose: bool) {
        let stride = 1usize << l;
        let cs = self.cs.as_slice();
        // stage l's interleaved table slice: d/2 (cos, sin) pairs
        let table = &cs[l * self.d..(l + 1) * self.d];
        let mut j = 0;
        let mut base = 0;
        // blocks of 2*stride; within a block, `stride` adjacent pairs
        while base < self.d {
            for off in 0..stride {
                let lo = base + off;
                let hi = lo + stride;
                let (c, s0) = (table[2 * j], table[2 * j + 1]);
                let s = if transpose { -s0 } else { s0 };
                let a = x[lo];
                let b = x[hi];
                x[lo] = c * a - s * b;
                x[hi] = s * a + c * b;
                j += 1;
            }
            base += 2 * stride;
        }
    }

    /// Batched apply over rows of a (rows, d) matrix — the stage-outer
    /// blocked kernel (§Perf iteration 6): each stage's (cos, sin) table
    /// is read once per row block and the per-pair FMAs vectorize across
    /// rows.  Bit-identical to applying [`Self::apply`] per row (see
    /// [`crate::kernels::butterfly_apply_blocked`]); the per-row walk is
    /// retained as [`Self::apply_batch_per_row`] for the ablation.
    ///
    /// Allocates a fresh transpose scratch; hot paths should hold one
    /// and call [`Self::apply_batch_with`].
    pub fn apply_batch(&self, x: &mut [f32]) {
        self.apply_batch_with(x, &mut Vec::new());
    }

    pub fn apply_transpose_batch(&self, x: &mut [f32]) {
        self.apply_transpose_batch_with(x, &mut Vec::new());
    }

    /// [`Self::apply_batch`] with caller-retained transpose scratch
    /// (resized to at most `d * RB` floats — [`crate::kernels::RB`] —
    /// and reused: zero steady-state allocation).
    pub fn apply_batch_with(&self, x: &mut [f32], scratch: &mut Vec<f32>) {
        assert_eq!(x.len() % self.d, 0);
        if x.len() == self.d {
            return self.apply(x); // single row: skip the transpose round-trip
        }
        crate::kernels::butterfly_apply_blocked(
            self.cs.as_slice(),
            self.d,
            self.depth,
            false,
            x,
            scratch,
        );
    }

    /// [`Self::apply_transpose_batch`] with caller-retained scratch.
    pub fn apply_transpose_batch_with(&self, x: &mut [f32], scratch: &mut Vec<f32>) {
        assert_eq!(x.len() % self.d, 0);
        if x.len() == self.d {
            return self.apply_transpose(x);
        }
        crate::kernels::butterfly_apply_blocked(
            self.cs.as_slice(),
            self.d,
            self.depth,
            true,
            x,
            scratch,
        );
    }

    /// Reference batched apply: one row at a time through
    /// [`Self::apply`], re-streaming the full table per row.  Kept for
    /// the §Perf old-vs-new ablation in `benches/hotpath.rs` and the
    /// bit-identity property tests.
    pub fn apply_batch_per_row(&self, x: &mut [f32]) {
        assert_eq!(x.len() % self.d, 0);
        for row in x.chunks_exact_mut(self.d) {
            self.apply(row);
        }
    }

    /// Reference batched transpose apply (see [`Self::apply_batch_per_row`]).
    pub fn apply_transpose_batch_per_row(&self, x: &mut [f32]) {
        assert_eq!(x.len() % self.d, 0);
        for row in x.chunks_exact_mut(self.d) {
            self.apply_transpose(row);
        }
    }

    /// Materialize the dense matrix (tests/analysis only).
    pub fn to_matrix(&self) -> Vec<f32> {
        let d = self.d;
        let mut m = vec![0.0f32; d * d];
        for col in 0..d {
            let mut e = vec![0.0f32; d];
            e[col] = 1.0;
            self.apply(&mut e);
            for row in 0..d {
                m[row * d + col] = e[row];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_bfly(d: usize, depth: usize, seed: u64) -> Butterfly {
        let mut rng = Rng::new(seed);
        Butterfly::random(d, depth, 0.7, &mut rng)
    }

    #[test]
    fn identity_is_noop() {
        let b = Butterfly::identity(8, 3);
        let mut x = vec![1., 2., 3., 4., 5., 6., 7., 8.];
        let orig = x.clone();
        b.apply(&mut x);
        assert_eq!(x, orig);
    }

    #[test]
    fn transpose_inverts() {
        for d in [2usize, 4, 16, 64, 512] {
            let b = rand_bfly(d, Butterfly::max_depth(d), d as u64);
            let mut rng = Rng::new(99);
            let mut x: Vec<f32> = (0..d).map(|_| rng.normal_f32(1.0)).collect();
            let orig = x.clone();
            b.apply(&mut x);
            b.apply_transpose(&mut x);
            for (a, o) in x.iter().zip(&orig) {
                assert!((a - o).abs() < 1e-4, "d={d}");
            }
        }
    }

    #[test]
    fn preserves_norm() {
        let d = 64;
        let b = rand_bfly(d, 6, 5);
        let mut rng = Rng::new(1);
        let mut x: Vec<f32> = (0..d).map(|_| rng.normal_f32(2.0)).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        b.apply(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
    }

    #[test]
    fn matrix_is_orthogonal() {
        let d = 16;
        let b = rand_bfly(d, 4, 7);
        let m = b.to_matrix();
        // M M^T = I
        for i in 0..d {
            for j in 0..d {
                let mut acc = 0.0f32;
                for k in 0..d {
                    acc += m[i * d + k] * m[j * d + k];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((acc - want).abs() < 1e-5, "({i},{j})={acc}");
            }
        }
    }

    #[test]
    fn truncated_depth_param_count() {
        // Table 2: d=512, both transforms counted at d=512 ->
        // params/expert = 2 * depth * 256
        for (depth, want) in [(2usize, 1024usize), (4, 2048), (6, 3072), (9, 4608)] {
            let b = Butterfly::identity(512, depth);
            assert_eq!(2 * b.n_params(), want);
        }
    }

    #[test]
    fn single_stage_stride_one_rotates_adjacent_pairs() {
        let mut b = Butterfly::identity(4, 1);
        let mut a = b.angles().to_vec();
        a[0] = std::f32::consts::FRAC_PI_2; // rotate pair (0,1) by 90°
        b.set_angles(a);
        let mut x = vec![1.0, 0.0, 1.0, 0.0];
        b.apply(&mut x);
        // pair (0,1): (1,0) -> (0,1); pair (2,3) untouched angle=0
        assert!((x[0] - 0.0).abs() < 1e-6 && (x[1] - 1.0).abs() < 1e-6);
        assert!((x[2] - 1.0).abs() < 1e-6 && (x[3] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn stage_stride_two_pairs_across() {
        let mut b = Butterfly::identity(4, 2);
        // zero stage 0; stage 1 (stride 2) pairs (0,2) and (1,3)
        let mut a = b.angles().to_vec();
        a[2] = std::f32::consts::FRAC_PI_2;
        b.set_angles(a);
        let mut x = vec![1.0, 0.0, 0.0, 0.0];
        b.apply(&mut x);
        assert!((x[0]).abs() < 1e-6 && (x[2] - 1.0).abs() < 1e-6, "{x:?}");
    }

    #[test]
    fn batch_matches_single() {
        let d = 32;
        let b = rand_bfly(d, 5, 11);
        let mut rng = Rng::new(2);
        let rows = 7;
        let mut batch: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32(1.0)).collect();
        let singles: Vec<Vec<f32>> = batch
            .chunks_exact(d)
            .map(|r| {
                let mut v = r.to_vec();
                b.apply(&mut v);
                v
            })
            .collect();
        b.apply_batch(&mut batch);
        for (i, s) in singles.iter().enumerate() {
            assert_eq!(&batch[i * d..(i + 1) * d], &s[..]);
        }
    }

    // NOTE: blocked-vs-per-row bit-identity across shapes/depths/tails
    // lives in rust/tests/kernels.rs (plus `batch_matches_single` below,
    // which already pins apply_batch == per-row singles bitwise).

    #[test]
    fn blocked_scratch_is_reused_across_calls() {
        let b = rand_bfly(32, 5, 77);
        let mut scratch = Vec::new();
        let mut x: Vec<f32> = (0..20 * 32).map(|i| i as f32 * 0.01).collect();
        b.apply_batch_with(&mut x, &mut scratch);
        let (cap, ptr) = (scratch.capacity(), scratch.as_ptr());
        for _ in 0..3 {
            b.apply_batch_with(&mut x, &mut scratch);
            b.apply_transpose_batch_with(&mut x, &mut scratch);
        }
        assert_eq!(cap, scratch.capacity(), "steady-state scratch must not grow");
        assert_eq!(ptr, scratch.as_ptr(), "steady-state scratch must not move");
    }

    #[test]
    fn from_angles_roundtrip() {
        let d = 8;
        let depth = 3;
        let src = rand_bfly(d, depth, 13);
        let b2 = Butterfly::from_angles(d, depth, src.angles());
        let mut x = vec![0.3f32; d];
        let mut y = x.clone();
        src.apply(&mut x);
        b2.apply(&mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn from_shared_serves_the_packed_table_bit_for_bit() {
        let src = rand_bfly(16, 4, 21);
        // simulate the pack -> load path: the loader hands back the same
        // angle + cs values through shared storage
        let shared = Butterfly::from_shared(
            16,
            4,
            SharedSlice::owned(src.angles().to_vec()),
            SharedSlice::owned(src.cs_table().to_vec()),
        );
        assert!(!shared.is_shared()); // owned storage in this simulation
        let mut rng = Rng::new(22);
        let mut a: Vec<f32> = (0..5 * 16).map(|_| rng.normal_f32(1.0)).collect();
        let mut b = a.clone();
        src.apply_batch(&mut a);
        shared.apply_batch(&mut b);
        assert_eq!(a, b);
        let mut ta = a.clone();
        let mut tb = a.clone();
        src.apply_transpose_batch(&mut ta);
        shared.apply_transpose_batch(&mut tb);
        assert_eq!(ta, tb);
    }
}
