//! Expert-parallel worker pool for the MoE hot path.
//!
//! The offline vendor set has no `rayon` (and no `crossbeam`), so this is
//! a dependency-free persistent pool: threads are spawned **once** at
//! construction and parked on a mutex/condvar work queue between decode
//! steps — no per-step spawn cost, which matters when a step is a few
//! hundred microseconds.  [`WorkerPool::run`] executes an indexed task
//! set `0..n` across the pool (the submitting thread participates, so a
//! 1-thread pool is exactly the sequential loop) and returns when every
//! task has finished.
//!
//! # Determinism contract
//!
//! The pool itself guarantees only that every index runs exactly once;
//! *bitwise determinism of the MoE forward is a property of how the hot
//! path shards work*, documented here because every caller relies on it:
//!
//! * **Disjoint writes, no reductions across tasks.**  Callers shard
//!   output so that each element is written by exactly one task
//!   ([`DisjointSliceMut`]).  Work whose result depends on float
//!   accumulation *order* (the scatter of expert outputs into shared
//!   token rows — experts may share a token under top-k ≥ 2 routing) is
//!   never split across tasks along the accumulation axis: the layer
//!   runs a separate reduction phase sharded by **token row**, inside
//!   which each row accumulates its experts in ascending expert order —
//!   the exact association of the sequential loop.  See
//!   `moe::layer::ButterflyMoeLayer::experts_forward`.
//! * Consequently the forward pass is bit-identical for **any** worker
//!   count, including 1 — asserted by `rust/tests/determinism.rs`.
//!
//! # Panic behaviour
//!
//! A panicking task must fail the decode step, not hang it.  Workers run
//! tasks under `catch_unwind`; the first panic payload is stored, all
//! *unclaimed* tasks of the batch are cancelled, and once in-flight
//! tasks drain, [`WorkerPool::run`] re-raises the payload on the
//! submitting thread (`resume_unwind`).  The accounting that wakes the
//! submitter is updated on the panic path too, so the condvar wait can
//! never deadlock on a dead task — covered by the poisoned-expert tests.
//! The pool stays usable after a panic.
//!
//! # Memory accounting
//!
//! Per-worker/per-block gather scratch (`xg`/`hg` in the layer) is
//! **working-set** memory — like the expert-residency cache's decoded
//! sets, it is *not* expert-identity storage and never counts toward the
//! Table-1 bytes (`MoeLayer::expert_bytes`); see `crate::memmodel`.

use std::any::Any;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// Poison-tolerant lock: a panic re-raised by [`WorkerPool::run`]
/// unwinds while holding the submit lock (and a panicking caller may
/// poison the state lock); pool state stays consistent across panics by
/// construction, so poisoning is cleared rather than propagated —
/// "pool stays usable after a panic" is part of the contract.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Raw pointer to the current batch's task closure.  Only dereferenced
/// while [`WorkerPool::run`] keeps the referent alive on the submitting
/// thread's stack (the run/`unfinished` protocol guarantees it).
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` and outlives every dereference (see
// TaskPtr docs); the pointer itself is just an address.
unsafe impl Send for TaskPtr {}

struct Job {
    task: TaskPtr,
    n_tasks: usize,
    /// Next unclaimed task index (claims ascend; execution overlaps).
    next: usize,
    /// Tasks not yet completed (claimed-and-running + unclaimed).
    unfinished: usize,
    /// First panic payload observed in this batch.
    panic: Option<Box<dyn Any + Send>>,
}

#[derive(Default)]
struct PoolState {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers park here between batches.
    work_ready: Condvar,
    /// The submitter parks here until `unfinished == 0`.
    work_done: Condvar,
    /// Serializes concurrent `run` calls from different threads.
    submit: Mutex<()>,
}

/// Persistent worker pool; see the module docs for the determinism and
/// panic contracts.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Pool with `threads` total execution threads: `threads - 1` are
    /// spawned; the thread calling [`run`](Self::run) is the last one.
    /// `threads == 1` therefore spawns nothing and runs inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
            submit: Mutex::new(()),
        });
        let handles = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("bmoe-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn worker thread")
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            threads,
        }
    }

    /// Pool sized by [`resolve_workers`]`(0)` — env override or all cores.
    pub fn from_env() -> Self {
        WorkerPool::new(resolve_workers(0))
    }

    /// Total execution threads (spawned workers + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `task(i)` for every `i in 0..n_tasks` and wait for all of
    /// them.  Claims are handed out in ascending index order; execution
    /// overlaps across threads.  If any task panics, the remaining
    /// unclaimed tasks are cancelled and the first payload is re-raised
    /// here once in-flight tasks finish.
    ///
    /// Must not be called from inside one of its own tasks (a nested
    /// call would block on the submit lock the outer call holds).
    /// Concurrent calls from *different* threads serialize.
    pub fn run(&self, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.handles.is_empty() {
            // 1-thread pool: exactly the sequential loop, panics unwind
            // naturally to the caller.
            for i in 0..n_tasks {
                task(i);
            }
            return;
        }
        let _submit = lock(&self.shared.submit);
        {
            let mut st = lock(&self.shared.state);
            debug_assert!(st.job.is_none(), "stale job under the submit lock");
            // SAFETY: launder the borrow to 'static for storage only; the
            // referent lives on this stack frame until `unfinished == 0`
            // below, and no dereference survives that point.
            let task_ptr: *const (dyn Fn(usize) + Sync) = task;
            let task_ptr: *const (dyn Fn(usize) + Sync + 'static) =
                unsafe { std::mem::transmute(task_ptr) };
            st.job = Some(Job {
                task: TaskPtr(task_ptr),
                n_tasks,
                next: 0,
                unfinished: n_tasks,
                panic: None,
            });
            self.shared.work_ready.notify_all();
        }
        // The submitting thread claims tasks alongside the workers.
        let mut st = lock(&self.shared.state);
        loop {
            let job = st.job.as_mut().expect("job lives until taken below");
            if job.panic.is_some() {
                // fail fast: drop everything not yet claimed
                job.unfinished -= job.n_tasks - job.next;
                job.next = job.n_tasks;
            }
            if job.next >= job.n_tasks {
                break;
            }
            let i = job.next;
            job.next += 1;
            drop(st);
            let result = catch_unwind(AssertUnwindSafe(|| task(i)));
            st = lock(&self.shared.state);
            let job = st.job.as_mut().expect("job lives until taken below");
            job.unfinished -= 1;
            if let Err(payload) = result {
                job.panic.get_or_insert(payload);
            }
        }
        while st.job.as_ref().expect("job lives until taken").unfinished > 0 {
            st = wait(&self.shared.work_done, st);
        }
        let job = st.job.take().unwrap();
        drop(st);
        if let Some(payload) = job.panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for h in self.handles.drain(..) {
            // panic-propagating join: a worker dying outside a task is a
            // pool bug; surface it unless we are already unwinding.
            if let Err(payload) = h.join() {
                if !std::thread::panicking() {
                    resume_unwind(payload);
                }
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut st = lock(&shared.state);
    loop {
        if st.shutdown {
            return;
        }
        let claimed = match st.job.as_mut() {
            Some(job) if job.next < job.n_tasks => {
                if job.panic.is_some() {
                    // a sibling task panicked: cancel unclaimed work so
                    // the submitter's condvar wait terminates (this is
                    // the no-deadlock guarantee)
                    job.unfinished -= job.n_tasks - job.next;
                    job.next = job.n_tasks;
                    if job.unfinished == 0 {
                        shared.work_done.notify_all();
                    }
                    None
                } else {
                    let i = job.next;
                    job.next += 1;
                    Some((i, job.task))
                }
            }
            _ => None,
        };
        match claimed {
            Some((i, task)) => {
                drop(st);
                // SAFETY: the submitter keeps the closure alive until
                // this task is accounted finished (see TaskPtr docs).
                let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*task.0)(i) }));
                st = lock(&shared.state);
                if let Some(job) = st.job.as_mut() {
                    job.unfinished -= 1;
                    if let Err(payload) = result {
                        job.panic.get_or_insert(payload);
                    }
                    if job.unfinished == 0 {
                        shared.work_done.notify_all();
                    }
                } else {
                    debug_assert!(false, "job vanished while a task was in flight");
                }
            }
            None => {
                st = wait(&shared.work_ready, st);
            }
        }
    }
}

/// Worker-count resolution for the `--workers` knob: an explicit
/// `requested > 0` wins; otherwise the `BMOE_WORKERS` env var (CI runs
/// the suite under 1 and 4); otherwise every available core.
pub fn resolve_workers(requested: usize) -> usize {
    workers_from(requested, std::env::var("BMOE_WORKERS").ok().as_deref())
}

/// Pure core of [`resolve_workers`] (unit-testable without env races).
fn workers_from(requested: usize, env: Option<&str>) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Some(n) = env.and_then(|v| v.trim().parse::<usize>().ok()) {
        if n > 0 {
            return n;
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `0..n` into at most `parts` contiguous, ascending, disjoint
/// ranges that exactly cover `0..n` (the unit of token-row sharding in
/// the deterministic reduction — asserted here so callers can rely on
/// "every row exactly once").
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n, "ranges must cover 0..n exactly");
    debug_assert!(out.windows(2).all(|w| w[0].1 == w[1].0));
    out
}

/// Shared mutable slice for disjoint-index parallel writes.
///
/// Wraps `&mut [T]` so several pool tasks can write to it concurrently
/// **provided they touch disjoint indices** — the layer shards by
/// dispatch block / token row / output row, all naturally disjoint.
/// Every access is `unsafe` to keep that proof obligation at the call
/// site.
pub struct DisjointSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is index-disjoint by the caller contract, so aliasing
// &mut never materializes; T: Send makes cross-thread writes sound.
unsafe impl<T: Send> Send for DisjointSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSliceMut<'_, T> {}

impl<'a, T> DisjointSliceMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointSliceMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exclusive reference to element `i`.
    ///
    /// # Safety
    /// No concurrent task may access index `i`.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn index_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        &mut *self.ptr.add(i)
    }

    /// Exclusive reference to `start..start + len`.
    ///
    /// # Safety
    /// No concurrent task may access any index in the range.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_index_runs_exactly_once() {
        for threads in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(threads);
            let n = 103;
            let mut out = vec![0u32; n];
            let shards = DisjointSliceMut::new(&mut out);
            let task = |i: usize| {
                // SAFETY: one task per index
                unsafe { *shards.index_mut(i) += i as u32 + 1 };
            };
            pool.run(n, &task);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i as u32 + 1, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = WorkerPool::new(3);
        let count = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(7, &|_i| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 350);
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        let pool = WorkerPool::new(4);
        pool.run(0, &|_| panic!("must not run"));
    }

    #[test]
    fn panic_propagates_payload_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, &|i| {
                if i == 13 {
                    panic!("poisoned task 13");
                }
            });
        }))
        .expect_err("run must re-raise the task panic");
        let msg = err
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("poisoned task 13"), "payload was: {msg}");
        // no deadlocked condvar, no wedged workers: the pool keeps working
        let count = AtomicUsize::new(0);
        pool.run(32, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn panic_on_single_thread_pool_unwinds_directly() {
        let pool = WorkerPool::new(1);
        let err = catch_unwind(AssertUnwindSafe(|| pool.run(3, &|_| panic!("seq boom"))));
        assert!(err.is_err());
        pool.run(3, &|_| {});
    }

    #[test]
    fn concurrent_submitters_serialize() {
        let pool = Arc::new(WorkerPool::new(4));
        let total = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                let total = total.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        pool.run(11, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 11);
    }

    #[test]
    fn chunk_ranges_cover_disjointly() {
        for (n, parts) in [(10usize, 3usize), (1, 8), (16, 16), (7, 1), (64, 5)] {
            let r = chunk_ranges(n, parts);
            assert!(r.len() <= parts && !r.is_empty());
            assert_eq!(r[0].0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous, disjoint");
            }
            let covered: usize = r.iter().map(|(a, b)| b - a).sum();
            assert_eq!(covered, n);
        }
        assert!(chunk_ranges(0, 4).is_empty());
    }

    #[test]
    fn workers_from_resolution_order() {
        assert_eq!(workers_from(3, Some("8")), 3, "explicit wins");
        assert_eq!(workers_from(0, Some("8")), 8, "env next");
        assert_eq!(workers_from(0, Some(" 2 ")), 2, "env trimmed");
        let auto = workers_from(0, None);
        assert!(auto >= 1, "falls back to cores");
        assert_eq!(workers_from(0, Some("0")), auto, "env 0 = auto");
        assert_eq!(workers_from(0, Some("nope")), auto, "bad env = auto");
    }

    #[test]
    fn threads_accessor_counts_submitter() {
        assert_eq!(WorkerPool::new(1).threads(), 1);
        assert_eq!(WorkerPool::new(4).threads(), 4);
        assert_eq!(WorkerPool::new(0).threads(), 1, "clamped to 1");
    }
}
