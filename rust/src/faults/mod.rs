//! Deterministic fault injection for chaos testing (DESIGN.md §8).
//!
//! Compiled in unconditionally — like [`crate::testutil`] it ships in
//! the binary but is **default-inert**: every hook below starts with one
//! relaxed atomic load and a branch, so the serving hot path pays
//! nothing until a plan is installed.  A plan comes from the
//! `BMOE_FAULT` environment variable or the `--fault <spec>` flag
//! (`key=value` pairs separated by `;` or `,`), or programmatically via
//! [`install`] from the chaos tests and `benches/chaos.rs`.
//!
//! Every decision is **seeded**: a hook's nth draw is a pure function of
//! `(plan.seed, injection point, n)`, so a failing chaos schedule can be
//! replayed exactly by re-running with the same spec.  Nothing here
//! touches decoded bits — faults only decide *when* infrastructure
//! breaks, and the determinism contract (DESIGN.md §5) is what makes
//! the recovery paths verifiable afterwards.
//!
//! Injection points and the spec keys that drive them:
//!
//! | key                 | point                                                       |
//! |---------------------|-------------------------------------------------------------|
//! | `seed=N`            | seeds every draw below                                      |
//! | `spawn_fail=P`      | worker launch attempt fails (both launchers)                |
//! | `kill_after=N`      | SIGKILL a session's placed worker after N relayed tokens    |
//! | `kill_prob=P`       | probability per session that `kill_after` fires (default 1) |
//! | `kill_limit=N`      | total kills across the process (0 = unlimited)              |
//! | `stall_ms=N`        | worker stops responding: sleep before answering a wire line |
//! | `stall_prob=P`      | probability per wire line that the stall fires (default 1)  |
//! | `corrupt_line=P`    | mangle an inbound worker `GEN` line (always parse-visible)  |
//! | `bitflip=1`         | flip one byte of a heap-loaded artifact (once per process)  |
//! | `client_stall_ms=N` | load generators: how long a stalled client reader sleeps    |
//! | `client_stall_prob=P` | probability per session of the client stall (default 1)   |

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

/// A parsed fault plan.  All probabilities are in `[0, 1]`; the
/// `*_prob` knobs default to 1 so e.g. `kill_after=5` alone means
/// "every session".  The inert default plan injects nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a worker launch attempt fails.
    pub spawn_fail: f64,
    /// SIGKILL the placed worker after this many relayed tokens (0 = off).
    pub kill_after: u64,
    /// Per-session probability that the kill fires.
    pub kill_prob: f64,
    /// Cap on total kills fired by this process (0 = unlimited).
    pub kill_limit: u64,
    /// Worker-side stall before answering a wire line, ms (0 = off).
    pub stall_ms: u64,
    /// Per-line probability that the stall fires.
    pub stall_prob: f64,
    /// Probability an inbound worker `GEN` line is corrupted.
    pub corrupt_line: f64,
    /// Flip one byte of the next heap-loaded artifact.
    pub bitflip: bool,
    /// Stalled-client-reader sleep for load generators, ms (0 = off).
    pub client_stall_ms: u64,
    /// Per-session probability of the client stall.
    pub client_stall_prob: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            spawn_fail: 0.0,
            kill_after: 0,
            kill_prob: 1.0,
            kill_limit: 0,
            stall_ms: 0,
            stall_prob: 1.0,
            corrupt_line: 0.0,
            bitflip: false,
            client_stall_ms: 0,
            client_stall_prob: 1.0,
        }
    }
}

impl FaultPlan {
    /// Parse a `key=value[;key=value...]` spec (`,` also separates).
    /// Unknown keys are errors — a typo'd fault spec must never run a
    /// silently different chaos schedule.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut p = FaultPlan::default();
        for pair in spec.split([';', ',']).map(str::trim).filter(|s| !s.is_empty()) {
            let (key, value) = pair
                .split_once('=')
                .with_context(|| format!("fault spec item '{pair}' is not key=value"))?;
            let (key, value) = (key.trim(), value.trim());
            let int = || -> Result<u64> {
                value.parse().with_context(|| format!("fault key {key}: bad integer '{value}'"))
            };
            let prob = || -> Result<f64> {
                let v: f64 = value
                    .parse()
                    .with_context(|| format!("fault key {key}: bad probability '{value}'"))?;
                anyhow::ensure!((0.0..=1.0).contains(&v), "fault key {key}: '{value}' not in [0,1]");
                Ok(v)
            };
            match key {
                "seed" => p.seed = int()?,
                "spawn_fail" => p.spawn_fail = prob()?,
                "kill_after" => p.kill_after = int()?,
                "kill_prob" => p.kill_prob = prob()?,
                "kill_limit" => p.kill_limit = int()?,
                "stall_ms" => p.stall_ms = int()?,
                "stall_prob" => p.stall_prob = prob()?,
                "corrupt_line" => p.corrupt_line = prob()?,
                "bitflip" => p.bitflip = int()? != 0,
                "client_stall_ms" => p.client_stall_ms = int()?,
                "client_stall_prob" => p.client_stall_prob = prob()?,
                _ => anyhow::bail!("unknown fault key '{key}' in '{pair}'"),
            }
        }
        Ok(p)
    }
}

/// Fast inert-path gate: one relaxed load on every hook.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

// Per-injection-point draw counters: the nth draw at a point is
// deterministic in (seed, point, n) regardless of thread interleaving
// *of other points*.  (Interleaving within one point still orders its
// draws; chaos tests pin outcomes, not which session drew which.)
static SPAWN_N: AtomicU64 = AtomicU64::new(0);
static KILL_N: AtomicU64 = AtomicU64::new(0);
static KILLS_FIRED: AtomicU64 = AtomicU64::new(0);
static STALL_N: AtomicU64 = AtomicU64::new(0);
static CORRUPT_N: AtomicU64 = AtomicU64::new(0);
static CLIENT_N: AtomicU64 = AtomicU64::new(0);
static BITFLIP_DONE: AtomicBool = AtomicBool::new(false);

/// Install a plan (resets all draw counters).  Used by chaos tests and
/// benches; the CLI path goes through [`init_from`].
pub fn install(plan: FaultPlan) {
    let mut guard = PLAN.lock().unwrap();
    for c in [&SPAWN_N, &KILL_N, &KILLS_FIRED, &STALL_N, &CORRUPT_N, &CLIENT_N] {
        c.store(0, Ordering::SeqCst);
    }
    BITFLIP_DONE.store(false, Ordering::SeqCst);
    *guard = Some(plan);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Back to inert — every hook returns to its one-load fast path.
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
    *PLAN.lock().unwrap() = None;
}

/// CLI/runtime entry: install from `--fault <spec>` if given, else from
/// `BMOE_FAULT` if set, else stay inert.
pub fn init_from(flag_spec: &str) -> Result<()> {
    let spec = if !flag_spec.is_empty() {
        flag_spec.to_string()
    } else {
        match std::env::var("BMOE_FAULT") {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return Ok(()),
        }
    };
    let plan = FaultPlan::parse(&spec).with_context(|| format!("parse fault spec '{spec}'"))?;
    crate::obs::log("faults", &format!("fault plan active: {plan:?}"));
    install(plan);
    Ok(())
}

/// Is any plan installed?  (The one-load fast path every hook starts
/// with.)
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

fn plan() -> Option<FaultPlan> {
    if !active() {
        return None;
    }
    PLAN.lock().unwrap().clone()
}

/// SplitMix64: the standard seeded mixer — a pure function of its
/// input, so draws replay exactly.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The nth unit-interval draw at an injection point.
fn unit(seed: u64, point: u64, n: u64) -> f64 {
    let bits = splitmix64(seed ^ point.wrapping_mul(0xA076_1D64_78BD_642F) ^ n);
    (bits >> 11) as f64 / (1u64 << 53) as f64
}

const POINT_SPAWN: u64 = 1;
const POINT_KILL: u64 = 2;
const POINT_STALL: u64 = 3;
const POINT_CORRUPT: u64 = 4;
const POINT_BITFLIP: u64 = 5;
const POINT_CLIENT: u64 = 6;

/// Should this worker launch attempt fail?  (Hooked in both launchers.)
pub fn spawn_failure(worker: usize) -> bool {
    let Some(p) = plan() else { return false };
    if p.spawn_fail <= 0.0 {
        return false;
    }
    let n = SPAWN_N.fetch_add(1, Ordering::SeqCst);
    let _ = worker; // failure schedule is draw-ordered, not slot-keyed
    unit(p.seed, POINT_SPAWN, n) < p.spawn_fail
}

/// Per-session draw: kill the placed worker after this many relayed
/// tokens?  Counts toward `kill_limit` at draw time.
pub fn session_kill_after() -> Option<u64> {
    let p = plan()?;
    if p.kill_after == 0 {
        return None;
    }
    let n = KILL_N.fetch_add(1, Ordering::SeqCst);
    if unit(p.seed, POINT_KILL, n) >= p.kill_prob {
        return None;
    }
    if p.kill_limit > 0 && KILLS_FIRED.fetch_add(1, Ordering::SeqCst) >= p.kill_limit {
        return None;
    }
    Some(p.kill_after)
}

/// Worker-side: how long to stall before answering this wire line.
pub fn server_stall() -> Option<Duration> {
    let p = plan()?;
    if p.stall_ms == 0 {
        return None;
    }
    let n = STALL_N.fetch_add(1, Ordering::SeqCst);
    (unit(p.seed, POINT_STALL, n) < p.stall_prob).then(|| Duration::from_millis(p.stall_ms))
}

/// Worker-side: maybe corrupt an inbound `GEN` line in place.  The
/// corruption byte (`#`) is outside the `GEN` grammar, so a corrupted
/// line always *fails to parse* — it can never silently become a
/// different valid request (which would break the bit-identity chaos
/// gates).  Returns whether the line was mangled.
pub fn corrupt_wire_line(line: &mut String) -> bool {
    let Some(p) = plan() else { return false };
    if p.corrupt_line <= 0.0 || line.is_empty() {
        return false;
    }
    let n = CORRUPT_N.fetch_add(1, Ordering::SeqCst);
    if unit(p.seed, POINT_CORRUPT, n) >= p.corrupt_line {
        return false;
    }
    let idx = (unit(p.seed, POINT_CORRUPT, n ^ 0x5EED) * line.len() as f64) as usize;
    let idx = idx.min(line.len() - 1);
    // operate on bytes: '#' is ASCII, and we only replace ASCII-safe
    // positions (skip if it would split a UTF-8 sequence)
    let mut bytes = std::mem::take(line).into_bytes();
    if bytes[idx].is_ascii() {
        bytes[idx] = b'#';
    } else {
        bytes[0] = b'#';
    }
    *line = String::from_utf8_lossy(&bytes).into_owned();
    true
}

/// Flip one byte of a heap-loaded artifact image, once per process.
/// The flip lands in the second half of the file — the bulk tensor
/// payload region — so it exercises the checksum path rather than the
/// directory bounds checks.  Returns the flipped offset.
pub fn artifact_bitflip(bytes: &mut [u8]) -> Option<usize> {
    let p = plan()?;
    if !p.bitflip || bytes.len() < 2 {
        return None;
    }
    if BITFLIP_DONE.swap(true, Ordering::SeqCst) {
        return None;
    }
    let half = bytes.len() / 2;
    let idx = half + (unit(p.seed, POINT_BITFLIP, 0) * (bytes.len() - half) as f64) as usize;
    let idx = idx.min(bytes.len() - 1);
    bytes[idx] ^= 0xFF;
    Some(idx)
}

/// Load generators: per-session draw of a stalled-client-reader sleep.
pub fn client_stall() -> Option<Duration> {
    let p = plan()?;
    if p.client_stall_ms == 0 {
        return None;
    }
    let n = CLIENT_N.fetch_add(1, Ordering::SeqCst);
    (unit(p.seed, POINT_CLIENT, n) < p.client_stall_prob)
        .then(|| Duration::from_millis(p.client_stall_ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The plan is process-global; tests that install one serialize here.
    pub(crate) static TEST_MUTEX: Mutex<()> = Mutex::new(());

    #[test]
    fn spec_parses_round_trip_and_rejects_garbage() {
        let p = FaultPlan::parse("seed=7;kill_after=5,kill_prob=0.5; kill_limit=2").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.kill_after, 5);
        assert_eq!(p.kill_prob, 0.5);
        assert_eq!(p.kill_limit, 2);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("kill_after").is_err(), "not key=value");
        assert!(FaultPlan::parse("frobnicate=1").is_err(), "unknown key");
        assert!(FaultPlan::parse("kill_prob=1.5").is_err(), "probability range");
        assert!(FaultPlan::parse("kill_after=x").is_err(), "bad integer");
    }

    #[test]
    fn inert_by_default_and_after_clear() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        clear();
        assert!(!active());
        assert!(!spawn_failure(0));
        assert!(session_kill_after().is_none());
        assert!(server_stall().is_none());
        let mut line = "GEN 4 0 0 0 -1 1".to_string();
        assert!(!corrupt_wire_line(&mut line));
        assert_eq!(line, "GEN 4 0 0 0 -1 1");
        let mut bytes = vec![1u8; 64];
        assert!(artifact_bitflip(&mut bytes).is_none());
        assert!(bytes.iter().all(|&b| b == 1));
        install(FaultPlan { kill_after: 3, ..FaultPlan::default() });
        assert_eq!(session_kill_after(), Some(3));
        clear();
        assert!(session_kill_after().is_none());
    }

    #[test]
    fn draws_are_deterministic_in_seed_and_order() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let run = |seed: u64| -> Vec<bool> {
            install(FaultPlan { seed, spawn_fail: 0.5, ..FaultPlan::default() });
            (0..32).map(|_| spawn_failure(0)).collect()
        };
        let a = run(42);
        let b = run(42);
        let c = run(43);
        clear();
        assert_eq!(a, b, "same seed => same schedule");
        assert_ne!(a, c, "different seed => different schedule");
        let fired = a.iter().filter(|&&f| f).count();
        assert!((4..=28).contains(&fired), "p=0.5 of 32 draws, got {fired}");
    }

    #[test]
    fn kill_limit_caps_total_kills() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        install(FaultPlan { kill_after: 4, kill_limit: 2, ..FaultPlan::default() });
        let fired = (0..10).filter(|_| session_kill_after().is_some()).count();
        clear();
        assert_eq!(fired, 2);
    }

    #[test]
    fn corrupted_line_never_parses_as_a_gen_request() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        install(FaultPlan { seed: 9, corrupt_line: 1.0, ..FaultPlan::default() });
        for i in 0..16 {
            let mut line = format!("GEN 8 0 0 {i} -1 1 2 3");
            assert!(corrupt_wire_line(&mut line));
            assert!(
                crate::coordinator::parse_gen_line(&line).is_err(),
                "corruption must be parse-visible, got valid '{line}'"
            );
        }
        clear();
    }

    #[test]
    fn bitflip_fires_once_in_payload_half() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        install(FaultPlan { seed: 3, bitflip: true, ..FaultPlan::default() });
        let mut bytes = vec![0u8; 256];
        let idx = artifact_bitflip(&mut bytes).expect("first flip fires");
        assert!(idx >= 128, "flip must land in the payload half, got {idx}");
        assert_eq!(bytes[idx], 0xFF);
        assert!(artifact_bitflip(&mut bytes).is_none(), "once per process");
        clear();
    }
}
