//! The coordinator proper: session submission, the continuous-batching
//! engine loop, and the streaming TCP line-protocol frontend.
//!
//! One engine thread owns the backend and the
//! [`ContinuousScheduler`]: each iteration admits queued requests into
//! the running batch (up to `max_batch`), executes one engine step, and
//! streams a [`TokenEvent`] to every resident session.  A joining
//! session's prompt is ingested over one or more *prefill* steps
//! (`--prefill-chunk` tokens per tick; 0 = all at once) before its
//! first token decodes, so a long prompt never stalls the batch-mates'
//! inter-token latency for its whole length.  Finished sequences leave
//! between steps, so a short completion never waits for a long
//! batch-mate to finish.
//!
//! Shutdown is loss-free for *waiters*: every in-flight session receives
//! a terminal `Done { reason: Shutdown }` and every still-queued request
//! is denied with the same terminal event — no client ever blocks on a
//! dead reply channel.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs;
use crate::obs::trace::Stage;

use super::backend::Backend;
use super::metrics::Metrics;
use super::scheduler::{ContinuousScheduler, QueuedRequest, SchedulerConfig};
use super::session::{
    collect_stream, Completion, FinishReason, GenerateRequest, SamplingParams, StopCriteria,
    TokenEvent,
};

/// How long the engine thread sleeps in `recv` while fully idle before
/// re-checking the stop flag.
const IDLE_POLL: Duration = Duration::from_millis(25);

/// Per-event timeout for blocking conveniences ([`Coordinator::generate`],
/// the TCP frontend): generous because a step may compile a bucket on
/// first use.
const STREAM_TIMEOUT: Duration = Duration::from_secs(120);

/// Coordinator handle: submit generation sessions, inspect metrics,
/// shut down.
pub struct Coordinator {
    /// `None` after shutdown; sends after that are denied immediately.
    tx: Mutex<Option<Sender<QueuedRequest>>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Start the engine thread running the continuous-batching loop.
    pub fn start(backend: Arc<dyn Backend>, cfg: SchedulerConfig) -> Arc<Coordinator> {
        let (tx, rx) = mpsc::channel::<QueuedRequest>();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let engine = {
            let backend = backend.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("bmoe-engine-loop".into())
                .spawn(move || engine_loop(rx, backend, cfg, metrics, stop))
                .expect("spawn engine loop")
        };
        Arc::new(Coordinator {
            tx: Mutex::new(Some(tx)),
            metrics,
            next_id: AtomicU64::new(1),
            stop,
            threads: Mutex::new(vec![engine]),
        })
    }

    /// Submit a generation session; returns the event stream.  The
    /// stream always ends with exactly one `Done`, even across shutdown.
    pub fn submit(&self, request: GenerateRequest) -> Receiver<TokenEvent> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let guard = self.tx.lock().unwrap();
        match guard.as_ref() {
            Some(tx) => {
                self.metrics.record_enqueue();
                obs::Event::new("session_enqueue")
                    .u64("session", id)
                    .u64("prompt_len", request.prompt.len() as u64)
                    .u64("max_new", request.stop.max_new_tokens as u64)
                    .emit();
                let q = QueuedRequest {
                    id,
                    request,
                    enqueued: Instant::now(),
                    reply: rtx,
                };
                if let Err(mpsc::SendError(q)) = tx.send(q) {
                    deny(q); // engine thread died; don't strand the client
                }
            }
            None => {
                let _ = rtx.send(TokenEvent::Done {
                    reason: FinishReason::Shutdown,
                    tokens: Vec::new(),
                    total: Duration::ZERO,
                    truncated: 0,
                });
            }
        }
        rrx
    }

    /// Blocking convenience: submit and collect the whole completion.
    pub fn generate(&self, request: GenerateRequest) -> Result<Completion> {
        let rx = self.submit(request);
        collect_stream(&rx, STREAM_TIMEOUT)
    }

    /// Stop the engine loop.  Every in-flight session gets a terminal
    /// `Shutdown` event and every queued request is drained and denied —
    /// no waiter is left blocking on a dead channel.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Drop the sender: a blocked engine loop wakes immediately, and
        // everything buffered in the channel drains on the stop path.
        *self.tx.lock().unwrap() = None;
        let mut threads = self.threads.lock().unwrap();
        for t in threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn deny(q: QueuedRequest) {
    let _ = q.reply.send(TokenEvent::Done {
        reason: FinishReason::Shutdown,
        tokens: Vec::new(),
        total: q.enqueued.elapsed(),
        truncated: 0,
    });
}

fn engine_loop(
    rx: Receiver<QueuedRequest>,
    backend: Arc<dyn Backend>,
    cfg: SchedulerConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let max_batch = cfg.max_batch.min(backend.max_batch()).max(1);
    let mut sched = ContinuousScheduler::new(max_batch, cfg.max_session_tokens, metrics)
        .with_prefill_chunk(cfg.prefill_chunk);
    let mut pending: VecDeque<QueuedRequest> = VecDeque::new();
    let mut disconnected = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            sched.abort_all(FinishReason::Shutdown);
            for q in pending.drain(..) {
                deny(q);
            }
            // deny everything still in — or racing into — the channel:
            // shutdown() drops the only Sender right after setting the
            // stop flag, so draining until disconnect guarantees no
            // concurrently-submitted request is stranded without a
            // terminal event
            loop {
                match rx.recv_timeout(IDLE_POLL) {
                    Ok(q) => deny(q),
                    Err(RecvTimeoutError::Disconnected) => return,
                    Err(RecvTimeoutError::Timeout) => {}
                }
            }
        }
        // join point: pick up every request that arrived since last step
        loop {
            match rx.try_recv() {
                Ok(q) => pending.push_back(q),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if sched.in_flight() == 0 {
            if pending.is_empty() {
                if disconnected {
                    return;
                }
                metrics.record_load(0, 0);
                match rx.recv_timeout(IDLE_POLL) {
                    Ok(q) => pending.push_back(q),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => disconnected = true,
                }
                continue;
            }
            // idle start: give the first batch up to `max_wait` to fill
            // (size flush when it does, deadline flush when it doesn't)
            let deadline = pending.front().unwrap().enqueued + cfg.max_wait;
            while pending.len() < max_batch && !disconnected && !stop.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(q) => pending.push_back(q),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => disconnected = true,
                }
            }
        }
        while sched.has_capacity() {
            match pending.pop_front() {
                Some(q) => sched.admit(q),
                None => break,
            }
        }
        // publish the load gauges every iteration so STATS readers (the
        // router's least-loaded placement) see queue depth and resident
        // batch size, not just the historical occupancy mean
        metrics.record_load(pending.len(), sched.in_flight());
        if sched.in_flight() > 0 {
            {
                // on backend failure the scheduler already streamed
                // terminal error events; keep serving subsequent requests
                let _t = obs::stage_timer(Stage::SchedStep, 0);
                let _ = sched.step(backend.as_ref());
            }
            {
                // step-time residency tick: fold gating stats, admit or
                // evict hot experts, publish counters for STATS readers
                let _t = obs::stage_timer(Stage::CacheTick, 0);
                backend.tick_caches();
            }
            if let Some(cs) = backend.cache_stats() {
                metrics.record_cache(cs);
            }
        } else if disconnected && pending.is_empty() {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// TCP frontend — streaming line protocol (one session per GEN line):
//
//   client:  GEN <max_new> <temperature> <top_k> <seed> <eos> <tok> <tok> ...
//   server:  TOK <index> <token> <latency_us>      (one per generated token)
//            END <reason> <n_tokens> <total_us> <truncated>
//                                                  (terminal; reason is
//                                                   max_tokens|eos|shutdown;
//                                                   truncated = prompt tokens
//                                                   dropped to fit the model
//                                                   window, usually 0)
//       or:  ERR <message>                         (terminal)
//
// `<eos>` is -1 for "no EOS token"; `<temperature>` 0 means greedy (then
// `<top_k>`/`<seed>` are ignored; pass 0).  Prompt tokens are
// non-negative vocabulary ids — a negative token would alias into the
// embedding table via the vocab modulus, so it is rejected at parse
// time instead of silently decoding someone else's row.  "QUIT" closes
// the connection.  A malformed request gets exactly one terminal
// `ERR <reason>` line and the connection is closed (a client that can't
// frame a GEN line can't be trusted to stay in sync with a stream).
//
// "SHUTDOWN" begins graceful process shutdown: the server stops
// accepting, lets in-flight sessions finish streaming, then runs the
// coordinator's loss-free shutdown.  `bmoe route` sends this to workers
// at the end of a drain.
//
// "STATS" returns one `key=value` telemetry line (see [`stats_line`]):
//
//   STATS req=.. done=.. tokens=.. tok_per_s=.. steps=.. occupancy=..
//         queue_depth=.. inflight=..
//         cache_enabled=.. cache_hits=.. cache_misses=.. cache_hit_rate=..
//         cache_resident_bytes=.. cache_resident_experts=..
//         cache_budget_bytes=.. cache_evictions=..
//
// `queue_depth`/`inflight` are instantaneous gauges (requests waiting
// for admission / sequences resident in the batch) — what the router's
// least-loaded placement keys on.  The cache_* fields report the
// expert-residency cache (zeros when the backend serves without one —
// `--expert-cache-mb` unset).
//
// "METRICS" returns the same telemetry (plus the latency histograms and
// the sampled per-stage hot-path timings) as Prometheus text
// exposition, terminated by a `# EOF` line so scrapers and the router's
// fleet aggregation can read a bounded reply without closing the
// connection (DESIGN.md §7).  The STATS format above stays unchanged.
// ---------------------------------------------------------------------------

/// Bind `127.0.0.1:<port>` (0 = ephemeral) with `SO_REUSEADDR`, announce
/// the actually-bound address on a machine-parseable `[listening]`
/// stdout line, and serve until `stop`.  Supervisors (`bmoe route`, CI)
/// parse that line to learn the port a `--port 0` worker landed on.
pub fn serve_tcp(coord: Arc<Coordinator>, port: u16, stop: Arc<AtomicBool>) -> Result<()> {
    let (listener, addr) = crate::util::net::listen_reuse(port)?;
    println!("[listening] {addr}");
    std::io::stdout().flush().ok();
    serve_on(listener, coord, stop)
}

/// Accept loop over an already-bound listener.  Returns after `stop` is
/// set (externally, or by a wire `SHUTDOWN`), once every connection
/// thread has exited and the coordinator has completed its loss-free
/// shutdown — so a clean return means no stranded sessions.
pub fn serve_on(listener: TcpListener, coord: Arc<Coordinator>, stop: Arc<AtomicBool>) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _addr)) => {
                let coord = coord.clone();
                let stop = stop.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, coord, stop);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    // in-flight sessions keep streaming while we join their relay
    // threads; only then tear the engine down (idempotent with an
    // external shutdown() — PR 1's loss-free semantics either way)
    for c in conns {
        let _ = c.join();
    }
    coord.shutdown();
    Ok(())
}

/// Render the single-line `STATS` wire reply: serving counters plus the
/// expert-residency cache's hit rate and resident bytes (zeros when no
/// cache is attached), `key=value` so clients and smoke tests can grep.
pub fn stats_line(s: &super::metrics::MetricsSnapshot) -> String {
    let c = s.cache.clone().unwrap_or_default();
    format!(
        "STATS req={} done={} tokens={} tok_per_s={:.1} steps={} occupancy={:.2} \
         queue_depth={} inflight={} \
         cache_enabled={} cache_hits={} cache_misses={} cache_hit_rate={:.3} \
         cache_resident_bytes={} cache_resident_experts={} cache_budget_bytes={} \
         cache_evictions={}",
        s.requests,
        s.responses,
        s.tokens,
        s.tokens_per_sec,
        s.steps,
        s.mean_batch_size,
        s.queue_depth,
        s.inflight,
        c.enabled as u8,
        c.hits,
        c.misses,
        c.hit_rate(),
        c.resident_bytes,
        c.resident_experts,
        c.budget_bytes,
        c.evictions,
    )
}

/// Parse one `GEN` request line (see the protocol block above).
pub fn parse_gen_line(line: &str) -> Result<GenerateRequest> {
    let mut it = line.split_whitespace();
    anyhow::ensure!(it.next() == Some("GEN"), "expected GEN");
    let max_new: usize = it.next().context("missing max_new")?.parse().context("max_new")?;
    let temperature: f32 = it
        .next()
        .context("missing temperature")?
        .parse()
        .context("temperature")?;
    let top_k: usize = it.next().context("missing top_k")?.parse().context("top_k")?;
    let seed: u64 = it.next().context("missing seed")?.parse().context("seed")?;
    let eos: i64 = it.next().context("missing eos")?.parse().context("eos")?;
    let prompt: Vec<i32> = it
        .map(|t| {
            let tok = t.parse::<i32>().with_context(|| format!("bad token '{t}'"))?;
            // negative ids would alias into the embed table through the
            // vocab modulus — reject here, not deep in a gather
            anyhow::ensure!(tok >= 0, "negative token '{t}'");
            Ok(tok)
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let mut stop = StopCriteria::max_tokens(max_new);
    if eos >= 0 {
        stop = stop.with_eos(eos as i32);
    }
    Ok(GenerateRequest {
        prompt,
        sampling: SamplingParams::top_k(temperature, top_k, seed),
        stop,
    })
}

/// Read one protocol line, waking periodically so a set `stop` flag can
/// end the connection even while the client sits idle (a drain must
/// never hang on a silent client).  Returns `Ok(false)` on EOF or stop.
/// Per `BufRead::read_until`'s contract, bytes read before a timeout
/// stay in `line`, so a slowly-arriving line is never truncated.
fn read_wire_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    stop: &AtomicBool,
) -> Result<bool> {
    line.clear();
    loop {
        match reader.read_line(line) {
            Ok(0) => return Ok(!line.trim().is_empty()), // EOF; flush a partial tail
            Ok(_) => return Ok(true),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::SeqCst) {
                    return Ok(false);
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    while read_wire_line(&mut reader, &mut line, &stop)? {
        // Fault injection (inert unless a plan is installed; see
        // DESIGN.md §8): an unresponsive worker that still holds its
        // TCP connections, and a flipped byte on the wire.
        if let Some(d) = crate::faults::server_stall() {
            std::thread::sleep(d);
        }
        if line.starts_with("GEN ") {
            crate::faults::corrupt_wire_line(&mut line);
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "QUIT" {
            break;
        }
        if line == "SHUTDOWN" {
            // graceful: acknowledge, then flip the accept loop's stop
            // flag; serve_on drains connections and the coordinator
            writeln!(writer, "OK shutdown")?;
            stop.store(true, Ordering::SeqCst);
            break;
        }
        if line == "STATS" {
            writeln!(writer, "{}", stats_line(&coord.metrics.snapshot()))?;
            continue;
        }
        if line == "METRICS" {
            // the exposition is framed by its own trailing `# EOF` line
            write!(writer, "{}", coord.metrics.prometheus())?;
            writer.flush()?;
            continue;
        }
        match parse_gen_line(line) {
            Ok(req) => {
                let rx = coord.submit(req);
                stream_session(&mut writer, &rx)?;
            }
            Err(e) => {
                // one terminal ERR line, then close: a client that can't
                // frame a request can't be trusted to resync mid-stream
                writeln!(writer, "ERR bad request: {e:#}")?;
                break;
            }
        }
    }
    Ok(())
}

/// Relay one session's event stream onto the wire.
fn stream_session(writer: &mut TcpStream, rx: &Receiver<TokenEvent>) -> Result<()> {
    loop {
        match rx.recv_timeout(STREAM_TIMEOUT) {
            Ok(TokenEvent::Token {
                token,
                index,
                latency,
            }) => {
                writeln!(writer, "TOK {index} {token} {}", latency.as_micros())?;
            }
            Ok(TokenEvent::Done {
                reason: FinishReason::Error(e),
                ..
            }) => {
                writeln!(writer, "ERR {e}")?;
                // a protocol ERR is a postmortem moment: keep the
                // preceding event history (DESIGN.md §7)
                obs::flight::dump("session error");
                return Ok(());
            }
            Ok(TokenEvent::Done {
                reason,
                tokens,
                total,
                truncated,
            }) => {
                writeln!(
                    writer,
                    "END {reason} {} {} {truncated}",
                    tokens.len(),
                    total.as_micros()
                )?;
                return Ok(());
            }
            Err(_) => {
                writeln!(writer, "ERR stream stalled")?;
                obs::flight::dump("stream stalled");
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CountBackend;

    fn cfg(max_batch: usize, wait_ms: u64) -> SchedulerConfig {
        SchedulerConfig::new(max_batch, Duration::from_millis(wait_ms))
    }

    /// Boot a coordinator over [`CountBackend`] plus a TCP frontend on
    /// an ephemeral port; returns everything a wire test needs.
    fn serve_fixture(
        backend: CountBackend,
        cfg: SchedulerConfig,
    ) -> (
        Arc<Coordinator>,
        std::net::SocketAddr,
        Arc<AtomicBool>,
        std::thread::JoinHandle<Result<()>>,
    ) {
        let coord = Coordinator::start(Arc::new(backend), cfg);
        let stop = Arc::new(AtomicBool::new(false));
        let (listener, addr) = crate::util::net::listen_reuse(0).unwrap();
        let handle = {
            let coord = coord.clone();
            let stop = stop.clone();
            std::thread::spawn(move || serve_on(listener, coord, stop))
        };
        (coord, addr, stop, handle)
    }

    #[test]
    fn single_session_roundtrip() {
        let coord = Coordinator::start(Arc::new(CountBackend::new()), cfg(4, 1));
        let c = coord
            .generate(GenerateRequest::greedy(vec![5, 6, 7], 4))
            .unwrap();
        // context lengths 3,4,5,6 -> tokens 3,4,5,6
        assert_eq!(c.tokens, vec![3, 4, 5, 6]);
        assert_eq!(c.reason, FinishReason::MaxTokens);
        assert!(c.ttft.is_some());
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_sessions_all_complete() {
        let coord = Coordinator::start(Arc::new(CountBackend::new()), cfg(8, 2));
        let rxs: Vec<_> = (1..=50)
            .map(|n| {
                (
                    n,
                    coord.submit(GenerateRequest::greedy(vec![0; n as usize % 7 + 1], 3)),
                )
            })
            .collect();
        for (_, rx) in rxs {
            let c = collect_stream(&rx, Duration::from_secs(10)).unwrap();
            assert_eq!(c.tokens.len(), 3);
            assert_eq!(c.reason, FinishReason::MaxTokens);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.responses, 50);
        assert_eq!(snap.tokens, 150);
        assert_eq!(snap.errors, 0);
        coord.shutdown();
    }

    #[test]
    fn size_flush_fills_the_first_batch() {
        // huge deadline: the first step must wait for max_batch arrivals
        let coord = Coordinator::start(Arc::new(CountBackend::new()), cfg(4, 10_000));
        let rxs: Vec<_> = (0..4)
            .map(|_| coord.submit(GenerateRequest::greedy(vec![1, 2], 1)))
            .collect();
        for rx in rxs {
            collect_stream(&rx, Duration::from_secs(10)).unwrap();
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.steps, 1, "one full step should serve all four");
        assert!((snap.mean_batch_size - 4.0).abs() < 1e-9);
        coord.shutdown();
    }

    #[test]
    fn deadline_flush_starts_a_partial_batch() {
        let coord = Coordinator::start(Arc::new(CountBackend::new()), cfg(16, 3));
        let c = coord
            .generate(GenerateRequest::greedy(vec![1, 2, 3], 2))
            .unwrap();
        assert_eq!(c.tokens.len(), 2);
        let snap = coord.metrics.snapshot();
        assert!(snap.mean_batch_size <= 1.0 + 1e-9);
        coord.shutdown();
    }

    #[test]
    fn short_requests_overtake_long_ones() {
        let coord = Coordinator::start(
            Arc::new(CountBackend::new().with_delay(Duration::from_millis(3))),
            cfg(8, 1),
        );
        let long = coord.submit(GenerateRequest::greedy(vec![1, 2], 64));
        // let the long request get admitted, then submit the short one
        std::thread::sleep(Duration::from_millis(20));
        let short = coord.submit(GenerateRequest::greedy(vec![3, 4], 2));
        let c_short = collect_stream(&short, Duration::from_secs(30)).unwrap();
        assert_eq!(c_short.reason, FinishReason::MaxTokens);
        // when the short one is done the long one must still be running
        assert!(
            matches!(long.try_recv(), Ok(TokenEvent::Token { .. })),
            "long request should still be streaming"
        );
        let c_long = collect_stream(&long, Duration::from_secs(30)).unwrap();
        assert_eq!(c_long.tokens.len(), 64);
        coord.shutdown();
    }

    #[test]
    fn shutdown_terminates_inflight_and_queued_waiters() {
        let coord = Coordinator::start(
            Arc::new(CountBackend::new().with_delay(Duration::from_millis(10))),
            cfg(2, 1),
        );
        // 2 admitted + 6 queued behind them, all effectively unbounded
        let rxs: Vec<_> = (0..8)
            .map(|_| coord.submit(GenerateRequest::greedy(vec![1, 2], 100_000)))
            .collect();
        std::thread::sleep(Duration::from_millis(40));
        coord.shutdown();
        for rx in rxs {
            let c = collect_stream(&rx, Duration::from_secs(5))
                .expect("every waiter must get a terminal event");
            assert_eq!(c.reason, FinishReason::Shutdown);
        }
        // submissions after shutdown are denied immediately, not stranded
        let rx = coord.submit(GenerateRequest::greedy(vec![1], 4));
        let c = collect_stream(&rx, Duration::from_secs(1)).unwrap();
        assert_eq!(c.reason, FinishReason::Shutdown);
    }

    #[test]
    fn parse_gen_line_roundtrip() {
        let req = parse_gen_line("GEN 16 0.8 40 1234 7 1 2 3").unwrap();
        assert_eq!(req.stop.max_new_tokens, 16);
        assert_eq!(req.stop.eos, Some(7));
        assert!((req.sampling.temperature - 0.8).abs() < 1e-6);
        assert_eq!(req.sampling.top_k, 40);
        assert_eq!(req.sampling.seed, 1234);
        assert_eq!(req.prompt, vec![1, 2, 3]);

        let greedy = parse_gen_line("GEN 4 0 0 0 -1 9 9").unwrap();
        assert!(greedy.sampling.is_greedy());
        assert_eq!(greedy.stop.eos, None);

        assert!(parse_gen_line("GEN 4 0 0 0 -1").is_err()); // no prompt
        assert!(parse_gen_line("NOPE 1 2").is_err());
        assert!(parse_gen_line("GEN x 0 0 0 -1 1").is_err());
    }

    #[test]
    fn parse_gen_line_rejects_each_malformed_field() {
        // every error path: the reason names the offending field so the
        // wire ERR line is actionable
        for (line, want) in [
            ("", "expected GEN"),
            ("STATSX", "expected GEN"),
            ("GEN", "missing max_new"),
            ("GEN 4", "missing temperature"),
            ("GEN 4 0.5", "missing top_k"),
            ("GEN 4 0.5 40", "missing seed"),
            ("GEN 4 0.5 40 7", "missing eos"),
            ("GEN 4 0.5 40 7 -1", "empty prompt"),
            ("GEN -2 0 0 0 -1 1", "max_new"),
            ("GEN 4 warm 0 0 -1 1", "temperature"),
            ("GEN 4 0 k 0 -1 1", "top_k"),
            ("GEN 4 0 0 -9 -1 1", "seed"),
            ("GEN 4 0 0 0 end 1", "eos"),
            ("GEN 4 0 0 0 -1 1 two 3", "bad token 'two'"),
            ("GEN 4 0 0 0 -1 1 -5 3", "negative token '-5'"),
            ("GEN 4 0 0 0 -1 -1", "negative token '-1'"),
        ] {
            let err = format!("{:#}", parse_gen_line(line).unwrap_err());
            assert!(err.contains(want), "line {line:?}: err {err:?} should name {want:?}");
        }
    }

    #[test]
    fn tcp_streaming_roundtrip() {
        let (coord, addr, stop, _serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 3 0 0 0 -1 1 2 3 4").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut toks = Vec::new();
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts[0] {
                "TOK" => toks.push(parts[2].parse::<i32>().unwrap()),
                "END" => {
                    assert_eq!(parts[1], "max_tokens");
                    assert_eq!(parts[2], "3");
                    assert_eq!(parts[4], "0", "in-window prompt: nothing truncated");
                    break;
                }
                other => panic!("unexpected line kind {other}"),
            }
        }
        // context lengths 4,5,6 -> tokens 4,5,6
        assert_eq!(toks, vec![4, 5, 6]);
        writeln!(s, "QUIT").unwrap();
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }

    #[test]
    fn oversized_prompt_reports_truncation_on_the_wire() {
        // CountBackend's window is 64: a 100-token prompt loses its
        // first 36 positions, and the END line must say so instead of
        // silently serving the tail
        let (coord, addr, stop, _serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let mut s = TcpStream::connect(addr).unwrap();
        let prompt: String = (0..100).map(|_| " 7").collect();
        writeln!(s, "GEN 2 0 0 0 -1{prompt}").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts[0] == "END" {
                assert_eq!(parts[1], "max_tokens");
                assert_eq!(parts[2], "2");
                assert_eq!(parts[4], "36", "100-token prompt in a 64 window drops 36: {line}");
                break;
            }
            assert_eq!(parts[0], "TOK", "unexpected line {line:?}");
        }
        writeln!(s, "QUIT").unwrap();
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }

    #[test]
    fn negative_prompt_token_rejected_on_the_wire() {
        // a negative id would alias into the embedding table via the
        // vocab modulus — the server must refuse it with a field-naming
        // ERR, not decode someone else's row
        let (coord, addr, stop, _serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 2 0 0 0 -1 1 -7 3").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR bad request:"), "{line:?}");
        assert!(line.contains("negative token '-7'"), "{line:?}");
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "connection closes after ERR");
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }

    #[test]
    fn wire_sessions_chunk_invariant_through_the_engine_loop() {
        // the same GEN line over servers configured with different
        // prefill chunks must stream identical tokens — the engine-loop
        // end of the determinism contract (DESIGN.md §2)
        let run = |chunk: usize| {
            let (coord, addr, stop, _serve) =
                serve_fixture(CountBackend::new(), cfg(4, 1).with_prefill_chunk(chunk));
            let mut s = TcpStream::connect(addr).unwrap();
            writeln!(s, "GEN 3 0 0 0 -1 1 2 3 4 5").unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut toks = Vec::new();
            loop {
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let parts: Vec<&str> = line.split_whitespace().collect();
                match parts[0] {
                    "TOK" => toks.push(parts[2].parse::<i32>().unwrap()),
                    "END" => break,
                    other => panic!("unexpected line kind {other}"),
                }
            }
            writeln!(s, "QUIT").unwrap();
            stop.store(true, Ordering::SeqCst);
            coord.shutdown();
            toks
        };
        let all_at_once = run(0);
        assert_eq!(all_at_once, vec![5, 6, 7]);
        for chunk in [1, 2, 4] {
            assert_eq!(run(chunk), all_at_once, "chunk {chunk} changed the stream");
        }
    }

    #[test]
    fn stats_wire_line_reports_cache_and_load_fields() {
        let (coord, addr, stop, _serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 2 0 0 0 -1 1 2").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            if line.starts_with("END") {
                break;
            }
        }
        writeln!(s, "STATS").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("STATS "), "{line}");
        // CountBackend has no cache: fields present, zeroed
        assert!(line.contains("cache_enabled=0"), "{line}");
        assert!(line.contains("cache_hit_rate=0.000"), "{line}");
        assert!(line.contains("cache_resident_bytes=0"), "{line}");
        assert!(line.contains("tokens=2"), "{line}");
        // load gauges (idle after END): present and drained to zero
        assert!(line.contains("queue_depth=0"), "{line}");
        assert!(line.contains("inflight=0"), "{line}");
        writeln!(s, "QUIT").unwrap();
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }

    #[test]
    fn metrics_wire_verb_returns_framed_exposition() {
        let (coord, addr, stop, _serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 2 0 0 0 -1 1 2").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            if line.starts_with("END") {
                break;
            }
        }
        writeln!(s, "METRICS").unwrap();
        let mut body = String::new();
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "EOF before the # EOF frame:\n{body}");
            if line.trim() == "# EOF" {
                break;
            }
            body.push_str(&line);
        }
        assert!(body.contains("bmoe_tokens_total 2\n"), "{body}");
        assert!(body.contains("bmoe_requests_total 1\n"), "{body}");
        assert!(body.contains("# TYPE bmoe_ttft_seconds histogram"), "{body}");
        assert!(body.contains("le=\"+Inf\""), "{body}");
        // the connection stays usable after a METRICS exchange
        writeln!(s, "STATS").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("STATS "), "{line}");
        writeln!(s, "QUIT").unwrap();
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }

    #[test]
    fn malformed_request_gets_one_err_line_then_close() {
        let (coord, addr, stop, _serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN nope").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(
            line.starts_with("ERR bad request:"),
            "malformed input must get a terminal ERR, got {line:?}"
        );
        assert!(line.contains("max_new"), "reason names the field: {line:?}");
        // ...and then the server closes: next read is clean EOF
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "connection must close after ERR");
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }

    #[test]
    fn shutdown_wire_command_drains_and_exits_serve_loop() {
        let (coord, addr, _stop, serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        // a normal session first, proving the server was live
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "GEN 1 0 0 0 -1 1 2").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        loop {
            line.clear();
            r.read_line(&mut line).unwrap();
            if line.starts_with("END") {
                break;
            }
        }
        writeln!(s, "SHUTDOWN").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "OK shutdown");
        // the accept loop exits cleanly and the coordinator is torn down:
        // post-shutdown submissions are denied with a terminal event
        serve.join().unwrap().unwrap();
        let c = coord
            .generate(GenerateRequest::greedy(vec![1], 4))
            .unwrap();
        assert_eq!(c.reason, FinishReason::Shutdown);
    }

    #[test]
    fn serve_on_join_is_not_blocked_by_an_idle_client() {
        // a client that holds its connection open without sending
        // anything must not wedge the drain: stop-aware reads time out
        let (coord, addr, stop, serve) =
            serve_fixture(CountBackend::new(), cfg(4, 1));
        let _idle = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        stop.store(true, Ordering::SeqCst);
        serve.join().unwrap().unwrap();
        coord.shutdown();
    }

    #[test]
    fn stats_line_formats_cache_gauge() {
        let m = Metrics::new();
        m.record_cache(crate::expertcache::CacheStatsSnapshot {
            enabled: true,
            hits: 30,
            misses: 10,
            resident_experts: 2,
            resident_bytes: 4096,
            budget_bytes: 8192,
            evictions: 1,
            ..Default::default()
        });
        let line = stats_line(&m.snapshot());
        assert!(line.contains("cache_enabled=1"), "{line}");
        assert!(line.contains("cache_hit_rate=0.750"), "{line}");
        assert!(line.contains("cache_resident_bytes=4096"), "{line}");
        assert!(line.contains("cache_resident_experts=2"), "{line}");
        assert!(line.contains("cache_evictions=1"), "{line}");
    }
}
