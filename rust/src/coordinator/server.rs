//! The coordinator proper: frontend channel, batching loop, worker pool,
//! and the optional TCP line-protocol frontend.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::backend::Backend;
use super::batcher::{Batch, DynamicBatcher};
use super::metrics::Metrics;

/// One in-flight generation request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub next_token: i32,
    pub latency: Duration,
}

/// Coordinator handle: submit requests, inspect metrics, shut down.
pub struct Coordinator {
    tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Start the batching loop + `workers` execution threads.
    pub fn start(
        backend: Arc<dyn Backend>,
        max_batch: usize,
        max_wait: Duration,
        workers: usize,
    ) -> Arc<Coordinator> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (btx, brx) = mpsc::channel::<Batch>();
        let brx = Arc::new(Mutex::new(brx));
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // batching loop
        {
            let metrics = metrics.clone();
            let stop = stop.clone();
            let max_batch = max_batch.min(backend.max_batch());
            threads.push(std::thread::spawn(move || {
                batching_loop(rx, btx, max_batch, max_wait, metrics, stop)
            }));
        }
        // worker pool
        for w in 0..workers.max(1) {
            let brx = brx.clone();
            let backend = backend.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("bmoe-worker-{w}"))
                    .spawn(move || worker_loop(brx, backend, metrics))
                    .expect("spawn worker"),
            );
        }

        Arc::new(Coordinator {
            tx,
            metrics,
            next_id: AtomicU64::new(1),
            stop,
            threads: Mutex::new(threads),
        })
    }

    /// Submit a prompt; returns a receiver for the response.
    pub fn submit(&self, tokens: Vec<i32>) -> Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_enqueue();
        let _ = self.tx.send(Request {
            id,
            tokens,
            enqueued: Instant::now(),
            reply: rtx,
        });
        rrx
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        let rx = self.submit(tokens);
        Ok(rx.recv()?)
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // dropping tx side is done when Coordinator drops; join threads
        let mut threads = self.threads.lock().unwrap();
        for t in threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn batching_loop(
    rx: Receiver<Request>,
    btx: Sender<Batch>,
    max_batch: usize,
    max_wait: Duration,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut batcher = DynamicBatcher::new(max_batch, max_wait);
    loop {
        if stop.load(Ordering::SeqCst) {
            if let Some(b) = batcher.flush() {
                let _ = btx.send(b);
            }
            return;
        }
        // wait bounded by the current flush deadline
        let timeout = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if let Some(batch) = batcher.push(req) {
                    send_batch(&btx, batch, &metrics);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    send_batch(&btx, batch, &metrics);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if let Some(b) = batcher.flush() {
                    send_batch(&btx, b, &metrics);
                }
                return;
            }
        }
    }
}

fn send_batch(btx: &Sender<Batch>, batch: Batch, metrics: &Metrics) {
    metrics.record_batch(batch.len(), batch.oldest.elapsed().as_secs_f64());
    let _ = btx.send(batch);
}

fn worker_loop(brx: Arc<Mutex<Receiver<Batch>>>, backend: Arc<dyn Backend>, metrics: Arc<Metrics>) {
    loop {
        let batch = {
            let guard = brx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        let prompts: Vec<Vec<i32>> = batch.requests.iter().map(|r| r.tokens.clone()).collect();
        match backend.forward(&prompts) {
            Ok(next) => {
                for (req, tok) in batch.requests.into_iter().zip(next) {
                    let latency = req.enqueued.elapsed();
                    metrics.record_response(latency.as_secs_f64());
                    let _ = req.reply.send(Response {
                        id: req.id,
                        next_token: tok,
                        latency,
                    });
                }
            }
            Err(e) => {
                eprintln!("[worker] backend error: {e:#}");
                for _ in &batch.requests {
                    metrics.record_error();
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TCP frontend: one line per request, space-separated token ids;
// response line: "<next_token>".  "QUIT" closes the connection.
// ---------------------------------------------------------------------------

pub fn serve_tcp(coord: Arc<Coordinator>, port: u16, stop: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    listener.set_nonblocking(true)?;
    eprintln!("[serve] listening on 127.0.0.1:{port}");
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _addr)) => {
                let coord = coord.clone();
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, coord);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "QUIT" {
            break;
        }
        let tokens: std::result::Result<Vec<i32>, _> =
            line.split_whitespace().map(str::parse).collect();
        match tokens {
            Ok(toks) if !toks.is_empty() => {
                let resp = coord.infer(toks)?;
                writeln!(writer, "{}", resp.next_token)?;
            }
            _ => {
                writeln!(writer, "ERR bad request")?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Backend that echoes prompt length (deterministic, instant).
    struct EchoBackend;
    impl Backend for EchoBackend {
        fn max_batch(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn name(&self) -> String {
            "echo".into()
        }
        fn forward(&self, prompts: &[Vec<i32>]) -> Result<Vec<i32>> {
            Ok(prompts.iter().map(|p| p.len() as i32).collect())
        }
    }

    #[test]
    fn roundtrip_single_request() {
        let coord = Coordinator::start(
            Arc::new(EchoBackend),
            4,
            Duration::from_millis(1),
            2,
        );
        let resp = coord.infer(vec![5, 6, 7]).unwrap();
        assert_eq!(resp.next_token, 3);
        coord.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let coord = Coordinator::start(
            Arc::new(EchoBackend),
            8,
            Duration::from_millis(2),
            3,
        );
        let rxs: Vec<_> = (1..=50)
            .map(|n| (n, coord.submit(vec![0; n as usize])))
            .collect();
        for (n, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.next_token, n as i32);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.responses, 50);
        assert!(snap.mean_batch_size >= 1.0);
        coord.shutdown();
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let coord = Coordinator::start(
            Arc::new(EchoBackend),
            8,
            Duration::from_millis(20),
            1,
        );
        // submit a burst before the deadline can fire
        let rxs: Vec<_> = (0..8).map(|_| coord.submit(vec![1, 2])).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        let snap = coord.metrics.snapshot();
        assert!(
            snap.mean_batch_size > 1.5,
            "burst should batch: {}",
            snap.mean_batch_size
        );
        coord.shutdown();
    }

    #[test]
    fn tcp_roundtrip() {
        let coord = Coordinator::start(
            Arc::new(EchoBackend),
            4,
            Duration::from_millis(1),
            1,
        );
        let stop = Arc::new(AtomicBool::new(false));
        let port = 17891;
        {
            let coord = coord.clone();
            let stop2 = stop.clone();
            std::thread::spawn(move || serve_tcp(coord, port, stop2));
        }
        std::thread::sleep(Duration::from_millis(100));
        let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        writeln!(s, "1 2 3 4").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "4");
        writeln!(s, "QUIT").unwrap();
        stop.store(true, Ordering::SeqCst);
        coord.shutdown();
    }
}
