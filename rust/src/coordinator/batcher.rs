//! Dynamic batcher: size-or-deadline flush policy.

use std::time::{Duration, Instant};

use super::server::Request;

/// A flushed batch ready for a worker.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// when the oldest member was enqueued (for queue-wait metrics)
    pub oldest: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Accumulates requests and decides when to flush.
///
/// Policy: flush when `max_batch` requests are queued, or when the oldest
/// queued request has waited `max_wait`.  `poll` is driven by the
/// coordinator loop; `push` never blocks.
pub struct DynamicBatcher {
    pub max_batch: usize,
    pub max_wait: Duration,
    queue: Vec<Request>,
    oldest: Option<Instant>,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        DynamicBatcher {
            max_batch,
            max_wait,
            queue: Vec::new(),
            oldest: None,
        }
    }

    pub fn push(&mut self, req: Request) -> Option<Batch> {
        if self.queue.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.queue.push(req);
        if self.queue.len() >= self.max_batch {
            return self.flush();
        }
        None
    }

    /// Deadline check; returns a batch if the oldest request expired.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self.oldest {
            Some(t0) if now.duration_since(t0) >= self.max_wait && !self.queue.is_empty() => {
                self.flush()
            }
            _ => None,
        }
    }

    /// Unconditional flush (shutdown / test).
    pub fn flush(&mut self) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest = self.oldest.take().unwrap_or_else(Instant::now);
        Some(Batch {
            requests: std::mem::take(&mut self.queue),
            oldest,
        })
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Time until the current deadline fires (None when queue is empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest
            .map(|t0| self.max_wait.saturating_sub(now.duration_since(t0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            tokens: vec![1, 2, 3],
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(1)).is_none());
        assert!(b.push(req(2)).is_none());
        let batch = b.push(req(3)).expect("flush at size");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(1));
        b.push(req(1));
        assert!(b.poll(Instant::now()).is_none() || true); // may or may not yet
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.poll(Instant::now()).expect("deadline flush");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn no_flush_when_empty() {
        let mut b = DynamicBatcher::new(2, Duration::from_millis(1));
        assert!(b.poll(Instant::now()).is_none());
        assert!(b.flush().is_none());
    }

    #[test]
    fn deadline_resets_after_flush() {
        let mut b = DynamicBatcher::new(2, Duration::from_millis(50));
        b.push(req(1));
        let _ = b.push(req(2)).unwrap(); // size flush
        assert!(b.time_to_deadline(Instant::now()).is_none());
        b.push(req(3)); // new epoch starts a fresh deadline
        assert!(b.time_to_deadline(Instant::now()).is_some());
    }

    #[test]
    fn preserves_fifo_order() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(1));
        b.push(req(10));
        b.push(req(11));
        let batch = b.push(req(12)).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 11, 12]);
    }
}
