//! Execution backends for the coordinator.
//!
//! The core backend operation is a **decode step over an in-flight
//! sequence set**: given the current context of every running sequence,
//! produce a next-token logit row per sequence.  Admission ("prefill")
//! is implicit in the first step a sequence participates in; both
//! backends here are stateless across steps and re-feed the grown
//! context each time, which is exactly what the compiled bucket graphs
//! support.
//!
//! * [`PjrtLmBackend`] — the full AOT-compiled LM (L2 graph with the L1
//!   Pallas kernels inside).  Each step is split into chunks that fit
//!   the compiled batch buckets; a chunk is padded up to the smallest
//!   bucket that holds it.  Oversized steps are *split*, never silently
//!   truncated to the largest bucket.
//! * [`NativeLmBackend`] — the pure-rust edge engine serving `L`
//!   residual ButterflyMoE blocks (the Alg.-1 hot path per block),
//!   either a packed `.bmoe` model artifact (mmap-loaded, DESIGN.md §3)
//!   or a seeded synthetic stand-in.  [`NativeMoeBackend`] is its
//!   historical single-layer name, kept as an alias.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::session::argmax;
use crate::artifact::{LoadMode, ShTensor};
use crate::expertcache::CacheStatsSnapshot;
use crate::moe::MoeLayer;
use crate::runtime::{spawn_engine_thread, EngineHandle, Manifest, Value};
use crate::tensor::{IntTensor, Tensor};

/// One running sequence: prompt plus everything generated so far.
#[derive(Clone, Debug)]
pub struct InflightSeq {
    pub id: u64,
    /// Full context: prompt tokens followed by generated tokens.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
}

impl InflightSeq {
    pub fn new(id: u64, prompt: Vec<i32>) -> Self {
        let prompt_len = prompt.len();
        InflightSeq {
            id,
            tokens: prompt,
            prompt_len,
        }
    }

    /// Number of tokens generated so far.
    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The trailing window of context that fits the model, left-truncated.
    pub fn context(&self, seq_len: usize) -> &[i32] {
        let take = self.tokens.len().min(seq_len);
        &self.tokens[self.tokens.len() - take..]
    }
}

/// The set of sequences currently resident in the decode loop.
/// Sequences join on admission and leave when they finish — membership
/// changes *between* steps, never during one.
#[derive(Debug, Default)]
pub struct InflightBatch {
    pub seqs: Vec<InflightSeq>,
}

impl InflightBatch {
    pub fn new() -> Self {
        InflightBatch { seqs: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn push(&mut self, seq: InflightSeq) {
        self.seqs.push(seq);
    }
}

/// Per-sequence result of one decode step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub seq_id: u64,
    /// Next-token logits over the backend's vocabulary.
    pub logits: Vec<f32>,
}

/// A serving backend advances every in-flight sequence by one token.
pub trait Backend: Send + Sync {
    /// Max sequences the scheduler should keep in flight at once.
    fn max_batch(&self) -> usize;
    /// Model context length; longer contexts are left-truncated.
    fn seq_len(&self) -> usize;
    /// Vocabulary size (length of every [`StepOutput::logits`] row).
    fn vocab(&self) -> usize;
    /// One decode step: next-token logits for every sequence in the
    /// batch, in batch order.
    fn step(&self, batch: &mut InflightBatch) -> Result<Vec<StepOutput>>;
    fn name(&self) -> String;
    /// Batch sizes worth driving once before measuring anything (the
    /// compiled bucket sizes for AOT backends — see [`warm`]).
    fn warmup_sizes(&self) -> Vec<usize> {
        vec![1, self.max_batch()]
    }
    /// Per-decode-step residency bookkeeping (expert-cache EWMA fold,
    /// admission, eviction).  The engine loop calls this after every
    /// step; backends without a cache keep the no-op default.
    fn tick_caches(&self) {}
    /// Expert-residency cache counters, when this backend serves a
    /// cached native layer (surfaced on the `STATS` wire line).
    fn cache_stats(&self) -> Option<CacheStatsSnapshot> {
        None
    }
    /// Pre-materialize the configured cache working set from warmup
    /// traffic so the first real request doesn't pay decode cost.
    fn prewarm_caches(&self) {}
}

/// Drive every warmup batch size once so one-time costs (XLA bucket
/// compilation, cache faulting) stay out of measured windows, then
/// pre-materialize the configured expert-cache working set from the
/// routing statistics that warmup traffic produced — TTFT on the first
/// real request doesn't eat materialization cost.  Shared by the serve
/// command/example, the serving bench, and anything else that times the
/// decode path.
pub fn warm(backend: &dyn Backend) -> Result<()> {
    for n in backend.warmup_sizes() {
        // vary the tail token so warmup exercises more than one route
        let prompts: Vec<Vec<i32>> = (0..n.max(1))
            .map(|i| vec![1, 2, (i % 61) as i32 + 2])
            .collect();
        greedy_next(backend, &prompts)?;
    }
    backend.prewarm_caches();
    Ok(())
}

/// One-shot convenience: greedy next token per prompt (quickstart /
/// parity checks).  Splits into `max_batch`-sized steps as needed.
pub fn greedy_next(backend: &dyn Backend, prompts: &[Vec<i32>]) -> Result<Vec<i32>> {
    let mut out = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(backend.max_batch().max(1)) {
        let mut batch = InflightBatch::new();
        for (i, p) in chunk.iter().enumerate() {
            batch.push(InflightSeq::new(i as u64, p.clone()));
        }
        for o in backend.step(&mut batch)? {
            out.push(argmax(&o.logits) as i32);
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------

pub struct PjrtLmBackend {
    handle: Arc<EngineHandle>,
    config: String,
    /// Shared with the engine thread per step (refcount, not weight copy).
    params: Arc<Vec<Value>>,
    /// (batch size, artifact name), ascending
    buckets: Vec<(usize, String)>,
    seq_len: usize,
    vocab: usize,
}

impl PjrtLmBackend {
    /// Read the manifest's `lm_logits` buckets and params (init export or
    /// a trained checkpoint), then start the engine's execution thread.
    /// Returns the backend plus the engine thread's join handle.
    pub fn start(
        artifacts_dir: &std::path::Path,
        config: &str,
        checkpoint: Option<&std::path::Path>,
    ) -> Result<(Self, std::thread::JoinHandle<()>)> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mcfg = manifest.config(config)?.clone();
        let mut buckets: Vec<(usize, String)> = manifest
            .find(config, "lm_logits")
            .into_iter()
            .map(|a| (a.inputs.last().unwrap().shape[0], a.name.clone()))
            .collect();
        anyhow::ensure!(!buckets.is_empty(), "no lm_logits artifacts for '{config}'");
        buckets.sort();
        let names = manifest
            .params
            .get(config)
            .context("params entry")?
            .names
            .clone();
        let params = match checkpoint {
            None => manifest.load_params(config)?,
            Some(p) => crate::train::load_checkpoint_values(p, &names)?,
        };
        let (handle, join) = spawn_engine_thread(artifacts_dir)?;
        Ok((
            PjrtLmBackend {
                handle,
                config: config.to_string(),
                params: Arc::new(params),
                buckets,
                seq_len: mcfg.seq_len,
                vocab: mcfg.vocab,
            },
            join,
        ))
    }

    /// Run one compiled forward over a chunk of at most `max_batch`
    /// sequences, appending a logits row per sequence to `out`.
    fn run_chunk(&self, seqs: &[InflightSeq], out: &mut Vec<StepOutput>) -> Result<()> {
        let bi = pick_bucket(&self.buckets, seqs.len())?;
        let (bucket, art) = self.buckets[bi].clone();
        let l = self.seq_len;
        // pad batch to bucket and every context to seq_len (left-aligned,
        // logits read at the context's last position)
        let mut toks = IntTensor::zeros(&[bucket, l]);
        for (i, s) in seqs.iter().enumerate() {
            let ctx = s.context(l);
            toks.data[i * l..i * l + ctx.len()].copy_from_slice(ctx);
        }
        let run = self
            .handle
            .run_with_prefix(&art, self.params.clone(), vec![Value::I32(toks)])?;
        let logits = run[0].as_f32()?; // (bucket, l, vocab)
        let v = self.vocab;
        for (i, s) in seqs.iter().enumerate() {
            let pos = s.context(l).len().max(1) - 1;
            let row = &logits.data[(i * l + pos) * v..(i * l + pos + 1) * v];
            out.push(StepOutput {
                seq_id: s.id,
                logits: row.to_vec(),
            });
        }
        Ok(())
    }
}

/// Index of the smallest bucket holding `n` sequences.  Unlike the old
/// behaviour (silent fallback to the largest bucket, dropping requests
/// past it), an `n` no bucket can hold is a hard error — callers split
/// oversized batches instead.
fn pick_bucket(buckets: &[(usize, String)], n: usize) -> Result<usize> {
    anyhow::ensure!(n > 0, "empty chunk");
    buckets
        .iter()
        .position(|(b, _)| *b >= n)
        .with_context(|| {
            format!(
                "chunk of {n} sequences exceeds the largest compiled bucket ({})",
                buckets.last().map(|(b, _)| *b).unwrap_or(0)
            )
        })
}

impl Backend for PjrtLmBackend {
    fn max_batch(&self) -> usize {
        self.buckets.last().unwrap().0
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn name(&self) -> String {
        format!("pjrt-lm:{}", self.config)
    }

    fn step(&self, batch: &mut InflightBatch) -> Result<Vec<StepOutput>> {
        anyhow::ensure!(!batch.is_empty());
        let mut out = Vec::with_capacity(batch.len());
        // split oversized steps across compiled buckets (no silent drop)
        for chunk in batch.seqs.chunks(self.max_batch()) {
            self.run_chunk(chunk, &mut out)?;
        }
        Ok(out)
    }

    fn warmup_sizes(&self) -> Vec<usize> {
        self.buckets.iter().map(|(b, _)| *b).collect()
    }
}

// ---------------------------------------------------------------------------

/// Historical name of the native backend, kept for the single-layer
/// call sites (tests, benches, examples): `NativeMoeBackend::new(layer,
/// …)` is [`NativeLmBackend::new`], which wraps one layer.
pub type NativeMoeBackend = NativeLmBackend;

/// Native multi-layer LM backend: embeds each sequence's context by
/// mean-pooling a token table, runs `L` residual ButterflyMoE blocks
/// (`x ← x + block(x)`), and returns the readout scores as logits.
///
/// Two ways to build one:
///
/// * [`NativeLmBackend::from_artifact`] — serve a packed `.bmoe` model
///   (`bmoe serve --native --model model.bmoe`); with
///   [`LoadMode::Mmap`](crate::artifact::LoadMode) the substrate
///   bitplanes, angle tables and dense projections are borrowed from
///   the file mapping (DESIGN.md §3).
/// * [`synthesize`](crate::artifact::synthesize) +
///   [`NativeLmBackend::from_layers`] — the seeded stand-in model used
///   when no `--model` is given; `bmoe pack-model` packs exactly this
///   model, so packed-vs-in-memory token streams are bit-identical
///   (pinned by `rust/tests/artifact.rs`).
///
/// Decoded streams are invariant to worker count, expert-cache budget
/// and load mode — the layer-level guarantees compose because each block
/// runs the same `MoeLayer::forward` contract.
pub struct NativeLmBackend {
    layers: Vec<Arc<dyn MoeLayer>>,
    embed: ShTensor,   // (vocab, d_model)
    readout: ShTensor, // (vocab, d_model)
    vocab: usize,
    seq_len: usize,
    max_batch: usize,
    /// bytes of the backing `.bmoe` file (0 = synthetic, no file)
    file_bytes: usize,
    load_mode: Option<LoadMode>,
}

impl NativeLmBackend {
    /// Single-layer compatibility constructor (the historical
    /// `NativeMoeBackend::new`): fixed-seed random embed/readout tables
    /// around one layer.
    pub fn new(layer: Arc<dyn MoeLayer>, vocab: usize, seq_len: usize, max_batch: usize) -> Self {
        let d = layer.d_model();
        let mut rng = crate::util::Rng::new(0xE13BED);
        let embed = ShTensor::from_tensor(Tensor::rand_normal(&[vocab, d], 0.1, &mut rng));
        let readout = ShTensor::from_tensor(Tensor::rand_normal(&[vocab, d], 0.1, &mut rng));
        Self::from_layers(vec![layer], embed, readout, vocab, seq_len, max_batch)
    }

    /// Assemble from an explicit layer stack and embedding tables.
    /// Layers must agree on `d_model`; worker pools / expert caches are
    /// attached per layer *before* this call.
    pub fn from_layers(
        layers: Vec<Arc<dyn MoeLayer>>,
        embed: ShTensor,
        readout: ShTensor,
        vocab: usize,
        seq_len: usize,
        max_batch: usize,
    ) -> Self {
        assert!(!layers.is_empty(), "backend needs at least one layer");
        let d = layers[0].d_model();
        for l in &layers {
            assert_eq!(l.d_model(), d, "layers disagree on d_model");
        }
        assert_eq!(embed.shape, vec![vocab, d], "embed shape");
        assert_eq!(readout.shape, vec![vocab, d], "readout shape");
        NativeLmBackend {
            layers,
            embed,
            readout,
            vocab,
            seq_len,
            max_batch,
            file_bytes: 0,
            load_mode: None,
        }
    }

    /// The one attach policy the packed and synthetic construction
    /// paths share (so they cannot drift — the parity the tests pin):
    /// the worker pool is shared across layers, the cache budget splits
    /// evenly (a split that rounds to zero attaches no cache), and each
    /// block learns its stack index so sampled stage timings carry a
    /// `layer` label (see `crate::obs::trace`).
    fn attach_stack(
        layers: Vec<crate::moe::ButterflyMoeLayer>,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
    ) -> Vec<Arc<dyn MoeLayer>> {
        let per_layer_budget = cache_budget_bytes / layers.len().max(1);
        layers
            .into_iter()
            .enumerate()
            .map(|(i, mut layer)| {
                layer.set_trace_layer(i as u32);
                if let Some(p) = &pool {
                    layer.attach_worker_pool(p.clone());
                }
                if per_layer_budget > 0 {
                    layer.attach_expert_cache(
                        crate::expertcache::ExpertCacheConfig::with_budget_bytes(per_layer_budget),
                    );
                }
                Arc::new(layer) as Arc<dyn MoeLayer>
            })
            .collect()
    }

    /// Build the full stack from a loaded model artifact, attaching a
    /// worker pool (shared across layers) and an optional expert-cache
    /// budget (split evenly across layers) to every block.
    pub fn from_artifact(
        artifact: &crate::artifact::ModelArtifact,
        max_batch: usize,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
    ) -> Result<Self> {
        let m = &artifact.manifest;
        let layers = Self::attach_stack(artifact.build_layers()?, pool, cache_budget_bytes);
        let mut b = Self::from_layers(
            layers,
            artifact.embed()?,
            artifact.readout()?,
            m.vocab,
            m.seq_len,
            max_batch,
        );
        b.file_bytes = artifact.file_bytes();
        b.load_mode = Some(artifact.mode());
        Ok(b)
    }

    /// Build from a synthesized model with the same pool/cache attach
    /// policy as [`Self::from_artifact`] — the one construction path
    /// `bmoe serve --native` (no `--model`) and the examples share.
    pub fn from_synth(
        model: crate::artifact::SynthModel,
        max_batch: usize,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
    ) -> Self {
        let (vocab, seq_len) = (model.manifest.vocab, model.manifest.seq_len);
        let layers = Self::attach_stack(model.layers, pool, cache_budget_bytes);
        Self::from_layers(
            layers,
            ShTensor::from_tensor(model.embed),
            ShTensor::from_tensor(model.readout),
            vocab,
            seq_len,
            max_batch,
        )
    }

    pub fn layers(&self) -> &[Arc<dyn MoeLayer>] {
        &self.layers
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Bytes of the backing model file (0 when serving the in-memory
    /// synthetic model) — the `memmodel` file-bytes accounting hook.
    pub fn file_bytes(&self) -> usize {
        self.file_bytes
    }

    /// Mean-pool the context's embeddings into one d_model vector.
    fn pool(&self, ctx: &[i32], out: &mut [f32]) {
        let d = self.layers[0].d_model();
        let embed = self.embed.data();
        out.fill(0.0);
        for &t in ctx {
            let row = &embed[(t as usize % self.vocab) * d..][..d];
            for (o, &e) in out.iter_mut().zip(row) {
                *o += e;
            }
        }
        let inv = 1.0 / ctx.len().max(1) as f32;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

impl Backend for NativeLmBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn name(&self) -> String {
        // advertise the hot path's parallelism (1w = sequential); the
        // decoded streams are worker-count invariant either way
        let workers = self.layers[0].worker_pool().map_or(1, |p| p.threads());
        let load = self
            .load_mode
            .map(|m| format!(":{}", m.name()))
            .unwrap_or_default();
        if self.layers.len() == 1 {
            format!("native-moe:{}exp:{}w{}", self.layers[0].n_experts(), workers, load)
        } else {
            format!(
                "native-lm:{}blk:{}exp:{}w{}",
                self.layers.len(),
                self.layers[0].n_experts(),
                workers,
                load
            )
        }
    }

    fn tick_caches(&self) {
        for l in &self.layers {
            if let Some(c) = l.expert_cache() {
                c.tick();
            }
        }
    }

    /// Aggregated over all layers' caches (counters and byte gauges
    /// sum; `enabled` is the OR).  `None` when no layer has a cache.
    fn cache_stats(&self) -> Option<CacheStatsSnapshot> {
        let mut agg: Option<CacheStatsSnapshot> = None;
        for l in &self.layers {
            if let Some(c) = l.expert_cache() {
                let s = c.snapshot();
                agg = Some(match agg {
                    None => s,
                    Some(mut a) => {
                        a.enabled |= s.enabled;
                        a.hits += s.hits;
                        a.misses += s.misses;
                        a.evictions += s.evictions;
                        a.materializations += s.materializations;
                        a.resident_experts += s.resident_experts;
                        a.resident_bytes += s.resident_bytes;
                        a.budget_bytes += s.budget_bytes;
                        a
                    }
                });
            }
        }
        agg
    }

    fn prewarm_caches(&self) {
        for l in &self.layers {
            if let Some(c) = l.expert_cache() {
                c.prewarm();
            }
        }
    }

    fn step(&self, batch: &mut InflightBatch) -> Result<Vec<StepOutput>> {
        anyhow::ensure!(!batch.is_empty());
        let d = self.layers[0].d_model();
        let t = batch.len();
        let mut x = vec![0.0f32; t * d];
        for (i, s) in batch.seqs.iter().enumerate() {
            self.pool(s.context(self.seq_len), &mut x[i * d..(i + 1) * d]);
        }
        // L residual ButterflyMoE blocks: x <- x + block(x)
        let mut y = vec![0.0f32; t * d];
        for layer in &self.layers {
            layer.forward(&x, t, &mut y);
            for (xv, &yv) in x.iter_mut().zip(&y) {
                *xv += yv;
            }
        }
        let readout = self.readout.data();
        Ok(batch
            .seqs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let yi = &x[i * d..(i + 1) * d];
                let logits: Vec<f32> = (0..self.vocab)
                    .map(|v| {
                        let row = &readout[v * d..(v + 1) * d];
                        row.iter().zip(yi).map(|(a, b)| a * b).sum()
                    })
                    .collect();
                StepOutput {
                    seq_id: s.id,
                    logits,
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ButterflyMoeLayer;
    use crate::util::Rng;

    fn native() -> NativeMoeBackend {
        let mut rng = Rng::new(1);
        let layer = Arc::new(ButterflyMoeLayer::random(16, 32, 4, 2, None, &mut rng));
        NativeMoeBackend::new(layer, 64, 8, 4)
    }

    fn batch_of(prompts: &[Vec<i32>]) -> InflightBatch {
        let mut b = InflightBatch::new();
        for (i, p) in prompts.iter().enumerate() {
            b.push(InflightSeq::new(i as u64, p.clone()));
        }
        b
    }

    #[test]
    fn native_backend_step_deterministic() {
        let b = native();
        let mut b1 = batch_of(&[vec![1, 2, 3], vec![9, 9]]);
        let mut b2 = batch_of(&[vec![1, 2, 3], vec![9, 9]]);
        let o1 = b.step(&mut b1).unwrap();
        let o2 = b.step(&mut b2).unwrap();
        assert_eq!(o1.len(), 2);
        for (a, c) in o1.iter().zip(&o2) {
            assert_eq!(a.seq_id, c.seq_id);
            assert_eq!(a.logits, c.logits);
            assert_eq!(a.logits.len(), b.vocab());
        }
    }

    #[test]
    fn greedy_next_matches_argmax_of_step() {
        let b = native();
        let prompts = vec![vec![1, 2, 3, 4], vec![60, 61, 62, 63]];
        let next = greedy_next(&b, &prompts).unwrap();
        let outs = b.step(&mut batch_of(&prompts)).unwrap();
        for (n, o) in next.iter().zip(&outs) {
            assert_eq!(*n, argmax(&o.logits) as i32);
            assert!((*n as usize) < 64);
        }
    }

    #[test]
    fn greedy_next_splits_oversized_prompt_sets() {
        let b = native(); // max_batch = 4
        let prompts: Vec<Vec<i32>> = (0..11).map(|i| vec![i, i + 1, i + 2]).collect();
        let next = greedy_next(&b, &prompts).unwrap();
        assert_eq!(next.len(), 11);
        // same prompts in small batches must agree (no cross-seq state)
        let solo = greedy_next(&b, &prompts[..1]).unwrap();
        assert_eq!(next[0], solo[0]);
    }

    #[test]
    fn native_backend_parallel_step_matches_sequential_bitwise() {
        // same weights, pooled vs sequential layer: logits (and thus
        // every decoded token) must agree bit-for-bit
        let seq = native();
        let mut rng = Rng::new(1);
        let mut layer = ButterflyMoeLayer::random(16, 32, 4, 2, None, &mut rng);
        layer.attach_worker_pool(Arc::new(crate::parallel::WorkerPool::new(4)));
        let par = NativeMoeBackend::new(Arc::new(layer), 64, 8, 4);
        assert!(par.name().ends_with(":4w"), "{}", par.name());
        assert!(seq.name().ends_with(":1w"), "{}", seq.name());
        let prompts = [vec![1, 2, 3], vec![9, 9], vec![40, 41, 42, 43]];
        let o1 = seq.step(&mut batch_of(&prompts)).unwrap();
        let o2 = par.step(&mut batch_of(&prompts)).unwrap();
        for (a, b) in o1.iter().zip(&o2) {
            assert_eq!(a.logits, b.logits);
        }
    }

    #[test]
    fn multi_layer_backend_is_deterministic_and_layer_count_matters() {
        let spec = crate::artifact::SynthSpec {
            d_model: 16,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_layers: 3,
            vocab: 64,
            seq_len: 8,
            depth: None,
            seed: 5,
        };
        let build = |n_layers: usize| {
            let mut s = spec;
            s.n_layers = n_layers;
            let m = crate::artifact::synthesize(&s);
            let layers: Vec<Arc<dyn MoeLayer>> = m
                .layers
                .into_iter()
                .map(|l| Arc::new(l) as Arc<dyn MoeLayer>)
                .collect();
            NativeLmBackend::from_layers(
                layers,
                crate::artifact::ShTensor::from_tensor(m.embed),
                crate::artifact::ShTensor::from_tensor(m.readout),
                64,
                8,
                4,
            )
        };
        let b3 = build(3);
        assert!(b3.name().starts_with("native-lm:3blk:4exp:"), "{}", b3.name());
        assert_eq!(b3.n_layers(), 3);
        assert_eq!(b3.file_bytes(), 0, "synthetic model has no backing file");
        let prompts = [vec![1, 2, 3], vec![9, 9]];
        let o1 = b3.step(&mut batch_of(&prompts)).unwrap();
        let o2 = b3.step(&mut batch_of(&prompts)).unwrap();
        for (a, c) in o1.iter().zip(&o2) {
            assert_eq!(a.logits, c.logits);
            assert_eq!(a.logits.len(), 64);
            assert!(a.logits.iter().all(|v| v.is_finite()));
        }
        // the residual stack is real: depth changes the logits (layer 0
        // weights are identical across the two builds by seeding)
        let b1 = build(1);
        assert!(b1.name().starts_with("native-moe:"), "{}", b1.name());
        let o_single = b1.step(&mut batch_of(&prompts)).unwrap();
        assert_ne!(o_single[0].logits, o1[0].logits);
    }

    #[test]
    fn inflight_seq_context_window() {
        let s = InflightSeq::new(0, (0..10).collect());
        assert_eq!(s.context(4), &[6, 7, 8, 9]);
        assert_eq!(s.context(16).len(), 10);
        assert_eq!(s.generated(), 0);
    }

    #[test]
    fn pick_bucket_smallest_fit_and_hard_error() {
        let buckets = vec![(1usize, "b1".into()), (4, "b4".into()), (16, "b16".into())];
        assert_eq!(pick_bucket(&buckets, 1).unwrap(), 0);
        assert_eq!(pick_bucket(&buckets, 2).unwrap(), 1);
        assert_eq!(pick_bucket(&buckets, 4).unwrap(), 1);
        assert_eq!(pick_bucket(&buckets, 16).unwrap(), 2);
        // past the largest bucket: hard error, not a silent fallback
        assert!(pick_bucket(&buckets, 17).is_err());
        assert!(pick_bucket(&buckets, 0).is_err());
    }
}
