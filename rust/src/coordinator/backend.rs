//! Execution backends for the coordinator.
//!
//! The core backend operation is a **step over an in-flight sequence
//! set**: fold every running sequence's pending tokens into its state
//! and produce a next-token logit row for each sequence that is past
//! prefill.  An admitted sequence starts in the [`SeqPhase::Prefill`]
//! phase and consumes its prompt in multi-token chunks (bounded by
//! [`InflightBatch::prefill_chunk`], the `--prefill-chunk` knob), so the
//! blocked butterfly/GEMM kernels see `t > 1` row batches on the prompt
//! path while in-flight decode inter-token latency stays bounded; once
//! the prompt is consumed the sequence decodes one token per step
//! (DESIGN.md §2).
//!
//! * [`PjrtLmBackend`] — the full AOT-compiled LM (L2 graph with the L1
//!   Pallas kernels inside).  Each step is split into chunks that fit
//!   the compiled batch buckets; a chunk is padded up to the smallest
//!   bucket that holds it.  Oversized steps are *split*, never silently
//!   truncated to the largest bucket.
//! * [`NativeLmBackend`] — the pure-rust edge engine serving `L`
//!   residual ButterflyMoE blocks (the Alg.-1 hot path per block),
//!   either a packed `.bmoe` model artifact (mmap-loaded, DESIGN.md §3)
//!   or a seeded synthetic stand-in.  [`NativeMoeBackend`] is its
//!   historical single-layer name, kept as an alias.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::session::argmax;
use crate::artifact::{LoadMode, ShTensor};
use crate::expertcache::CacheStatsSnapshot;
use crate::moe::MoeLayer;
use crate::runtime::{spawn_engine_thread, EngineHandle, Manifest, Value};
use crate::tensor::{IntTensor, Tensor};

/// Lifecycle phase of an in-flight sequence (DESIGN.md §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Prompt ingestion: `consumed` prompt positions folded so far
    /// (window-skipped positions count as consumed, see
    /// [`InflightSeq::next_span`]).
    Prefill { consumed: usize },
    /// Prompt fully ingested; every step samples one new token.
    Decode,
}

/// One running sequence: prompt plus everything generated so far.
#[derive(Clone, Debug)]
pub struct InflightSeq {
    pub id: u64,
    /// Full context: prompt tokens followed by generated tokens.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Prefill/decode phase machine; backends advance it via
    /// [`Self::next_span`].
    pub phase: SeqPhase,
    /// Prompt tokens dropped at prefill start because the prompt
    /// exceeds the model window (surfaced on the wire `END` line and as
    /// a `session_truncated` event — never silent).
    pub truncated: usize,
    /// Backend-owned pooled feature state: running sum of per-token
    /// feature rows plus the number of rows folded in.  Lazily sized by
    /// the native backend; backends that recompute from the raw context
    /// (PJRT) leave it empty.
    pub pool_sum: Vec<f32>,
    pub pool_count: usize,
}

impl InflightSeq {
    pub fn new(id: u64, prompt: Vec<i32>) -> Self {
        let prompt_len = prompt.len();
        InflightSeq {
            id,
            tokens: prompt,
            prompt_len,
            phase: SeqPhase::Prefill { consumed: 0 },
            truncated: 0,
            pool_sum: Vec::new(),
            pool_count: 0,
        }
    }

    /// Number of tokens generated so far.
    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The trailing window of context that fits the model, left-truncated.
    pub fn context(&self, seq_len: usize) -> &[i32] {
        let take = self.tokens.len().min(seq_len);
        &self.tokens[self.tokens.len() - take..]
    }

    /// True once every surviving prompt token has been folded — the
    /// sequence samples a token on each step from here on.
    pub fn prefill_done(&self) -> bool {
        matches!(self.phase, SeqPhase::Decode)
    }

    /// Advance the phase machine and return the next span of `tokens`
    /// to fold this step: the next `chunk`-capped bite of prompt during
    /// prefill (`chunk == 0` means the whole remainder — the
    /// all-at-once behaviour), or the single newly sampled token during
    /// decode.  On first contact the span skips prompt positions that
    /// already fell out of the `seq_len` window (no prefill steps are
    /// burned on tokens the model would never see) and records the drop
    /// in [`Self::truncated`].
    pub fn next_span(&mut self, seq_len: usize, chunk: usize) -> std::ops::Range<usize> {
        match self.phase {
            SeqPhase::Prefill { mut consumed } => {
                if consumed == 0 {
                    let skip = self.prompt_len.saturating_sub(seq_len);
                    self.truncated = skip;
                    consumed = skip;
                }
                let end = if chunk == 0 {
                    self.prompt_len
                } else {
                    (consumed + chunk).min(self.prompt_len)
                };
                self.phase = if end >= self.prompt_len {
                    SeqPhase::Decode
                } else {
                    SeqPhase::Prefill { consumed: end }
                };
                consumed..end
            }
            SeqPhase::Decode => self.tokens.len().saturating_sub(1)..self.tokens.len(),
        }
    }
}

/// The set of sequences currently resident in the decode loop.
/// Sequences join on admission and leave when they finish — membership
/// changes *between* steps, never during one.
#[derive(Debug, Default)]
pub struct InflightBatch {
    pub seqs: Vec<InflightSeq>,
    /// Max prompt tokens one step may ingest per prefilling sequence
    /// (the `--prefill-chunk` knob); 0 = unlimited, i.e. the whole
    /// prompt in the sequence's first step.  Small chunks bound the
    /// inter-token latency of in-flight decode batch-mates; large
    /// chunks amortize better (DESIGN.md §2).
    pub prefill_chunk: usize,
}

impl InflightBatch {
    pub fn new() -> Self {
        InflightBatch::default()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn push(&mut self, seq: InflightSeq) {
        self.seqs.push(seq);
    }
}

/// Per-sequence result of one step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub seq_id: u64,
    /// Next-token logits over the backend's vocabulary; `None` while
    /// the sequence is still mid-prefill (nothing to sample yet).  The
    /// step that ingests the final prompt chunk also emits logits, so
    /// an all-at-once prefill reproduces the historical one-step
    /// behaviour exactly.
    pub logits: Option<Vec<f32>>,
    /// Prompt tokens folded this step (0 during decode) — the
    /// scheduler's prefill-throughput accounting.
    pub prefilled: usize,
}

/// A serving backend advances every in-flight sequence by one token.
pub trait Backend: Send + Sync {
    /// Max sequences the scheduler should keep in flight at once.
    fn max_batch(&self) -> usize;
    /// Model context length; longer contexts are left-truncated.
    fn seq_len(&self) -> usize;
    /// Vocabulary size (length of every [`StepOutput::logits`] row).
    fn vocab(&self) -> usize;
    /// One step: fold each sequence's pending tokens (the next prompt
    /// chunk during prefill, the newly sampled token during decode) and
    /// return one [`StepOutput`] per sequence, in batch order.  Logits
    /// are `None` for sequences still mid-prefill.
    fn step(&self, batch: &mut InflightBatch) -> Result<Vec<StepOutput>>;
    fn name(&self) -> String;
    /// Batch sizes worth driving once before measuring anything (the
    /// compiled bucket sizes for AOT backends — see [`warm`]).
    fn warmup_sizes(&self) -> Vec<usize> {
        vec![1, self.max_batch()]
    }
    /// Per-decode-step residency bookkeeping (expert-cache EWMA fold,
    /// admission, eviction).  The engine loop calls this after every
    /// step; backends without a cache keep the no-op default.
    fn tick_caches(&self) {}
    /// Expert-residency cache counters, when this backend serves a
    /// cached native layer (surfaced on the `STATS` wire line).
    fn cache_stats(&self) -> Option<CacheStatsSnapshot> {
        None
    }
    /// Pre-materialize the configured cache working set from warmup
    /// traffic so the first real request doesn't pay decode cost.
    fn prewarm_caches(&self) {}
}

/// Drive every warmup batch size once so one-time costs (XLA bucket
/// compilation, cache faulting) stay out of measured windows, then
/// pre-materialize the configured expert-cache working set from the
/// routing statistics that warmup traffic produced — TTFT on the first
/// real request doesn't eat materialization cost.  Shared by the serve
/// command/example, the serving bench, and anything else that times the
/// decode path.
pub fn warm(backend: &dyn Backend) -> Result<()> {
    for n in backend.warmup_sizes() {
        // vary the tail token so warmup exercises more than one route
        let prompts: Vec<Vec<i32>> = (0..n.max(1))
            .map(|i| vec![1, 2, (i % 61) as i32 + 2])
            .collect();
        greedy_next(backend, &prompts)?;
    }
    backend.prewarm_caches();
    Ok(())
}

/// One-shot convenience: greedy next token per prompt (quickstart /
/// parity checks).  Splits into `max_batch`-sized steps as needed.
pub fn greedy_next(backend: &dyn Backend, prompts: &[Vec<i32>]) -> Result<Vec<i32>> {
    let mut out = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(backend.max_batch().max(1)) {
        let mut batch = InflightBatch::new();
        for (i, p) in chunk.iter().enumerate() {
            batch.push(InflightSeq::new(i as u64, p.clone()));
        }
        for o in backend.step(&mut batch)? {
            // one-shot batches keep the default prefill_chunk = 0, so
            // every prompt completes prefill (and yields logits) in the
            // single step above
            let logits = o
                .logits
                .context("backend returned no logits for an all-at-once prefill")?;
            out.push(argmax(&logits) as i32);
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------

pub struct PjrtLmBackend {
    handle: Arc<EngineHandle>,
    config: String,
    /// Shared with the engine thread per step (refcount, not weight copy).
    params: Arc<Vec<Value>>,
    /// (batch size, artifact name), ascending
    buckets: Vec<(usize, String)>,
    seq_len: usize,
    vocab: usize,
}

impl PjrtLmBackend {
    /// Read the manifest's `lm_logits` buckets and params (init export or
    /// a trained checkpoint), then start the engine's execution thread.
    /// Returns the backend plus the engine thread's join handle.
    pub fn start(
        artifacts_dir: &std::path::Path,
        config: &str,
        checkpoint: Option<&std::path::Path>,
    ) -> Result<(Self, std::thread::JoinHandle<()>)> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mcfg = manifest.config(config)?.clone();
        let mut buckets: Vec<(usize, String)> = manifest
            .find(config, "lm_logits")
            .into_iter()
            .map(|a| (a.inputs.last().unwrap().shape[0], a.name.clone()))
            .collect();
        anyhow::ensure!(!buckets.is_empty(), "no lm_logits artifacts for '{config}'");
        buckets.sort();
        let names = manifest
            .params
            .get(config)
            .context("params entry")?
            .names
            .clone();
        let params = match checkpoint {
            None => manifest.load_params(config)?,
            Some(p) => crate::train::load_checkpoint_values(p, &names)?,
        };
        let (handle, join) = spawn_engine_thread(artifacts_dir)?;
        Ok((
            PjrtLmBackend {
                handle,
                config: config.to_string(),
                params: Arc::new(params),
                buckets,
                seq_len: mcfg.seq_len,
                vocab: mcfg.vocab,
            },
            join,
        ))
    }

    /// Run one compiled forward over a chunk of at most `max_batch`
    /// sequences, appending a logits row per sequence to `out`.
    fn run_chunk(&self, seqs: &[&InflightSeq], out: &mut Vec<Vec<f32>>) -> Result<()> {
        let bi = pick_bucket(&self.buckets, seqs.len())?;
        let (bucket, art) = self.buckets[bi].clone();
        let l = self.seq_len;
        // pad batch to bucket and every context to seq_len (left-aligned,
        // logits read at the context's last position)
        let mut toks = IntTensor::zeros(&[bucket, l]);
        for (i, s) in seqs.iter().enumerate() {
            let ctx = s.context(l);
            toks.data[i * l..i * l + ctx.len()].copy_from_slice(ctx);
        }
        let run = self
            .handle
            .run_with_prefix(&art, self.params.clone(), vec![Value::I32(toks)])?;
        let logits = run[0].as_f32()?; // (bucket, l, vocab)
        let v = self.vocab;
        for (i, s) in seqs.iter().enumerate() {
            let pos = s.context(l).len().max(1) - 1;
            out.push(logits.data[(i * l + pos) * v..(i * l + pos + 1) * v].to_vec());
        }
        Ok(())
    }
}

/// Index of the smallest bucket holding `n` sequences.  Unlike the old
/// behaviour (silent fallback to the largest bucket, dropping requests
/// past it), an `n` no bucket can hold is a hard error — callers split
/// oversized batches instead.
fn pick_bucket(buckets: &[(usize, String)], n: usize) -> Result<usize> {
    anyhow::ensure!(n > 0, "empty chunk");
    buckets
        .iter()
        .position(|(b, _)| *b >= n)
        .with_context(|| {
            format!(
                "chunk of {n} sequences exceeds the largest compiled bucket ({})",
                buckets.last().map(|(b, _)| *b).unwrap_or(0)
            )
        })
}

impl Backend for PjrtLmBackend {
    fn max_batch(&self) -> usize {
        self.buckets.last().unwrap().0
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn name(&self) -> String {
        format!("pjrt-lm:{}", self.config)
    }

    fn step(&self, batch: &mut InflightBatch) -> Result<Vec<StepOutput>> {
        anyhow::ensure!(!batch.is_empty());
        // The compiled graphs are stateless and re-feed the whole
        // context window each step, so mid-prefill steps only advance
        // the phase machine, and the step that completes a prefill
        // reads logits from the full window — chunk-size invariance is
        // structural on this backend.
        let chunk = batch.prefill_chunk;
        let mut out: Vec<StepOutput> = Vec::with_capacity(batch.len());
        for s in batch.seqs.iter_mut() {
            let was_prefill = !s.prefill_done();
            let span = s.next_span(self.seq_len, chunk);
            out.push(StepOutput {
                seq_id: s.id,
                logits: None,
                prefilled: if was_prefill { span.len() } else { 0 },
            });
        }
        let need: Vec<usize> = (0..batch.len())
            .filter(|&i| batch.seqs[i].prefill_done())
            .collect();
        // split oversized steps across compiled buckets (no silent drop)
        for idx in need.chunks(self.max_batch()) {
            let seqs: Vec<&InflightSeq> = idx.iter().map(|&i| &batch.seqs[i]).collect();
            let mut rows = Vec::with_capacity(seqs.len());
            self.run_chunk(&seqs, &mut rows)?;
            for (&i, row) in idx.iter().zip(rows) {
                out[i].logits = Some(row);
            }
        }
        Ok(out)
    }

    fn warmup_sizes(&self) -> Vec<usize> {
        self.buckets.iter().map(|(b, _)| *b).collect()
    }
}

// ---------------------------------------------------------------------------

/// Historical name of the native backend, kept for the single-layer
/// call sites (tests, benches, examples): `NativeMoeBackend::new(layer,
/// …)` is [`NativeLmBackend::new`], which wraps one layer.
pub type NativeMoeBackend = NativeLmBackend;

/// Native multi-layer LM backend: each context token's embedding row
/// runs the `L` residual ButterflyMoE blocks (`x ← x + block(x)`)
/// independently, the resulting feature rows are folded left-to-right
/// into a per-sequence running mean, and the readout scores of that
/// mean are the logits.
///
/// Because the per-token function is row-independent and the fold
/// order is fixed by token position, the pooled state — and therefore
/// every decoded token — is bit-identical no matter how the prompt is
/// split into prefill chunks (DESIGN.md §2).  A prefill chunk of `c`
/// tokens reaches the blocked kernels as one `t = c` row batch (summed
/// across prefilling sequences), so the per-expert dispatch-block
/// gather is shared across the chunk; decode folds exactly one new row
/// per step, making it O(1) in context length.
///
/// Two ways to build one:
///
/// * [`NativeLmBackend::from_artifact`] — serve a packed `.bmoe` model
///   (`bmoe serve --native --model model.bmoe`); with
///   [`LoadMode::Mmap`](crate::artifact::LoadMode) the substrate
///   bitplanes, angle tables and dense projections are borrowed from
///   the file mapping (DESIGN.md §3).
/// * [`synthesize`](crate::artifact::synthesize) +
///   [`NativeLmBackend::from_layers`] — the seeded stand-in model used
///   when no `--model` is given; `bmoe pack-model` packs exactly this
///   model, so packed-vs-in-memory token streams are bit-identical
///   (pinned by `rust/tests/artifact.rs`).
///
/// Decoded streams are invariant to worker count, expert-cache budget
/// and load mode — the layer-level guarantees compose because each block
/// runs the same `MoeLayer::forward` contract.
pub struct NativeLmBackend {
    layers: Vec<Arc<dyn MoeLayer>>,
    embed: ShTensor,   // (vocab, d_model)
    readout: ShTensor, // (vocab, d_model)
    vocab: usize,
    seq_len: usize,
    max_batch: usize,
    /// bytes of the backing `.bmoe` file (0 = synthetic, no file)
    file_bytes: usize,
    load_mode: Option<LoadMode>,
}

impl NativeLmBackend {
    /// Single-layer compatibility constructor (the historical
    /// `NativeMoeBackend::new`): fixed-seed random embed/readout tables
    /// around one layer.
    pub fn new(layer: Arc<dyn MoeLayer>, vocab: usize, seq_len: usize, max_batch: usize) -> Self {
        let d = layer.d_model();
        let mut rng = crate::util::Rng::new(0xE13BED);
        let embed = ShTensor::from_tensor(Tensor::rand_normal(&[vocab, d], 0.1, &mut rng));
        let readout = ShTensor::from_tensor(Tensor::rand_normal(&[vocab, d], 0.1, &mut rng));
        Self::from_layers(vec![layer], embed, readout, vocab, seq_len, max_batch)
    }

    /// Assemble from an explicit layer stack and embedding tables.
    /// Layers must agree on `d_model`; worker pools / expert caches are
    /// attached per layer *before* this call.
    pub fn from_layers(
        layers: Vec<Arc<dyn MoeLayer>>,
        embed: ShTensor,
        readout: ShTensor,
        vocab: usize,
        seq_len: usize,
        max_batch: usize,
    ) -> Self {
        assert!(!layers.is_empty(), "backend needs at least one layer");
        let d = layers[0].d_model();
        for l in &layers {
            assert_eq!(l.d_model(), d, "layers disagree on d_model");
        }
        assert_eq!(embed.shape, vec![vocab, d], "embed shape");
        assert_eq!(readout.shape, vec![vocab, d], "readout shape");
        NativeLmBackend {
            layers,
            embed,
            readout,
            vocab,
            seq_len,
            max_batch,
            file_bytes: 0,
            load_mode: None,
        }
    }

    /// The one attach policy the packed and synthetic construction
    /// paths share (so they cannot drift — the parity the tests pin):
    /// the worker pool is shared across layers, the cache budget splits
    /// evenly (a split that rounds to zero attaches no cache), and each
    /// block learns its stack index so sampled stage timings carry a
    /// `layer` label (see `crate::obs::trace`).
    ///
    /// `act_quant` flips every block's substrate GEMM to the W1.58A8
    /// path (the serving default; `--exact` opts out).  Because the a8
    /// forward never consults the residency cache
    /// (`ButterflyMoeLayer::experts_forward`), no cache is attached in
    /// that mode even when a budget was requested — materializing
    /// working sets no forward would read wastes the budget silently;
    /// `cmd_serve` surfaces the conflict as a warning instead.
    fn attach_stack(
        layers: Vec<crate::moe::ButterflyMoeLayer>,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
        act_quant: bool,
    ) -> Vec<Arc<dyn MoeLayer>> {
        let per_layer_budget = cache_budget_bytes / layers.len().max(1);
        layers
            .into_iter()
            .enumerate()
            .map(|(i, mut layer)| {
                layer.set_trace_layer(i as u32);
                layer.act_quant = act_quant;
                if let Some(p) = &pool {
                    layer.attach_worker_pool(p.clone());
                }
                if per_layer_budget > 0 && !act_quant {
                    layer.attach_expert_cache(
                        crate::expertcache::ExpertCacheConfig::with_budget_bytes(per_layer_budget),
                    );
                }
                Arc::new(layer) as Arc<dyn MoeLayer>
            })
            .collect()
    }

    /// Build the full stack from a loaded model artifact, attaching a
    /// worker pool (shared across layers) and an optional expert-cache
    /// budget (split evenly across layers) to every block.  Exact (f32)
    /// substrate GEMMs — the bit-pinned path every parity test is
    /// defined against; serving uses [`Self::from_artifact_opts`] to
    /// select W1.58A8 by default.
    pub fn from_artifact(
        artifact: &crate::artifact::ModelArtifact,
        max_batch: usize,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
    ) -> Result<Self> {
        Self::from_artifact_opts(artifact, max_batch, pool, cache_budget_bytes, false)
    }

    /// [`Self::from_artifact`] with the activation-quantization choice
    /// explicit: `act_quant = true` is the W1.58A8 serving default,
    /// `false` the exact path (`--exact`).
    pub fn from_artifact_opts(
        artifact: &crate::artifact::ModelArtifact,
        max_batch: usize,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
        act_quant: bool,
    ) -> Result<Self> {
        let m = &artifact.manifest;
        let layers =
            Self::attach_stack(artifact.build_layers()?, pool, cache_budget_bytes, act_quant);
        let mut b = Self::from_layers(
            layers,
            artifact.embed()?,
            artifact.readout()?,
            m.vocab,
            m.seq_len,
            max_batch,
        );
        b.file_bytes = artifact.file_bytes();
        b.load_mode = Some(artifact.mode());
        Ok(b)
    }

    /// Build from a synthesized model with the same pool/cache attach
    /// policy as [`Self::from_artifact`] — the one construction path
    /// `bmoe serve --native` (no `--model`) and the examples share.
    /// Exact substrate GEMMs, like [`Self::from_artifact`].
    pub fn from_synth(
        model: crate::artifact::SynthModel,
        max_batch: usize,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
    ) -> Self {
        Self::from_synth_opts(model, max_batch, pool, cache_budget_bytes, false)
    }

    /// [`Self::from_synth`] with the activation-quantization choice
    /// explicit (see [`Self::from_artifact_opts`]).
    pub fn from_synth_opts(
        model: crate::artifact::SynthModel,
        max_batch: usize,
        pool: Option<Arc<crate::parallel::WorkerPool>>,
        cache_budget_bytes: usize,
        act_quant: bool,
    ) -> Self {
        let (vocab, seq_len) = (model.manifest.vocab, model.manifest.seq_len);
        let layers = Self::attach_stack(model.layers, pool, cache_budget_bytes, act_quant);
        Self::from_layers(
            layers,
            ShTensor::from_tensor(model.embed),
            ShTensor::from_tensor(model.readout),
            vocab,
            seq_len,
            max_batch,
        )
    }

    pub fn layers(&self) -> &[Arc<dyn MoeLayer>] {
        &self.layers
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Bytes of the backing model file (0 when serving the in-memory
    /// synthetic model) — the `memmodel` file-bytes accounting hook.
    pub fn file_bytes(&self) -> usize {
        self.file_bytes
    }

}

impl Backend for NativeLmBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn name(&self) -> String {
        // advertise the hot path's parallelism (1w = sequential); the
        // decoded streams are worker-count invariant either way
        let workers = self.layers[0].worker_pool().map_or(1, |p| p.threads());
        let load = self
            .load_mode
            .map(|m| format!(":{}", m.name()))
            .unwrap_or_default();
        if self.layers.len() == 1 {
            format!("native-moe:{}exp:{}w{}", self.layers[0].n_experts(), workers, load)
        } else {
            format!(
                "native-lm:{}blk:{}exp:{}w{}",
                self.layers.len(),
                self.layers[0].n_experts(),
                workers,
                load
            )
        }
    }

    fn tick_caches(&self) {
        for l in &self.layers {
            if let Some(c) = l.expert_cache() {
                c.tick();
            }
        }
    }

    /// Aggregated over all layers' caches (counters and byte gauges
    /// sum; `enabled` is the OR).  `None` when no layer has a cache.
    fn cache_stats(&self) -> Option<CacheStatsSnapshot> {
        let mut agg: Option<CacheStatsSnapshot> = None;
        for l in &self.layers {
            if let Some(c) = l.expert_cache() {
                let s = c.snapshot();
                agg = Some(match agg {
                    None => s,
                    Some(mut a) => {
                        a.enabled |= s.enabled;
                        a.hits += s.hits;
                        a.misses += s.misses;
                        a.evictions += s.evictions;
                        a.materializations += s.materializations;
                        a.resident_experts += s.resident_experts;
                        a.resident_bytes += s.resident_bytes;
                        a.budget_bytes += s.budget_bytes;
                        a
                    }
                });
            }
        }
        agg
    }

    fn prewarm_caches(&self) {
        for l in &self.layers {
            if let Some(c) = l.expert_cache() {
                c.prewarm();
            }
        }
    }

    fn step(&self, batch: &mut InflightBatch) -> Result<Vec<StepOutput>> {
        anyhow::ensure!(!batch.is_empty());
        let d = self.layers[0].d_model();
        let chunk = batch.prefill_chunk;
        // 1) Advance every sequence's phase machine and collect this
        //    step's pending spans: the next prompt chunk for prefilling
        //    sequences, the one newly sampled token for decoding ones.
        let mut spans = Vec::with_capacity(batch.len());
        let mut rows = 0usize;
        let mut prefill_rows = 0usize;
        for s in batch.seqs.iter_mut() {
            let was_prefill = !s.prefill_done();
            let span = s.next_span(self.seq_len, chunk);
            if was_prefill {
                prefill_rows += span.len();
            }
            rows += span.len();
            spans.push((span, was_prefill));
        }
        // Steps that ingest prompt rows are sampled as the `prefill`
        // stage; the timer writes a side registry only (DESIGN.md §7).
        let _prefill_timer = (prefill_rows > 0).then(|| {
            crate::obs::stage_timer(crate::obs::Stage::Prefill, 0)
        });
        // 2) One batched residual-stack forward over every pending row:
        //    each token's embedding runs the L blocks independently, so
        //    a prefill chunk reaches the blocked kernels as a t > 1 row
        //    batch and the per-expert dispatch gather is shared across
        //    the chunk's tokens.
        let embed = self.embed.data();
        let mut x = vec![0.0f32; rows * d];
        let mut r = 0usize;
        for (s, (span, _)) in batch.seqs.iter().zip(&spans) {
            for &tok in &s.tokens[span.clone()] {
                // negative wire tokens are rejected at parse time
                // (`parse_gen_line`); unchecked, `tok as usize` would
                // wrap and alias an arbitrary embedding row
                debug_assert!(tok >= 0, "negative token {tok} reached the embed gather");
                let row = &embed[(tok as usize % self.vocab) * d..][..d];
                x[r * d..(r + 1) * d].copy_from_slice(row);
                r += 1;
            }
        }
        if rows > 0 {
            // L residual ButterflyMoE blocks: x <- x + block(x)
            let mut y = vec![0.0f32; rows * d];
            for layer in &self.layers {
                layer.forward(&x, rows, &mut y);
                for (xv, &yv) in x.iter_mut().zip(&y) {
                    *xv += yv;
                }
            }
        }
        // 3) Fold each sequence's feature rows into its running pooled
        //    sum left-to-right.  The fold order is a function of token
        //    position only — chunk boundaries change *when* rows enter
        //    the pool, never the float association — which is the whole
        //    chunk-size-invariance argument (DESIGN.md §2).
        let readout = self.readout.data();
        let mut out = Vec::with_capacity(batch.len());
        let mut r = 0usize;
        for (s, (span, was_prefill)) in batch.seqs.iter_mut().zip(&spans) {
            if s.pool_sum.is_empty() {
                s.pool_sum = vec![0.0f32; d];
            }
            for _ in span.clone() {
                for (a, &b) in s.pool_sum.iter_mut().zip(&x[r * d..(r + 1) * d]) {
                    *a += b;
                }
                s.pool_count += 1;
                r += 1;
            }
            let logits = s.prefill_done().then(|| {
                let inv = 1.0 / s.pool_count.max(1) as f32;
                let yi: Vec<f32> = s.pool_sum.iter().map(|v| v * inv).collect();
                (0..self.vocab)
                    .map(|v| {
                        let row = &readout[v * d..(v + 1) * d];
                        row.iter().zip(&yi).map(|(a, b)| a * b).sum()
                    })
                    .collect()
            });
            out.push(StepOutput {
                seq_id: s.id,
                logits,
                prefilled: if *was_prefill { span.len() } else { 0 },
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ButterflyMoeLayer;
    use crate::util::Rng;

    fn native() -> NativeMoeBackend {
        let mut rng = Rng::new(1);
        let layer = Arc::new(ButterflyMoeLayer::random(16, 32, 4, 2, None, &mut rng));
        NativeMoeBackend::new(layer, 64, 8, 4)
    }

    fn batch_of(prompts: &[Vec<i32>]) -> InflightBatch {
        let mut b = InflightBatch::new();
        for (i, p) in prompts.iter().enumerate() {
            b.push(InflightSeq::new(i as u64, p.clone()));
        }
        b
    }

    #[test]
    fn native_backend_step_deterministic() {
        let b = native();
        let mut b1 = batch_of(&[vec![1, 2, 3], vec![9, 9]]);
        let mut b2 = batch_of(&[vec![1, 2, 3], vec![9, 9]]);
        let o1 = b.step(&mut b1).unwrap();
        let o2 = b.step(&mut b2).unwrap();
        assert_eq!(o1.len(), 2);
        for ((a, c), p) in o1.iter().zip(&o2).zip(&[3usize, 2]) {
            assert_eq!(a.seq_id, c.seq_id);
            assert_eq!(a.logits, c.logits);
            assert_eq!(a.prefilled, *p, "all-at-once prefill folds the whole prompt");
            assert_eq!(a.logits.as_ref().unwrap().len(), b.vocab());
        }
    }

    #[test]
    fn greedy_next_matches_argmax_of_step() {
        let b = native();
        let prompts = vec![vec![1, 2, 3, 4], vec![60, 61, 62, 63]];
        let next = greedy_next(&b, &prompts).unwrap();
        let outs = b.step(&mut batch_of(&prompts)).unwrap();
        for (n, o) in next.iter().zip(&outs) {
            assert_eq!(*n, argmax(o.logits.as_ref().unwrap()) as i32);
            assert!((*n as usize) < 64);
        }
    }

    #[test]
    fn greedy_next_splits_oversized_prompt_sets() {
        let b = native(); // max_batch = 4
        let prompts: Vec<Vec<i32>> = (0..11).map(|i| vec![i, i + 1, i + 2]).collect();
        let next = greedy_next(&b, &prompts).unwrap();
        assert_eq!(next.len(), 11);
        // same prompts in small batches must agree (no cross-seq state)
        let solo = greedy_next(&b, &prompts[..1]).unwrap();
        assert_eq!(next[0], solo[0]);
    }

    #[test]
    fn native_backend_parallel_step_matches_sequential_bitwise() {
        // same weights, pooled vs sequential layer: logits (and thus
        // every decoded token) must agree bit-for-bit
        let seq = native();
        let mut rng = Rng::new(1);
        let mut layer = ButterflyMoeLayer::random(16, 32, 4, 2, None, &mut rng);
        layer.attach_worker_pool(Arc::new(crate::parallel::WorkerPool::new(4)));
        let par = NativeMoeBackend::new(Arc::new(layer), 64, 8, 4);
        assert!(par.name().ends_with(":4w"), "{}", par.name());
        assert!(seq.name().ends_with(":1w"), "{}", seq.name());
        let prompts = [vec![1, 2, 3], vec![9, 9], vec![40, 41, 42, 43]];
        let o1 = seq.step(&mut batch_of(&prompts)).unwrap();
        let o2 = par.step(&mut batch_of(&prompts)).unwrap();
        for (a, b) in o1.iter().zip(&o2) {
            assert_eq!(a.logits, b.logits);
        }
    }

    #[test]
    fn multi_layer_backend_is_deterministic_and_layer_count_matters() {
        let spec = crate::artifact::SynthSpec {
            d_model: 16,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_layers: 3,
            vocab: 64,
            seq_len: 8,
            depth: None,
            seed: 5,
        };
        let build = |n_layers: usize| {
            let mut s = spec;
            s.n_layers = n_layers;
            let m = crate::artifact::synthesize(&s);
            let layers: Vec<Arc<dyn MoeLayer>> = m
                .layers
                .into_iter()
                .map(|l| Arc::new(l) as Arc<dyn MoeLayer>)
                .collect();
            NativeLmBackend::from_layers(
                layers,
                crate::artifact::ShTensor::from_tensor(m.embed),
                crate::artifact::ShTensor::from_tensor(m.readout),
                64,
                8,
                4,
            )
        };
        let b3 = build(3);
        assert!(b3.name().starts_with("native-lm:3blk:4exp:"), "{}", b3.name());
        assert_eq!(b3.n_layers(), 3);
        assert_eq!(b3.file_bytes(), 0, "synthetic model has no backing file");
        let prompts = [vec![1, 2, 3], vec![9, 9]];
        let o1 = b3.step(&mut batch_of(&prompts)).unwrap();
        let o2 = b3.step(&mut batch_of(&prompts)).unwrap();
        for (a, c) in o1.iter().zip(&o2) {
            assert_eq!(a.logits, c.logits);
            let l = a.logits.as_ref().unwrap();
            assert_eq!(l.len(), 64);
            assert!(l.iter().all(|v| v.is_finite()));
        }
        // the residual stack is real: depth changes the logits (layer 0
        // weights are identical across the two builds by seeding)
        let b1 = build(1);
        assert!(b1.name().starts_with("native-moe:"), "{}", b1.name());
        let o_single = b1.step(&mut batch_of(&prompts)).unwrap();
        assert_ne!(o_single[0].logits, o1[0].logits);
    }

    #[test]
    fn inflight_seq_context_window() {
        let s = InflightSeq::new(0, (0..10).collect());
        assert_eq!(s.context(4), &[6, 7, 8, 9]);
        assert_eq!(s.context(16).len(), 10);
        assert_eq!(s.generated(), 0);
        assert!(!s.prefill_done());
    }

    #[test]
    fn next_span_phase_machine() {
        let mut s = InflightSeq::new(0, (0..10).collect());
        assert_eq!(s.next_span(16, 4), 0..4);
        assert_eq!(s.next_span(16, 4), 4..8);
        assert!(!s.prefill_done());
        assert_eq!(s.next_span(16, 4), 8..10);
        assert!(s.prefill_done());
        assert_eq!(s.truncated, 0);
        // decode: the span is the one newly pushed token
        s.tokens.push(99);
        assert_eq!(s.next_span(16, 4), 10..11);
        // chunk 0 = the whole remainder in one span
        let mut a = InflightSeq::new(1, (0..10).collect());
        assert_eq!(a.next_span(16, 0), 0..10);
        assert!(a.prefill_done());
        // oversized prompts skip the out-of-window prefix on first
        // contact and record the drop
        let mut t = InflightSeq::new(2, (0..10).collect());
        assert_eq!(t.next_span(4, 3), 6..9);
        assert_eq!(t.truncated, 6);
        assert_eq!(t.next_span(4, 3), 9..10);
        assert!(t.prefill_done());
    }

    /// Greedy-decode `n` tokens of one prompt, prefilled in
    /// `chunk`-token bites, driving the backend the way the scheduler
    /// does.  Returns (tokens, prefill steps, first logits row).
    fn decode_with_chunk(
        b: &dyn Backend,
        prompt: &[i32],
        chunk: usize,
        n: usize,
    ) -> (Vec<i32>, usize, Vec<f32>) {
        let mut batch = InflightBatch::new();
        batch.prefill_chunk = chunk;
        batch.push(InflightSeq::new(0, prompt.to_vec()));
        let mut toks = Vec::new();
        let mut prefill_steps = 0;
        let mut first_logits = Vec::new();
        while toks.len() < n {
            let outs = b.step(&mut batch).unwrap();
            if outs[0].prefilled > 0 {
                prefill_steps += 1;
            }
            if let Some(l) = &outs[0].logits {
                if first_logits.is_empty() {
                    first_logits = l.clone();
                }
                let t = argmax(l) as i32;
                toks.push(t);
                batch.seqs[0].tokens.push(t);
            }
        }
        (toks, prefill_steps, first_logits)
    }

    #[test]
    fn prefill_chunk_size_never_changes_the_stream() {
        let b = native(); // d16, vocab 64, seq_len 8
        let prompt = [5, 9, 2, 33, 17, 4, 8];
        let (all, steps_all, logits_all) = decode_with_chunk(&b, &prompt, 0, 6);
        assert_eq!(steps_all, 1, "chunk 0 = all-at-once single prefill step");
        for chunk in [1usize, 2, 3, 4] {
            let (toks, steps, logits) = decode_with_chunk(&b, &prompt, chunk, 6);
            assert_eq!(toks, all, "chunk {chunk} changed the decoded stream");
            assert_eq!(
                logits, logits_all,
                "chunk {chunk} changed the first logits row bitwise"
            );
            assert_eq!(steps, (prompt.len() + chunk - 1) / chunk);
        }
    }

    #[test]
    fn oversized_prompt_skips_window_and_reports_truncated() {
        let b = native(); // seq_len 8
        let long: Vec<i32> = (0..20).collect();
        // chunked prefill must not burn steps on the 12 tokens that
        // already fell out of the window: 8 survivors / chunk 4 = 2
        let (_, steps, logits_long) = decode_with_chunk(&b, &long, 4, 1);
        assert_eq!(steps, 2, "out-of-window prefix must be skipped, not fed");
        // the surviving suffix alone produces bit-identical logits
        let (_, _, logits_tail) = decode_with_chunk(&b, &long[12..], 0, 1);
        assert_eq!(logits_long, logits_tail);
        let mut batch = InflightBatch::new();
        batch.push(InflightSeq::new(0, long));
        b.step(&mut batch).unwrap();
        assert_eq!(batch.seqs[0].truncated, 12);
    }

    #[test]
    fn pick_bucket_smallest_fit_and_hard_error() {
        let buckets = vec![(1usize, "b1".into()), (4, "b4".into()), (16, "b16".into())];
        assert_eq!(pick_bucket(&buckets, 1).unwrap(), 0);
        assert_eq!(pick_bucket(&buckets, 2).unwrap(), 1);
        assert_eq!(pick_bucket(&buckets, 4).unwrap(), 1);
        assert_eq!(pick_bucket(&buckets, 16).unwrap(), 2);
        // past the largest bucket: hard error, not a silent fallback
        assert!(pick_bucket(&buckets, 17).is_err());
        assert!(pick_bucket(&buckets, 0).is_err());
    }
}
