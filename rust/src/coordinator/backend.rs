//! Execution backends for the coordinator.
//!
//! * [`PjrtLmBackend`] — the full AOT-compiled LM (L2 graph with the L1
//!   Pallas kernels inside).  Each flush is padded to the smallest
//!   compiled batch bucket; returns argmax next-token per sequence.
//! * [`NativeMoeBackend`] — the pure-rust edge engine serving a single
//!   ButterflyMoE layer (the Alg.-1 hot path); used for edge-deployment
//!   demos and throughput ablations where no LM wrapper is wanted.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::moe::MoeLayer;
use crate::runtime::{spawn_engine_thread, EngineHandle, Manifest, Value};
use crate::tensor::IntTensor;

/// A serving backend turns a batch of token prompts into next tokens.
pub trait Backend: Send + Sync {
    /// Max sequences per forward (the largest compiled bucket).
    fn max_batch(&self) -> usize;
    /// Model context length; prompts are right-aligned / truncated to it.
    fn seq_len(&self) -> usize;
    /// Greedy next token for each prompt.
    fn forward(&self, prompts: &[Vec<i32>]) -> Result<Vec<i32>>;
    fn name(&self) -> String;
}

// ---------------------------------------------------------------------------

pub struct PjrtLmBackend {
    handle: Arc<EngineHandle>,
    config: String,
    params: Vec<Value>,
    /// (batch size, artifact name), ascending
    buckets: Vec<(usize, String)>,
    seq_len: usize,
    vocab: usize,
}

impl PjrtLmBackend {
    /// Read the manifest's `lm_logits` buckets and params (init export or
    /// a trained checkpoint), then start the engine's execution thread.
    /// Returns the backend plus the engine thread's join handle.
    pub fn start(
        artifacts_dir: &std::path::Path,
        config: &str,
        checkpoint: Option<&std::path::Path>,
    ) -> Result<(Self, std::thread::JoinHandle<()>)> {
        let manifest = Manifest::load(artifacts_dir)?;
        let mcfg = manifest.config(config)?.clone();
        let mut buckets: Vec<(usize, String)> = manifest
            .find(config, "lm_logits")
            .into_iter()
            .map(|a| (a.inputs.last().unwrap().shape[0], a.name.clone()))
            .collect();
        anyhow::ensure!(!buckets.is_empty(), "no lm_logits artifacts for '{config}'");
        buckets.sort();
        let names = manifest
            .params
            .get(config)
            .context("params entry")?
            .names
            .clone();
        let params = match checkpoint {
            None => manifest.load_params(config)?,
            Some(p) => crate::train::load_checkpoint_values(p, &names)?,
        };
        let (handle, join) = spawn_engine_thread(artifacts_dir)?;
        Ok((
            PjrtLmBackend {
                handle,
                config: config.to_string(),
                params,
                buckets,
                seq_len: mcfg.seq_len,
                vocab: mcfg.vocab,
            },
            join,
        ))
    }

    fn bucket_for(&self, n: usize) -> &(usize, String) {
        self.buckets
            .iter()
            .find(|(b, _)| *b >= n)
            .unwrap_or_else(|| self.buckets.last().unwrap())
    }
}

impl Backend for PjrtLmBackend {
    fn max_batch(&self) -> usize {
        self.buckets.last().unwrap().0
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn name(&self) -> String {
        format!("pjrt-lm:{}", self.config)
    }

    fn forward(&self, prompts: &[Vec<i32>]) -> Result<Vec<i32>> {
        anyhow::ensure!(!prompts.is_empty());
        anyhow::ensure!(prompts.len() <= self.max_batch(), "batch too large");
        let (bucket, art) = self.bucket_for(prompts.len()).clone();
        let l = self.seq_len;
        // pad batch to bucket and every prompt to seq_len (left-aligned,
        // argmax read at the prompt's last position)
        let mut toks = IntTensor::zeros(&[bucket, l]);
        for (i, p) in prompts.iter().enumerate() {
            let take = p.len().min(l);
            let src = &p[p.len() - take..];
            toks.data[i * l..i * l + take].copy_from_slice(src);
        }
        let mut inputs = self.params.clone();
        inputs.push(Value::I32(toks));
        let out = self.handle.run(&art, inputs)?;
        let logits = out[0].as_f32()?; // (bucket, l, vocab)
        let v = self.vocab;
        let next = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let pos = p.len().min(l) - 1;
                let row = &logits.data[(i * l + pos) * v..(i * l + pos + 1) * v];
                argmax(row) as i32
            })
            .collect();
        Ok(next)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

// ---------------------------------------------------------------------------

/// Native single-layer backend: embeds tokens with a fixed random table,
/// runs the ButterflyMoE layer, returns argmax over a random readout —
/// a deterministic stand-in model that exercises the true edge hot path.
pub struct NativeMoeBackend {
    pub layer: Arc<dyn MoeLayer>,
    embed: Vec<f32>, // (vocab, d_model)
    readout: Vec<f32>, // (vocab, d_model)
    vocab: usize,
    seq_len: usize,
    max_batch: usize,
}

impl NativeMoeBackend {
    pub fn new(layer: Arc<dyn MoeLayer>, vocab: usize, seq_len: usize, max_batch: usize) -> Self {
        let d = layer.d_model();
        let mut rng = crate::util::Rng::new(0xE13BED);
        let mut embed = vec![0.0f32; vocab * d];
        rng.fill_normal(&mut embed, 0.1);
        let mut readout = vec![0.0f32; vocab * d];
        rng.fill_normal(&mut readout, 0.1);
        NativeMoeBackend {
            layer,
            embed,
            readout,
            vocab,
            seq_len,
            max_batch,
        }
    }

    /// Mean-pool the prompt's embeddings into one d_model vector.
    fn pool(&self, prompt: &[i32], out: &mut [f32]) {
        let d = self.layer.d_model();
        out.fill(0.0);
        let take = prompt.len().min(self.seq_len);
        for &t in &prompt[prompt.len() - take..] {
            let row = &self.embed[(t as usize % self.vocab) * d..][..d];
            for (o, &e) in out.iter_mut().zip(row) {
                *o += e;
            }
        }
        let inv = 1.0 / take.max(1) as f32;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

impl Backend for NativeMoeBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn name(&self) -> String {
        format!("native-moe:{}exp", self.layer.n_experts())
    }

    fn forward(&self, prompts: &[Vec<i32>]) -> Result<Vec<i32>> {
        let d = self.layer.d_model();
        let t = prompts.len();
        let mut x = vec![0.0f32; t * d];
        for (i, p) in prompts.iter().enumerate() {
            self.pool(p, &mut x[i * d..(i + 1) * d]);
        }
        let mut y = vec![0.0f32; t * d];
        self.layer.forward(&x, t, &mut y);
        Ok((0..t)
            .map(|i| {
                let yi = &y[i * d..(i + 1) * d];
                let mut best = (0usize, f32::NEG_INFINITY);
                for v in 0..self.vocab {
                    let row = &self.readout[v * d..(v + 1) * d];
                    let score: f32 = row.iter().zip(yi).map(|(a, b)| a * b).sum();
                    if score > best.1 {
                        best = (v, score);
                    }
                }
                best.0 as i32
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::ButterflyMoeLayer;
    use crate::util::Rng;

    fn native() -> NativeMoeBackend {
        let mut rng = Rng::new(1);
        let layer = Arc::new(ButterflyMoeLayer::random(16, 32, 4, 2, None, &mut rng));
        NativeMoeBackend::new(layer, 64, 8, 4)
    }

    #[test]
    fn native_backend_deterministic() {
        let b = native();
        let prompts = vec![vec![1, 2, 3], vec![9, 9]];
        let a = b.forward(&prompts).unwrap();
        let c = b.forward(&prompts).unwrap();
        assert_eq!(a, c);
        assert_eq!(a.len(), 2);
        assert!(a.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn native_backend_distinguishes_prompts() {
        let b = native();
        let out = b
            .forward(&vec![vec![1, 2, 3, 4], vec![60, 61, 62, 63]])
            .unwrap();
        // different prompts usually map to different tokens with random
        // embeddings; accept equality but require valid range
        assert!(out.iter().all(|&t| t >= 0));
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
