//! Serving coordinator — L3's request path, built around **generation
//! sessions** with continuous batching.
//!
//! Architecture:
//!
//! ```text
//!  clients ──TCP──► frontend ──mpsc──► engine thread ─────────────────┐
//!    ▲                                   │                            │
//!    │                         ContinuousScheduler          Backend::step
//!    │                      (admit / step / retire)      (PJRT bucketed LM
//!    └────── TokenEvent stream ──────────┘                or native MoE)
//! ```
//!
//! * A client submits a [`GenerateRequest`] — prompt, [`SamplingParams`]
//!   (greedy, or temperature/top-k with a seeded RNG), [`StopCriteria`]
//!   (max new tokens and/or EOS) — and receives a channel of
//!   [`TokenEvent`]s: one `Token { token, index, latency }` per decoded
//!   position, terminated by `Done { reason, tokens, total, truncated }`.
//! * The [`ContinuousScheduler`] keeps sequences *resident* across
//!   decode steps.  Between steps, finished sequences leave and queued
//!   requests join (up to `max_batch`), so short requests stream out
//!   ahead of long batch-mates instead of convoying behind them.  When
//!   the loop is idle, the first batch waits up to `max_wait` to fill —
//!   the classic size-or-deadline knob, but only for cold starts.
//! * [`Backend::step`] advances every sequence in an [`InflightBatch`]
//!   by one engine tick.  A joining sequence starts in
//!   [`SeqPhase::Prefill`] and consumes its prompt in chunks of up to
//!   `--prefill-chunk` tokens per tick (0 = all at once); mid-prefill
//!   ticks return no logits, and the tick that finishes the prompt also
//!   decodes the first token.  Decode ticks yield one logit row per
//!   sequence.  The PJRT backend packs each step into the smallest
//!   compiled batch bucket and splits oversized steps across buckets.
//! * [`Metrics`] tracks queue wait, time-to-first-token, inter-token
//!   latency, end-to-end session time, step occupancy, and tokens/sec.
//!
//! # Wire protocol (TCP frontend)
//!
//! One line per session; the server streams events back as lines:
//!
//! ```text
//! client:  GEN 8 0.7 40 42 -1 10 11 12\n
//!          └── 8 new tokens, temperature 0.7, top-40, seed 42,
//!              no EOS token, prompt [10, 11, 12]
//! server:  TOK 0 17 1523\n        (first token 17, TTFT 1523 µs)
//!          TOK 1 99 812\n         (second token, 812 µs after the first)
//!          ...
//!          END max_tokens 8 9120 0\n
//!          └── reason, token count, total µs, prompt tokens truncated
//!              to fit the model window (0 = the model saw it all)
//! ```
//!
//! Greedy decoding is `GEN 8 0 0 0 -1 <prompt…>`; `QUIT` closes the
//! connection; malformed requests and backend failures produce a
//! terminal `ERR <message>` line instead of `END` (and a malformed
//! request additionally closes the connection — an unframed client
//! can't be trusted to stay in stream sync).  `STATS` returns one
//! `key=value` telemetry line including the instantaneous
//! `queue_depth`/`inflight` load gauges and the expert-residency
//! cache's hit rate and resident bytes (see [`server::stats_line`] and
//! [`crate::expertcache`] — the `--expert-cache-mb` memory↔throughput
//! dial).  `SHUTDOWN` begins graceful, loss-free process shutdown —
//! how `bmoe route` retires drained workers.
//!
//! The server binds with `SO_REUSEADDR`, accepts `--port 0`, and
//! announces the actually-bound address on a machine-parseable
//! `[listening] <addr>` stdout line, so supervisors ([`crate::router`])
//! can spawn workers on ephemeral ports and discover where they landed.
//!
//! Threads + channels only (no tokio in the offline vendor set): one
//! engine thread owns the backend; each TCP connection gets a relay
//! thread.

pub mod backend;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod session;

pub use backend::{
    greedy_next, warm, Backend, InflightBatch, InflightSeq, NativeLmBackend, NativeMoeBackend,
    PjrtLmBackend, SeqPhase, StepOutput,
};
pub use metrics::{Metrics, MetricsSnapshot};
pub use scheduler::{ContinuousScheduler, QueuedRequest, SchedulerConfig};
pub use server::{parse_gen_line, serve_on, serve_tcp, stats_line, Coordinator};
pub use session::{
    collect_stream, Completion, FinishReason, GenerateRequest, Sampler, SamplingParams,
    StopCriteria, TokenEvent,
};
