//! Serving coordinator — L3's request path.
//!
//! Architecture (vLLM-router-shaped, scaled to this testbed):
//!
//! ```text
//!  clients ──TCP──► frontend ──mpsc──► DynamicBatcher ──► worker pool
//!                                              │               │
//!                                   (size/deadline flush)  Backend::forward
//!                                                        (PJRT bucketed LM
//!                                                         or native MoE)
//! ```
//!
//! * [`batcher::DynamicBatcher`] flushes a queued batch when either
//!   `max_batch` requests are waiting or the oldest has waited
//!   `max_wait_ms` — the standard latency/throughput knob.
//! * [`backend::Backend`] abstracts the execution engine; the PJRT
//!   backend pads each flush to the smallest compiled batch bucket
//!   (aot.py emits b ∈ {1,4,16}).
//! * [`metrics::Metrics`] tracks queue wait, batch occupancy and
//!   end-to-end latency histograms.
//!
//! Threads + channels only (no tokio in the offline vendor set); the
//! worker pool uses `crossbeam_utils::thread::scope` in the server loop.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{Backend, NativeMoeBackend, PjrtLmBackend};
pub use batcher::{Batch, DynamicBatcher};
pub use metrics::Metrics;
pub use server::{Coordinator, Request, Response};
