//! Generation-session request/response vocabulary.
//!
//! A client submits a [`GenerateRequest`] and receives a stream of
//! [`TokenEvent`]s: one `Token` per decoded position, terminated by a
//! single `Done` carrying the [`FinishReason`] and the full completion.
//! Sampling is seeded and deterministic — the same request produces the
//! same tokens on every run and on every backend replica.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::Rng;

/// How to turn a logit row into the next token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    /// 0.0 (or below) means greedy argmax decoding.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits; 0 means the full
    /// vocabulary.  Ignored under greedy decoding.
    pub top_k: usize,
    /// Seed for the per-request RNG stream (deterministic replay).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams::greedy()
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            seed: 0,
        }
    }

    pub fn temperature(temperature: f32, seed: u64) -> Self {
        SamplingParams {
            temperature,
            top_k: 0,
            seed,
        }
    }

    pub fn top_k(temperature: f32, top_k: usize, seed: u64) -> Self {
        SamplingParams {
            temperature,
            top_k,
            seed,
        }
    }

    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// When to stop decoding a sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StopCriteria {
    /// Hard cap on generated tokens (always enforced).
    pub max_new_tokens: usize,
    /// Stop early when this token is sampled (it is still emitted).
    pub eos: Option<i32>,
}

impl Default for StopCriteria {
    fn default() -> Self {
        StopCriteria {
            max_new_tokens: 32,
            eos: None,
        }
    }
}

impl StopCriteria {
    pub fn max_tokens(max_new_tokens: usize) -> Self {
        StopCriteria {
            max_new_tokens,
            eos: None,
        }
    }

    pub fn with_eos(mut self, eos: i32) -> Self {
        self.eos = Some(eos);
        self
    }
}

/// A multi-token generation request: the unit of admission for the
/// continuous-batching scheduler.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    pub sampling: SamplingParams,
    pub stop: StopCriteria,
}

impl GenerateRequest {
    /// Greedy decode of `max_new_tokens` tokens — the common default.
    pub fn greedy(prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        GenerateRequest {
            prompt,
            sampling: SamplingParams::greedy(),
            stop: StopCriteria::max_tokens(max_new_tokens),
        }
    }

    pub fn with_sampling(mut self, sampling: SamplingParams) -> Self {
        self.sampling = sampling;
        self
    }

    pub fn with_stop(mut self, stop: StopCriteria) -> Self {
        self.stop = stop;
        self
    }
}

/// Why a session ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new_tokens` generated.
    MaxTokens,
    /// The EOS token was sampled.
    Eos,
    /// The coordinator shut down before (or while) serving the request.
    Shutdown,
    /// The backend failed mid-generation.
    Error(String),
}

impl std::fmt::Display for FinishReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FinishReason::MaxTokens => write!(f, "max_tokens"),
            FinishReason::Eos => write!(f, "eos"),
            FinishReason::Shutdown => write!(f, "shutdown"),
            FinishReason::Error(e) => write!(f, "error: {e}"),
        }
    }
}

/// One element of a session's event stream.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// The `index`-th generated token; `latency` is the time since the
    /// previous event on this sequence (since enqueue for index 0, i.e.
    /// the time to first token).
    Token {
        token: i32,
        index: usize,
        latency: Duration,
    },
    /// Terminal event: the stream never yields anything after this.
    Done {
        reason: FinishReason,
        /// Every token generated for this request, in order.
        tokens: Vec<i32>,
        /// End-to-end time from enqueue to finish.
        total: Duration,
        /// Prompt tokens dropped because the prompt exceeded the model
        /// window (0 = nothing truncated) — surfaced so clients learn
        /// the model never saw their prompt's head.
        truncated: usize,
    },
}

/// A fully collected completion (blocking-client view of a stream).
#[derive(Clone, Debug)]
pub struct Completion {
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    /// Time to first token (None when the request died before any token).
    pub ttft: Option<Duration>,
    pub total: Duration,
    /// Prompt tokens dropped to fit the model window (0 = none).
    pub truncated: usize,
}

/// Drain a session's event stream into a [`Completion`].  `timeout`
/// bounds the wait for *each* event, not the whole stream.
pub fn collect_stream(rx: &Receiver<TokenEvent>, timeout: Duration) -> Result<Completion> {
    let mut ttft = None;
    loop {
        match rx.recv_timeout(timeout) {
            Ok(TokenEvent::Token { index, latency, .. }) => {
                if index == 0 {
                    ttft = Some(latency);
                }
            }
            Ok(TokenEvent::Done {
                reason,
                tokens,
                total,
                truncated,
            }) => {
                return Ok(Completion {
                    tokens,
                    reason,
                    ttft,
                    total,
                    truncated,
                })
            }
            Err(RecvTimeoutError::Timeout) => bail!("generation stream stalled for {timeout:?}"),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("generation stream dropped without a terminal event")
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// Stateful per-sequence sampler: owns the seeded RNG stream.
#[derive(Clone, Debug)]
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Self {
        Sampler {
            rng: Rng::new(params.seed),
            params,
        }
    }

    /// Pick the next token from a logit row.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        debug_assert!(!logits.is_empty());
        if self.params.is_greedy() {
            return argmax(logits) as i32;
        }
        let k = match self.params.top_k {
            0 => logits.len(),
            k => k.min(logits.len()),
        };
        let inv_t = 1.0 / self.params.temperature as f64;
        if k == logits.len() {
            // full-vocabulary softmax: only the max is needed (stability),
            // so a single scan replaces any ordering work
            let m = logits[argmax(logits)] as f64;
            let weights: Vec<f64> = logits
                .iter()
                .map(|&l| ((l as f64 - m) * inv_t).exp())
                .collect();
            return self.rng.weighted(&weights) as i32;
        }
        // top-k restriction: partial selection, no full sort
        let desc = |&a: &usize, &b: &usize| {
            logits[b]
                .partial_cmp(&logits[a])
                .unwrap_or(std::cmp::Ordering::Equal)
        };
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.select_nth_unstable_by(k - 1, desc);
        idx.truncate(k);
        // softmax over the candidates at the given temperature
        let m = idx
            .iter()
            .map(|&i| logits[i])
            .fold(f32::NEG_INFINITY, f32::max) as f64;
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| ((logits[i] as f64 - m) * inv_t).exp())
            .collect();
        idx[self.rng.weighted(&weights)] as i32
    }
}

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampler_is_argmax() {
        let mut s = Sampler::new(SamplingParams::greedy());
        assert_eq!(s.sample(&[0.1, 2.0, -1.0, 1.9]), 1);
        assert_eq!(s.sample(&[-5.0, -4.0]), 1);
    }

    #[test]
    fn seeded_sampling_is_deterministic() {
        let logits: Vec<f32> = (0..64).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let mut a = Sampler::new(SamplingParams::temperature(1.0, 42));
        let mut b = Sampler::new(SamplingParams::temperature(1.0, 42));
        let sa: Vec<i32> = (0..32).map(|_| a.sample(&logits)).collect();
        let sb: Vec<i32> = (0..32).map(|_| b.sample(&logits)).collect();
        assert_eq!(sa, sb, "same seed must replay the same tokens");
    }

    #[test]
    fn different_seeds_diverge() {
        let logits = vec![0.0f32; 64]; // uniform: divergence is ~certain
        let mut a = Sampler::new(SamplingParams::temperature(1.0, 1));
        let mut b = Sampler::new(SamplingParams::temperature(1.0, 2));
        let sa: Vec<i32> = (0..32).map(|_| a.sample(&logits)).collect();
        let sb: Vec<i32> = (0..32).map(|_| b.sample(&logits)).collect();
        assert_ne!(sa, sb);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut logits = vec![0.0f32; 16];
        logits[3] = 5.0;
        logits[7] = 4.0;
        let mut s = Sampler::new(SamplingParams::top_k(1.0, 2, 9));
        for _ in 0..200 {
            let t = s.sample(&logits);
            assert!(t == 3 || t == 7, "top-2 must only yield the two peaks, got {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let mut logits = vec![0.0f32; 8];
        logits[5] = 10.0;
        let mut s = Sampler::new(SamplingParams::temperature(0.05, 3));
        for _ in 0..100 {
            assert_eq!(s.sample(&logits), 5);
        }
    }

    #[test]
    fn finish_reason_wire_names() {
        assert_eq!(FinishReason::MaxTokens.to_string(), "max_tokens");
        assert_eq!(FinishReason::Eos.to_string(), "eos");
        assert_eq!(FinishReason::Shutdown.to_string(), "shutdown");
        assert!(FinishReason::Error("boom".into()).to_string().contains("boom"));
    }
}
