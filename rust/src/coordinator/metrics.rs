//! Coordinator telemetry for token streaming: counters plus latency
//! histograms (queue wait, time-to-first-token, inter-token latency,
//! end-to-end session time), shared across threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::expertcache::CacheStatsSnapshot;
use crate::util::stats::LatencyHistogram;

pub struct Metrics {
    /// Sessions submitted.
    pub requests: AtomicU64,
    /// Sessions that reached a terminal event through the normal path.
    pub responses: AtomicU64,
    /// Tokens generated across all sessions.
    pub tokens: AtomicU64,
    /// Decode steps executed (each advances every resident sequence).
    pub steps: AtomicU64,
    /// Sum of batch occupancy over all steps (mean = / steps).
    pub stepped_seqs: AtomicU64,
    /// Sessions retired because the client dropped its event stream.
    pub cancelled: AtomicU64,
    pub errors: AtomicU64,
    /// Gauge: requests queued behind the running batch (engine loop
    /// overwrites it every iteration).  Occupancy alone can't tell an
    /// idle server from a saturated-but-draining one; the router's
    /// least-loaded placement needs the queue explicitly.
    pub queue_depth: AtomicU64,
    /// Gauge: sequences resident in the running batch right now (as
    /// opposed to `stepped_seqs`, a historical mean).
    pub inflight: AtomicU64,
    started: Instant,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    queue_wait: LatencyHistogram,
    ttft: LatencyHistogram,
    itl: LatencyHistogram,
    e2e: LatencyHistogram,
    /// Latest expert-residency-cache counters (gauge semantics: the
    /// engine loop overwrites it after every decode step).
    cache: Option<CacheStatsSnapshot>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub tokens: u64,
    pub steps: u64,
    pub cancelled: u64,
    pub errors: u64,
    /// Requests queued behind the running batch at snapshot time.
    pub queue_depth: u64,
    /// Sequences resident in the running batch at snapshot time.
    pub inflight: u64,
    /// Mean resident sequences per decode step (continuous-batching
    /// occupancy; the old "mean batch size").
    pub mean_batch_size: f64,
    /// Generated tokens per wall-clock second since the metrics epoch.
    pub tokens_per_sec: f64,
    pub queue_wait_p50: f64,
    pub queue_wait_p99: f64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub itl_p50: f64,
    pub itl_p99: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
    /// Expert-residency cache counters, when the backend serves a cached
    /// native layer (hit rate, resident bytes, evictions — the
    /// memory↔throughput dial's telemetry).
    pub cache: Option<CacheStatsSnapshot>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            steps: AtomicU64::new(0),
            stepped_seqs: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            started: Instant::now(),
            inner: Mutex::new(Inner::default()),
        }
    }

    pub fn record_enqueue(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Time a request spent queued before admission.
    pub fn record_queue_wait(&self, wait: Duration) {
        self.inner.lock().unwrap().queue_wait.record(wait.as_secs_f64());
    }

    /// One decode step over `occupancy` resident sequences.
    pub fn record_step(&self, occupancy: usize) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.stepped_seqs
            .fetch_add(occupancy as u64, Ordering::Relaxed);
    }

    pub fn record_token(&self) {
        self.tokens.fetch_add(1, Ordering::Relaxed);
    }

    /// Enqueue-to-first-token latency of one session.
    pub fn record_ttft(&self, ttft: Duration) {
        self.inner.lock().unwrap().ttft.record(ttft.as_secs_f64());
    }

    /// Gap between consecutive tokens of one session.
    pub fn record_itl(&self, gap: Duration) {
        self.inner.lock().unwrap().itl.record(gap.as_secs_f64());
    }

    /// A session reached its terminal event after `total` end-to-end.
    pub fn record_finished(&self, total: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap().e2e.record(total.as_secs_f64());
    }

    /// Instantaneous load gauges, published by the engine loop every
    /// iteration (pending queue length, resident batch size).
    pub fn record_load(&self, queue_depth: usize, inflight: usize) {
        self.queue_depth.store(queue_depth as u64, Ordering::Relaxed);
        self.inflight.store(inflight as u64, Ordering::Relaxed);
    }

    /// A session was retired because its client dropped the stream.
    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Latest expert-cache counters (the engine loop publishes these
    /// after every decode step).
    pub fn record_cache(&self, snap: CacheStatsSnapshot) {
        self.inner.lock().unwrap().cache = Some(snap);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let steps = self.steps.load(Ordering::Relaxed);
        let tokens = self.tokens.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            tokens,
            steps,
            cancelled: self.cancelled.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            mean_batch_size: if steps == 0 {
                0.0
            } else {
                self.stepped_seqs.load(Ordering::Relaxed) as f64 / steps as f64
            },
            tokens_per_sec: tokens as f64 / elapsed,
            queue_wait_p50: inner.queue_wait.quantile(0.5),
            queue_wait_p99: inner.queue_wait.quantile(0.99),
            ttft_p50: inner.ttft.quantile(0.5),
            ttft_p99: inner.ttft.quantile(0.99),
            itl_p50: inner.itl.quantile(0.5),
            itl_p99: inner.itl.quantile(0.99),
            latency_p50: inner.e2e.quantile(0.5),
            latency_p95: inner.e2e.quantile(0.95),
            latency_p99: inner.e2e.quantile(0.99),
            latency_mean: inner.e2e.mean(),
            cache: inner.cache.clone(),
        }
    }
}

impl MetricsSnapshot {
    pub fn summary(&self) -> String {
        let cache = match &self.cache {
            Some(c) if c.enabled => format!(" | {}", c.summary()),
            _ => String::new(),
        };
        format!(
            "req={} done={} cancelled={} err={} tokens={} ({:.0} tok/s) steps={} (occupancy {:.1}) ttft p50/p99 {:.2}/{:.2} ms itl p50/p99 {:.2}/{:.2} ms e2e p50/p95/p99 {:.2}/{:.2}/{:.2} ms{cache}",
            self.requests,
            self.responses,
            self.cancelled,
            self.errors,
            self.tokens,
            self.tokens_per_sec,
            self.steps,
            self.mean_batch_size,
            self.ttft_p50 * 1e3,
            self.ttft_p99 * 1e3,
            self.itl_p50 * 1e3,
            self.itl_p99 * 1e3,
            self.latency_p50 * 1e3,
            self.latency_p95 * 1e3,
            self.latency_p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::new();
        m.record_enqueue();
        m.record_enqueue();
        m.record_queue_wait(Duration::from_millis(1));
        m.record_step(2);
        m.record_step(1);
        for _ in 0..3 {
            m.record_token();
        }
        m.record_ttft(Duration::from_millis(4));
        m.record_itl(Duration::from_millis(2));
        m.record_finished(Duration::from_millis(5));
        m.record_finished(Duration::from_millis(7));
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.tokens, 3);
        assert_eq!(s.steps, 2);
        assert!((s.mean_batch_size - 1.5).abs() < 1e-9);
        assert!(s.tokens_per_sec > 0.0);
        assert!(s.ttft_p50 > 0.0);
        assert!(s.itl_p50 > 0.0);
        assert!(s.latency_p95 >= s.latency_p50);
        assert!(s.latency_mean > 0.004 && s.latency_mean < 0.01);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn load_gauges_overwrite_not_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.queue_depth, s.inflight), (0, 0));
        m.record_load(7, 3);
        let s = m.snapshot();
        assert_eq!((s.queue_depth, s.inflight), (7, 3));
        // gauge semantics: the next publish replaces, never adds
        m.record_load(0, 1);
        let s = m.snapshot();
        assert_eq!((s.queue_depth, s.inflight), (0, 1));
    }

    #[test]
    fn cache_gauge_appears_in_snapshot_and_summary() {
        let m = Metrics::new();
        assert!(m.snapshot().cache.is_none());
        m.record_cache(CacheStatsSnapshot {
            enabled: true,
            hits: 9,
            misses: 1,
            resident_experts: 1,
            resident_bytes: 1024,
            budget_bytes: 2048,
            ..Default::default()
        });
        let s = m.snapshot();
        let c = s.cache.as_ref().unwrap();
        assert!((c.hit_rate() - 0.9).abs() < 1e-9);
        assert!(s.summary().contains("cache hit 90.0%"), "{}", s.summary());
    }
}
