//! Coordinator telemetry: counters + latency histograms, shared across
//! worker threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::LatencyHistogram;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub errors: AtomicU64,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    queue_wait: LatencyHistogram,
    e2e_latency: LatencyHistogram,
    batch_sizes: Vec<u64>, // count per size bucket (index = size)
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_batch_size: f64,
    pub queue_wait_p50: f64,
    pub queue_wait_p99: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
}

impl Metrics {
    pub fn record_enqueue(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize, queue_wait_secs: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        inner.queue_wait.record(queue_wait_secs);
        if inner.batch_sizes.len() <= size {
            inner.batch_sizes.resize(size + 1, 0);
        }
        inner.batch_sizes[size] += 1;
    }

    pub fn record_response(&self, e2e_secs: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap().e2e_latency.record(e2e_secs);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let batches = self.batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            batches,
            errors: self.errors.load(Ordering::Relaxed),
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            },
            queue_wait_p50: inner.queue_wait.quantile(0.5),
            queue_wait_p99: inner.queue_wait.quantile(0.99),
            latency_p50: inner.e2e_latency.quantile(0.5),
            latency_p95: inner.e2e_latency.quantile(0.95),
            latency_p99: inner.e2e_latency.quantile(0.99),
            latency_mean: inner.e2e_latency.mean(),
        }
    }
}

impl MetricsSnapshot {
    pub fn summary(&self) -> String {
        format!(
            "req={} resp={} err={} batches={} (mean size {:.1}) wait p50/p99 {:.2}/{:.2} ms lat p50/p95/p99 {:.2}/{:.2}/{:.2} ms",
            self.requests,
            self.responses,
            self.errors,
            self.batches,
            self.mean_batch_size,
            self.queue_wait_p50 * 1e3,
            self.queue_wait_p99 * 1e3,
            self.latency_p50 * 1e3,
            self.latency_p95 * 1e3,
            self.latency_p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::default();
        m.record_enqueue();
        m.record_enqueue();
        m.record_batch(2, 0.001);
        m.record_response(0.005);
        m.record_response(0.007);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert!(s.latency_p95 >= s.latency_p50);
        assert!(s.latency_mean > 0.004 && s.latency_mean < 0.01);
        assert!(!s.summary().is_empty());
    }
}
