//! Coordinator telemetry for token streaming: counters plus latency
//! histograms (queue wait, time-to-first-token, inter-token latency,
//! end-to-end session time), shared across threads.  [`Metrics::prometheus`]
//! renders everything — including the sampled per-stage hot-path timings
//! from [`crate::obs::trace`] — as Prometheus text exposition for the
//! `METRICS` wire verb (DESIGN.md §7).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::expertcache::CacheStatsSnapshot;
use crate::obs::prom::PromText;
use crate::util::stats::LatencyHistogram;

pub struct Metrics {
    /// Sessions submitted.
    pub requests: AtomicU64,
    /// Sessions that reached a terminal event through the normal path.
    pub responses: AtomicU64,
    /// Tokens generated across all sessions.
    pub tokens: AtomicU64,
    /// Prompt tokens ingested by prefill across all sessions (distinct
    /// from `tokens`, which counts decoded tokens only).
    pub prefill_tokens: AtomicU64,
    /// Decode steps executed (each advances every resident sequence).
    pub steps: AtomicU64,
    /// Sum of batch occupancy over all steps (mean = / steps).
    pub stepped_seqs: AtomicU64,
    /// Sessions retired because the client dropped its event stream.
    pub cancelled: AtomicU64,
    pub errors: AtomicU64,
    /// Gauge: requests queued behind the running batch (engine loop
    /// overwrites it every iteration).  Occupancy alone can't tell an
    /// idle server from a saturated-but-draining one; the router's
    /// least-loaded placement needs the queue explicitly.
    pub queue_depth: AtomicU64,
    /// Gauge: sequences resident in the running batch right now (as
    /// opposed to `stepped_seqs`, a historical mean).
    pub inflight: AtomicU64,
    started: Instant,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    queue_wait: LatencyHistogram,
    ttft: LatencyHistogram,
    itl: LatencyHistogram,
    e2e: LatencyHistogram,
    /// Latest expert-residency-cache counters (gauge semantics: the
    /// engine loop overwrites it after every decode step).
    cache: Option<CacheStatsSnapshot>,
    /// When the first request arrived.  Throughput is measured from here,
    /// not from construction: a server that sits idle before its first
    /// request would otherwise report a tokens/sec diluted by the idle
    /// span, which made bench-vs-serve numbers incomparable.
    first_activity: Option<Instant>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub tokens: u64,
    /// Prompt tokens ingested by prefill.
    pub prefill_tokens: u64,
    pub steps: u64,
    pub cancelled: u64,
    pub errors: u64,
    /// Requests queued behind the running batch at snapshot time.
    pub queue_depth: u64,
    /// Sequences resident in the running batch at snapshot time.
    pub inflight: u64,
    /// Mean resident sequences per decode step (continuous-batching
    /// occupancy; the old "mean batch size").
    pub mean_batch_size: f64,
    /// Generated (decode) tokens per wall-clock second since the
    /// metrics epoch.
    pub tokens_per_sec: f64,
    /// Prompt tokens ingested per wall-clock second since the metrics
    /// epoch — the prefill side of the throughput split.
    pub prefill_tok_s: f64,
    pub queue_wait_p50: f64,
    pub queue_wait_p99: f64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// TTFT samples recorded — exactly one per session that produced a
    /// decoded token (prefill chunks never record TTFT), so invariance
    /// tests can assert the count non-vacuously.
    pub ttft_count: u64,
    pub itl_p50: f64,
    pub itl_p99: f64,
    pub latency_p50: f64,
    pub latency_p95: f64,
    pub latency_p99: f64,
    pub latency_mean: f64,
    /// Expert-residency cache counters, when the backend serves a cached
    /// native layer (hit rate, resident bytes, evictions — the
    /// memory↔throughput dial's telemetry).
    pub cache: Option<CacheStatsSnapshot>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            tokens: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            steps: AtomicU64::new(0),
            stepped_seqs: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            started: Instant::now(),
            inner: Mutex::new(Inner::default()),
        }
    }

    pub fn record_enqueue(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        if inner.first_activity.is_none() {
            inner.first_activity = Some(Instant::now());
        }
    }

    /// Time a request spent queued before admission.
    pub fn record_queue_wait(&self, wait: Duration) {
        self.inner.lock().unwrap().queue_wait.record(wait.as_secs_f64());
    }

    /// One decode step over `occupancy` resident sequences.
    pub fn record_step(&self, occupancy: usize) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.stepped_seqs
            .fetch_add(occupancy as u64, Ordering::Relaxed);
    }

    pub fn record_token(&self) {
        self.tokens.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` prompt tokens were folded by a prefill step.
    pub fn record_prefill_tokens(&self, n: u64) {
        self.prefill_tokens.fetch_add(n, Ordering::Relaxed);
    }

    /// Enqueue-to-first-token latency of one session.
    pub fn record_ttft(&self, ttft: Duration) {
        self.inner.lock().unwrap().ttft.record(ttft.as_secs_f64());
    }

    /// Gap between consecutive tokens of one session.
    pub fn record_itl(&self, gap: Duration) {
        self.inner.lock().unwrap().itl.record(gap.as_secs_f64());
    }

    /// A session reached its terminal event after `total` end-to-end.
    pub fn record_finished(&self, total: Duration) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.inner.lock().unwrap().e2e.record(total.as_secs_f64());
    }

    /// Instantaneous load gauges, published by the engine loop every
    /// iteration (pending queue length, resident batch size).
    pub fn record_load(&self, queue_depth: usize, inflight: usize) {
        self.queue_depth.store(queue_depth as u64, Ordering::Relaxed);
        self.inflight.store(inflight as u64, Ordering::Relaxed);
    }

    /// A session was retired because its client dropped the stream.
    pub fn record_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Latest expert-cache counters (the engine loop publishes these
    /// after every decode step).
    pub fn record_cache(&self, snap: CacheStatsSnapshot) {
        self.inner.lock().unwrap().cache = Some(snap);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let steps = self.steps.load(Ordering::Relaxed);
        let tokens = self.tokens.load(Ordering::Relaxed);
        let prefill_tokens = self.prefill_tokens.load(Ordering::Relaxed);
        // Throughput counts from the first recorded activity, not from
        // construction — pre-request idle must not dilute tokens/sec.
        let elapsed = inner
            .first_activity
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            tokens,
            prefill_tokens,
            steps,
            cancelled: self.cancelled.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            mean_batch_size: if steps == 0 {
                0.0
            } else {
                self.stepped_seqs.load(Ordering::Relaxed) as f64 / steps as f64
            },
            tokens_per_sec: tokens as f64 / elapsed,
            prefill_tok_s: prefill_tokens as f64 / elapsed,
            queue_wait_p50: inner.queue_wait.quantile(0.5),
            queue_wait_p99: inner.queue_wait.quantile(0.99),
            ttft_p50: inner.ttft.quantile(0.5),
            ttft_p99: inner.ttft.quantile(0.99),
            ttft_count: inner.ttft.n,
            itl_p50: inner.itl.quantile(0.5),
            itl_p99: inner.itl.quantile(0.99),
            latency_p50: inner.e2e.quantile(0.5),
            latency_p95: inner.e2e.quantile(0.95),
            latency_p99: inner.e2e.quantile(0.99),
            latency_mean: inner.e2e.mean(),
            cache: inner.cache.clone(),
        }
    }

    /// Render everything as Prometheus text exposition (the `METRICS`
    /// wire verb's reply body), framed by the `# EOF` terminator line.
    ///
    /// Includes the coordinator counters/gauges, the four session
    /// latency histograms as cumulative-bucket series, the expert-cache
    /// counters when a cache is attached, and one
    /// `bmoe_stage_seconds{stage=...,layer=...}` histogram per sampled
    /// hot-path stage from [`crate::obs::trace`].
    pub fn prometheus(&self) -> String {
        let snap = self.snapshot();
        let hists = {
            let inner = self.inner.lock().unwrap();
            [
                ("bmoe_queue_wait_seconds", "Queue wait before admission", inner.queue_wait.clone()),
                ("bmoe_ttft_seconds", "Enqueue-to-first-token latency", inner.ttft.clone()),
                ("bmoe_itl_seconds", "Gap between consecutive tokens of a session", inner.itl.clone()),
                ("bmoe_session_seconds", "End-to-end session time", inner.e2e.clone()),
            ]
        };
        let mut p = PromText::new();
        for (name, help, value) in [
            ("bmoe_requests_total", "Sessions submitted", snap.requests),
            ("bmoe_responses_total", "Sessions that reached a terminal event", snap.responses),
            ("bmoe_tokens_total", "Tokens generated across all sessions", snap.tokens),
            ("bmoe_prefill_tokens_total", "Prompt tokens ingested by prefill", snap.prefill_tokens),
            ("bmoe_decode_steps_total", "Decode steps executed", snap.steps),
            ("bmoe_cancelled_total", "Sessions retired because the client dropped", snap.cancelled),
            ("bmoe_errors_total", "Sessions that ended in an error", snap.errors),
        ] {
            p.counter(name, help, &[], value as f64);
        }
        p.gauge("bmoe_queue_depth", "Requests queued behind the running batch", &[], snap.queue_depth as f64);
        p.gauge("bmoe_inflight", "Sequences resident in the running batch", &[], snap.inflight as f64);
        p.gauge("bmoe_mean_batch_size", "Mean resident sequences per decode step", &[], snap.mean_batch_size);
        p.gauge("bmoe_tokens_per_sec", "Tokens per second since first activity", &[], snap.tokens_per_sec);
        p.gauge("bmoe_prefill_tok_s", "Prompt tokens ingested per second since first activity", &[], snap.prefill_tok_s);
        p.gauge("bmoe_uptime_seconds", "Seconds since the metrics epoch", &[], self.started.elapsed().as_secs_f64());
        for (name, help, h) in &hists {
            p.histogram(name, help, &[], h);
        }
        if let Some(c) = &snap.cache {
            for (name, help, value) in [
                ("bmoe_cache_hits_total", "Expert dispatches served from a resident decode", c.hits),
                ("bmoe_cache_misses_total", "Expert dispatches that fell back to synthesis", c.misses),
                ("bmoe_cache_evictions_total", "Experts evicted from the residency cache", c.evictions),
                ("bmoe_cache_materializations_total", "Experts materialized into the cache", c.materializations),
            ] {
                p.counter(name, help, &[], value as f64);
            }
            p.gauge("bmoe_cache_resident_bytes", "Bytes resident in the expert cache", &[], c.resident_bytes as f64);
            p.gauge("bmoe_cache_budget_bytes", "Expert-cache byte budget", &[], c.budget_bytes as f64);
        }
        p.gauge(
            "bmoe_trace_sample",
            "Hot-path stage sampling rate (0 = tracing off)",
            &[],
            crate::obs::trace::sample() as f64,
        );
        for s in crate::obs::trace::snapshot() {
            p.histogram(
                "bmoe_stage_seconds",
                "Sampled wall time of one hot-path stage occurrence",
                &[("stage", s.stage.name().to_string()), ("layer", s.layer.to_string())],
                &s.hist,
            );
        }
        p.finish()
    }
}

impl MetricsSnapshot {
    pub fn summary(&self) -> String {
        let cache = match &self.cache {
            Some(c) if c.enabled => format!(" | {}", c.summary()),
            _ => String::new(),
        };
        format!(
            "req={} done={} cancelled={} err={} tokens={} ({:.0} tok/s) prefill={} ({:.0} tok/s) steps={} (occupancy {:.1}) ttft p50/p99 {:.2}/{:.2} ms itl p50/p99 {:.2}/{:.2} ms e2e p50/p95/p99 {:.2}/{:.2}/{:.2} ms{cache}",
            self.requests,
            self.responses,
            self.cancelled,
            self.errors,
            self.tokens,
            self.tokens_per_sec,
            self.prefill_tokens,
            self.prefill_tok_s,
            self.steps,
            self.mean_batch_size,
            self.ttft_p50 * 1e3,
            self.ttft_p99 * 1e3,
            self.itl_p50 * 1e3,
            self.itl_p99 * 1e3,
            self.latency_p50 * 1e3,
            self.latency_p95 * 1e3,
            self.latency_p99 * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::new();
        m.record_enqueue();
        m.record_enqueue();
        m.record_queue_wait(Duration::from_millis(1));
        m.record_step(2);
        m.record_step(1);
        for _ in 0..3 {
            m.record_token();
        }
        m.record_prefill_tokens(5);
        m.record_ttft(Duration::from_millis(4));
        m.record_itl(Duration::from_millis(2));
        m.record_finished(Duration::from_millis(5));
        m.record_finished(Duration::from_millis(7));
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.tokens, 3);
        assert_eq!(s.prefill_tokens, 5);
        assert!(s.prefill_tok_s > 0.0);
        assert_eq!(s.steps, 2);
        assert!((s.mean_batch_size - 1.5).abs() < 1e-9);
        assert!(s.tokens_per_sec > 0.0);
        assert!(s.ttft_p50 > 0.0);
        assert!(s.itl_p50 > 0.0);
        assert!(s.latency_p95 >= s.latency_p50);
        assert!(s.latency_mean > 0.004 && s.latency_mean < 0.01);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn load_gauges_overwrite_not_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.queue_depth, s.inflight), (0, 0));
        m.record_load(7, 3);
        let s = m.snapshot();
        assert_eq!((s.queue_depth, s.inflight), (7, 3));
        // gauge semantics: the next publish replaces, never adds
        m.record_load(0, 1);
        let s = m.snapshot();
        assert_eq!((s.queue_depth, s.inflight), (0, 1));
    }

    #[test]
    fn tokens_per_sec_ignores_prerequest_idle() {
        let m = Metrics::new();
        // No activity yet: no throughput (and no division blowup).
        assert_eq!(m.snapshot().tokens_per_sec, 0.0);
        // Simulate a server idling before its first request.  If the
        // epoch were `Metrics::new()` the idle span would dilute the
        // rate to <= 100 tokens / 0.2 s = 500 tok/s; measured from the
        // first request it is orders of magnitude higher.
        std::thread::sleep(Duration::from_millis(200));
        m.record_enqueue();
        for _ in 0..100 {
            m.record_token();
        }
        let s = m.snapshot();
        assert!(
            s.tokens_per_sec > 1_000.0,
            "pre-request idle diluted throughput: {} tok/s",
            s.tokens_per_sec
        );
    }

    #[test]
    fn prometheus_exposition_has_families_and_eof() {
        let m = Metrics::new();
        m.record_enqueue();
        m.record_token();
        m.record_token();
        m.record_ttft(Duration::from_millis(3));
        m.record_finished(Duration::from_millis(5));
        m.record_load(1, 2);
        let text = m.prometheus();
        assert!(text.ends_with("# EOF\n"), "{text}");
        assert!(text.contains("# TYPE bmoe_requests_total counter"), "{text}");
        assert!(text.contains("bmoe_requests_total 1\n"), "{text}");
        assert!(text.contains("bmoe_tokens_total 2\n"), "{text}");
        assert!(text.contains("bmoe_queue_depth 1\n"), "{text}");
        assert!(text.contains("bmoe_inflight 2\n"), "{text}");
        assert!(text.contains("# TYPE bmoe_ttft_seconds histogram"), "{text}");
        assert!(text.contains("bmoe_ttft_seconds_count 1\n"), "{text}");
        assert!(text.contains("bmoe_session_seconds_count 1\n"), "{text}");
        assert!(text.contains("le=\"+Inf\""), "{text}");
        assert!(text.contains("bmoe_trace_sample"), "{text}");
        // no cache attached -> no cache families
        assert!(!text.contains("bmoe_cache_hits_total"), "{text}");
    }

    #[test]
    fn prometheus_includes_cache_and_stage_series() {
        let m = Metrics::new();
        m.record_cache(CacheStatsSnapshot {
            enabled: true,
            hits: 4,
            misses: 1,
            resident_bytes: 512,
            budget_bytes: 1024,
            ..Default::default()
        });
        // Stage histograms come from the process-global trace registry;
        // serialize with the trace tests that also mutate it.
        let _g = crate::obs::trace::TEST_MUTEX
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::obs::trace::set_sample(1);
        {
            let _t = crate::obs::trace::stage_timer(
                crate::obs::trace::Stage::DownProject,
                11,
            );
        }
        crate::obs::trace::set_sample(0);
        let text = m.prometheus();
        assert!(text.contains("bmoe_cache_hits_total 4\n"), "{text}");
        assert!(text.contains("bmoe_cache_resident_bytes 512\n"), "{text}");
        assert!(
            text.contains("stage=\"down_project\",layer=\"11\""),
            "{text}"
        );
        assert!(text.contains("# TYPE bmoe_stage_seconds histogram"), "{text}");
    }

    #[test]
    fn cache_gauge_appears_in_snapshot_and_summary() {
        let m = Metrics::new();
        assert!(m.snapshot().cache.is_none());
        m.record_cache(CacheStatsSnapshot {
            enabled: true,
            hits: 9,
            misses: 1,
            resident_experts: 1,
            resident_bytes: 1024,
            budget_bytes: 2048,
            ..Default::default()
        });
        let s = m.snapshot();
        let c = s.cache.as_ref().unwrap();
        assert!((c.hit_rate() - 0.9).abs() < 1e-9);
        assert!(s.summary().contains("cache hit 90.0%"), "{}", s.summary());
    }
}
