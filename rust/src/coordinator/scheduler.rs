//! Continuous-batching scheduler: the decode loop's bookkeeping core.
//!
//! Unlike the old flush-once batcher (accumulate → flush → forward →
//! reply, one token per request), sequences here stay *resident* across
//! decode steps.  Between any two steps, finished sequences leave and
//! queued requests join, up to `max_batch` — a short request admitted
//! next to a long one streams out and exits while the long one keeps
//! decoding, so short requests never convoy behind long ones.
//!
//! The scheduler itself is synchronous and single-owner (driven by the
//! coordinator's engine thread, or directly by tests); all concurrency
//! lives in the channels around it.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs;

use super::backend::{Backend, InflightBatch, InflightSeq};
use super::metrics::Metrics;
use super::session::{FinishReason, GenerateRequest, Sampler, StopCriteria, TokenEvent};

/// Scheduler knobs (the latency/throughput trade-off surface).
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max sequences resident in the decode loop (clamped to the
    /// backend's own `max_batch`).
    pub max_batch: usize,
    /// When the loop is idle, wait at most this long for more arrivals
    /// before starting a partial batch (the classic deadline knob; once
    /// the loop is busy, joins happen between steps with no extra wait).
    pub max_wait: Duration,
    /// Server-side ceiling on generated tokens per session.  Requests
    /// asking for more are clamped at admission, so untrusted wire input
    /// cannot pin a batch slot forever.
    pub max_session_tokens: usize,
    /// Max prompt tokens one engine tick may ingest per prefilling
    /// sequence (`--prefill-chunk`); 0 = the whole prompt at once.
    /// Small chunks bound in-flight decode inter-token latency; large
    /// chunks amortize the blocked kernels better (DESIGN.md §2).
    pub prefill_chunk: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            max_session_tokens: 4096,
            prefill_chunk: 0,
        }
    }
}

impl SchedulerConfig {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        SchedulerConfig {
            max_batch,
            max_wait,
            ..SchedulerConfig::default()
        }
    }

    pub fn with_session_cap(mut self, max_session_tokens: usize) -> Self {
        self.max_session_tokens = max_session_tokens;
        self
    }

    pub fn with_prefill_chunk(mut self, prefill_chunk: usize) -> Self {
        self.prefill_chunk = prefill_chunk;
        self
    }
}

/// A request plus its reply stream, waiting for admission.
#[derive(Debug)]
pub struct QueuedRequest {
    pub id: u64,
    pub request: GenerateRequest,
    pub enqueued: Instant,
    pub reply: Sender<TokenEvent>,
}

/// Per-sequence serving state the backend doesn't need to see.
struct SeqMeta {
    /// Session id (mirrors the batch entry's; kept here so lifecycle
    /// events can be emitted after the batch slot is already retired).
    id: u64,
    reply: Sender<TokenEvent>,
    sampler: Sampler,
    stop: StopCriteria,
    enqueued: Instant,
    /// Previous event time on this sequence (enqueue before any token),
    /// so per-token latency = now - last_event.
    last_event: Instant,
    new_tokens: Vec<i32>,
}

/// The in-flight sequence set plus everything needed to stream results.
pub struct ContinuousScheduler {
    max_batch: usize,
    max_session_tokens: usize,
    batch: InflightBatch,
    meta: Vec<SeqMeta>,
    metrics: Arc<Metrics>,
}

impl ContinuousScheduler {
    pub fn new(max_batch: usize, max_session_tokens: usize, metrics: Arc<Metrics>) -> Self {
        ContinuousScheduler {
            max_batch: max_batch.max(1),
            max_session_tokens: max_session_tokens.max(1),
            batch: InflightBatch::new(),
            meta: Vec::new(),
            metrics,
        }
    }

    /// Cap prompt ingestion at `chunk` tokens per engine tick per
    /// sequence (0 = all at once, the default).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.batch.prefill_chunk = chunk;
        self
    }

    pub fn in_flight(&self) -> usize {
        self.batch.len()
    }

    pub fn has_capacity(&self) -> bool {
        self.batch.len() < self.max_batch
    }

    /// Admit a queued request into the running batch.  Prefill happens on
    /// the sequence's first step; degenerate requests (empty prompt,
    /// zero-token budget) finish immediately without touching the batch.
    pub fn admit(&mut self, q: QueuedRequest) {
        let now = Instant::now();
        self.metrics.record_queue_wait(now.duration_since(q.enqueued));
        if q.request.prompt.is_empty() {
            self.metrics.record_error();
            obs::Event::new("session_error")
                .u64("session", q.id)
                .str("error", "empty prompt")
                .emit();
            let _ = q.reply.send(TokenEvent::Done {
                reason: FinishReason::Error("empty prompt".into()),
                tokens: Vec::new(),
                total: now.duration_since(q.enqueued),
                truncated: 0,
            });
            return;
        }
        if q.request.stop.max_new_tokens == 0 {
            self.metrics.record_finished(now.duration_since(q.enqueued));
            obs::Event::new("session_finish")
                .u64("session", q.id)
                .str("reason", "max_tokens")
                .u64("tokens", 0)
                .emit();
            let _ = q.reply.send(TokenEvent::Done {
                reason: FinishReason::MaxTokens,
                tokens: Vec::new(),
                total: now.duration_since(q.enqueued),
                truncated: 0,
            });
            return;
        }
        obs::Event::new("session_admit")
            .u64("session", q.id)
            .u64("queue_wait_us", now.duration_since(q.enqueued).as_micros() as u64)
            .emit();
        // server-side cap: wire input can't reserve a slot forever
        let mut stop = q.request.stop;
        stop.max_new_tokens = stop.max_new_tokens.min(self.max_session_tokens);
        self.batch.push(InflightSeq::new(q.id, q.request.prompt));
        self.meta.push(SeqMeta {
            id: q.id,
            reply: q.reply,
            sampler: Sampler::new(q.request.sampling),
            stop,
            enqueued: q.enqueued,
            last_event: q.enqueued,
            new_tokens: Vec::new(),
        });
    }

    /// One decode step over the in-flight set: sample a token per
    /// sequence, stream the events, retire finished sequences.  Returns
    /// how many sequences finished.  On backend failure every in-flight
    /// sequence is aborted with a terminal error event.
    pub fn step(&mut self, backend: &dyn Backend) -> Result<usize> {
        if self.batch.is_empty() {
            return Ok(0);
        }
        self.metrics.record_step(self.batch.len());
        let outs = backend.step(&mut self.batch).and_then(|outs| {
            anyhow::ensure!(
                outs.len() == self.batch.len(),
                "backend returned {} outputs for {} sequences",
                outs.len(),
                self.batch.len()
            );
            // hard check (not a debug_assert): a backend that reorders
            // the batch through its &mut access would otherwise pair one
            // session's sampler and reply channel with another's logits
            for (o, s) in outs.iter().zip(&self.batch.seqs) {
                anyhow::ensure!(
                    o.seq_id == s.id,
                    "backend reordered sequences: output for {} at slot of {}",
                    o.seq_id,
                    s.id
                );
            }
            Ok(outs)
        });
        let outs = match outs {
            Ok(o) => o,
            Err(e) => {
                self.metrics.record_error();
                self.abort_all(FinishReason::Error(format!("{e:#}")));
                return Err(e);
            }
        };
        // walk backwards so swap_remove never disturbs unvisited entries
        let mut finished = 0;
        for i in (0..outs.len()).rev() {
            // prefill bookkeeping: throughput accounting, plus the
            // one-shot transition events on the step that finished this
            // sequence's prompt ingestion
            if outs[i].prefilled > 0 {
                self.metrics.record_prefill_tokens(outs[i].prefilled as u64);
                let s = &self.batch.seqs[i];
                if s.prefill_done() {
                    if s.truncated > 0 {
                        obs::Event::new("session_truncated")
                            .u64("session", s.id)
                            .u64("dropped", s.truncated as u64)
                            .u64("prompt", s.prompt_len as u64)
                            .emit();
                    }
                    obs::Event::new("session_prefill_done")
                        .u64("session", s.id)
                        .u64("prompt_tokens", (s.prompt_len - s.truncated) as u64)
                        .emit();
                }
            }
            let Some(logits) = outs[i].logits.as_ref() else {
                continue; // still mid-prefill: nothing to sample yet
            };
            let token = self.meta[i].sampler.sample(logits);
            let now = Instant::now();
            self.batch.seqs[i].tokens.push(token);

            let m = &mut self.meta[i];
            let latency = now.duration_since(m.last_event);
            m.last_event = now;
            let index = m.new_tokens.len();
            m.new_tokens.push(token);
            if m.reply
                .send(TokenEvent::Token {
                    token,
                    index,
                    latency,
                })
                .is_err()
            {
                // the client dropped its receiver: cancel the session so
                // a dead connection can't keep occupying a batch slot.
                // Nothing was recorded for this token — token/latency
                // series must not keep inflating after a client is gone.
                let m = self.meta.swap_remove(i);
                self.batch.seqs.swap_remove(i);
                self.metrics.record_cancelled();
                obs::Event::new("session_cancel")
                    .u64("session", m.id)
                    .u64("tokens", m.new_tokens.len() as u64)
                    .emit();
                finished += 1;
                continue;
            }
            // token metrics only after the send succeeded (see above);
            // TTFT is the first *decoded* token — prefill chunks never
            // reach this point because they carry no logits
            if index == 0 {
                self.metrics.record_ttft(now.duration_since(m.enqueued));
                obs::Event::new("session_first_token")
                    .u64("session", m.id)
                    .u64("ttft_us", now.duration_since(m.enqueued).as_micros() as u64)
                    .emit();
            } else {
                self.metrics.record_itl(latency);
            }
            self.metrics.record_token();

            let m = &mut self.meta[i];
            let reason = if m.stop.eos == Some(token) {
                Some(FinishReason::Eos)
            } else if m.new_tokens.len() >= m.stop.max_new_tokens {
                Some(FinishReason::MaxTokens)
            } else {
                None
            };
            if let Some(reason) = reason {
                let truncated = self.batch.seqs[i].truncated;
                let m = self.meta.swap_remove(i);
                self.batch.seqs.swap_remove(i);
                let total = now.duration_since(m.enqueued);
                self.metrics.record_finished(total);
                obs::Event::new("session_finish")
                    .u64("session", m.id)
                    .str("reason", format!("{reason}"))
                    .u64("tokens", m.new_tokens.len() as u64)
                    .u64("total_us", total.as_micros() as u64)
                    .emit();
                let _ = m.reply.send(TokenEvent::Done {
                    reason,
                    tokens: m.new_tokens,
                    total,
                    truncated,
                });
                finished += 1;
            }
        }
        Ok(finished)
    }

    /// Terminate every in-flight sequence with the given reason (used on
    /// shutdown and on backend failure) so no client waits forever.
    pub fn abort_all(&mut self, reason: FinishReason) {
        let now = Instant::now();
        let truncated: std::collections::HashMap<u64, usize> = self
            .batch
            .seqs
            .iter()
            .map(|s| (s.id, s.truncated))
            .collect();
        self.batch.seqs.clear();
        for m in self.meta.drain(..) {
            obs::Event::new("session_abort")
                .u64("session", m.id)
                .str("reason", format!("{reason}"))
                .u64("tokens", m.new_tokens.len() as u64)
                .emit();
            let _ = m.reply.send(TokenEvent::Done {
                reason: reason.clone(),
                tokens: m.new_tokens,
                total: now.duration_since(m.enqueued),
                truncated: truncated.get(&m.id).copied().unwrap_or(0),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::StepOutput;
    use crate::coordinator::session::SamplingParams;
    use crate::testutil::CountBackend;
    use std::sync::mpsc::{channel, Receiver};

    struct FailingBackend;
    impl Backend for FailingBackend {
        fn max_batch(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            8
        }
        fn vocab(&self) -> usize {
            16
        }
        fn name(&self) -> String {
            "failing".into()
        }
        fn step(&self, _batch: &mut InflightBatch) -> Result<Vec<StepOutput>> {
            anyhow::bail!("injected fault")
        }
    }

    fn sched(max_batch: usize) -> ContinuousScheduler {
        ContinuousScheduler::new(max_batch, usize::MAX, Arc::new(Metrics::new()))
    }

    fn queued(id: u64, req: GenerateRequest) -> (QueuedRequest, Receiver<TokenEvent>) {
        let (tx, rx) = channel();
        (
            QueuedRequest {
                id,
                request: req,
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    fn drain(rx: &Receiver<TokenEvent>) -> (Vec<i32>, Option<FinishReason>) {
        let mut toks = Vec::new();
        let mut reason = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                TokenEvent::Token { token, .. } => toks.push(token),
                TokenEvent::Done { reason: r, tokens, .. } => {
                    assert_eq!(tokens, toks, "Done must carry the streamed tokens");
                    reason = Some(r);
                }
            }
        }
        (toks, reason)
    }

    #[test]
    fn generates_until_max_tokens() {
        let be = CountBackend::new().with_vocab(16);
        let mut s = sched(4);
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![1, 2, 3], 4));
        s.admit(q);
        let mut finished = 0;
        for _ in 0..10 {
            finished += s.step(&be).unwrap();
        }
        assert_eq!(finished, 1);
        assert_eq!(s.in_flight(), 0);
        let (toks, reason) = drain(&rx);
        // context lengths 3,4,5,6 -> tokens 3,4,5,6
        assert_eq!(toks, vec![3, 4, 5, 6]);
        assert_eq!(reason, Some(FinishReason::MaxTokens));
    }

    #[test]
    fn eos_stops_early() {
        let be = CountBackend::new().with_vocab(16);
        let mut s = sched(4);
        // context length 3 -> first token is 3; eos = 5 fires on step 3
        let (q, rx) = queued(
            1,
            GenerateRequest::greedy(vec![1, 2, 3], 100)
                .with_stop(StopCriteria::max_tokens(100).with_eos(5)),
        );
        s.admit(q);
        for _ in 0..10 {
            s.step(&be).unwrap();
        }
        let (toks, reason) = drain(&rx);
        assert_eq!(toks, vec![3, 4, 5]);
        assert_eq!(reason, Some(FinishReason::Eos));
    }

    #[test]
    fn join_and_leave_between_steps() {
        let be = CountBackend::new().with_vocab(1024);
        let mut s = sched(4);
        let (qlong, rx_long) = queued(1, GenerateRequest::greedy(vec![0; 4], 16));
        s.admit(qlong);
        s.step(&be).unwrap();
        s.step(&be).unwrap();
        // short request joins the running batch mid-flight
        let (qshort, rx_short) = queued(2, GenerateRequest::greedy(vec![0; 8], 2));
        s.admit(qshort);
        assert_eq!(s.in_flight(), 2);
        s.step(&be).unwrap();
        let fin = s.step(&be).unwrap();
        // short finished (2 tokens) while long is still resident
        assert_eq!(fin, 1);
        assert_eq!(s.in_flight(), 1);
        let (toks_short, reason_short) = drain(&rx_short);
        assert_eq!(toks_short.len(), 2);
        assert_eq!(reason_short, Some(FinishReason::MaxTokens));
        // long continues to completion afterwards
        while s.in_flight() > 0 {
            s.step(&be).unwrap();
        }
        let (toks_long, reason_long) = drain(&rx_long);
        assert_eq!(toks_long.len(), 16);
        assert_eq!(reason_long, Some(FinishReason::MaxTokens));
    }

    #[test]
    fn seeded_sampling_replays_identically() {
        let be = CountBackend::new().with_vocab(64);
        let run = |seed: u64| {
            let mut s = sched(4);
            let (q, rx) = queued(
                1,
                GenerateRequest::greedy(vec![7, 8], 12)
                    .with_sampling(SamplingParams::temperature(1.0, seed)),
            );
            s.admit(q);
            while s.in_flight() > 0 {
                s.step(&be).unwrap();
            }
            drain(&rx).0
        };
        assert_eq!(run(123), run(123), "same seed => same tokens");
    }

    #[test]
    fn backend_failure_aborts_all_with_error_events() {
        let mut s = sched(4);
        let (q1, rx1) = queued(1, GenerateRequest::greedy(vec![1], 8));
        let (q2, rx2) = queued(2, GenerateRequest::greedy(vec![2], 8));
        s.admit(q1);
        s.admit(q2);
        assert!(s.step(&FailingBackend).is_err());
        assert_eq!(s.in_flight(), 0);
        for rx in [&rx1, &rx2] {
            let (_, reason) = drain(rx);
            assert!(matches!(reason, Some(FinishReason::Error(_))));
        }
    }

    #[test]
    fn degenerate_requests_finish_immediately() {
        let mut s = sched(4);
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![], 8));
        s.admit(q);
        assert_eq!(s.in_flight(), 0);
        let (_, reason) = drain(&rx);
        assert!(matches!(reason, Some(FinishReason::Error(_))));

        let (q, rx) = queued(2, GenerateRequest::greedy(vec![1, 2], 0));
        s.admit(q);
        assert_eq!(s.in_flight(), 0);
        let (toks, reason) = drain(&rx);
        assert!(toks.is_empty());
        assert_eq!(reason, Some(FinishReason::MaxTokens));
    }

    #[test]
    fn dropped_client_cancels_session() {
        let be = CountBackend::new().with_vocab(16);
        let mut s = sched(4);
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![1, 2], 100));
        s.admit(q);
        s.step(&be).unwrap();
        drop(rx); // client went away mid-generation
        s.step(&be).unwrap();
        assert_eq!(s.in_flight(), 0, "dead client must not hold a slot");
    }

    #[test]
    fn session_token_cap_clamps_requests() {
        let be = CountBackend::new().with_vocab(16);
        let mut s = ContinuousScheduler::new(4, 3, Arc::new(Metrics::new()));
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![1, 2], 1_000_000));
        s.admit(q);
        for _ in 0..10 {
            s.step(&be).unwrap();
        }
        let (toks, reason) = drain(&rx);
        assert_eq!(toks.len(), 3, "server-side cap must bound generation");
        assert_eq!(reason, Some(FinishReason::MaxTokens));
    }

    #[test]
    fn cancelled_sessions_add_no_token_metrics() {
        let be = CountBackend::new().with_vocab(16);
        let metrics = Arc::new(Metrics::new());
        let mut s = ContinuousScheduler::new(4, usize::MAX, metrics.clone());
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![1, 2], 100));
        s.admit(q);
        drop(rx); // client gone before any token is delivered
        s.step(&be).unwrap();
        assert_eq!(s.in_flight(), 0);
        let snap = metrics.snapshot();
        assert_eq!(snap.tokens, 0, "undelivered tokens must not inflate the series");
        assert_eq!(snap.ttft_count, 0, "no TTFT for a client that never got a token");
        assert_eq!(snap.cancelled, 1);
    }

    #[test]
    fn chunked_prefill_matches_all_at_once_and_counts_prompt_tokens() {
        let run = |chunk: usize| {
            let be = CountBackend::new().with_vocab(64);
            let metrics = Arc::new(Metrics::new());
            let mut s =
                ContinuousScheduler::new(4, usize::MAX, metrics.clone()).with_prefill_chunk(chunk);
            let (q, rx) = queued(1, GenerateRequest::greedy(vec![0; 6], 4));
            s.admit(q);
            let mut steps = 0;
            while s.in_flight() > 0 {
                s.step(&be).unwrap();
                steps += 1;
            }
            let snap = metrics.snapshot();
            assert_eq!(snap.prefill_tokens, 6, "every prompt token counted once");
            assert_eq!(snap.ttft_count, 1, "TTFT = first decoded token, recorded once");
            assert_eq!(snap.tokens, 4);
            let (toks, reason) = drain(&rx);
            assert_eq!(reason, Some(FinishReason::MaxTokens));
            (toks, steps)
        };
        let (all, steps_all) = run(0);
        assert_eq!(steps_all, 4, "chunk 0: the first step prefills and decodes");
        let (chunked, steps_chunked) = run(2);
        assert_eq!(chunked, all, "prefill chunking must not change the stream");
        // 6 prompt tokens at chunk 2: two logit-less steps, then the
        // completing chunk decodes the first token in the same tick
        assert_eq!(steps_chunked, steps_all + 2);
    }

    #[test]
    fn oversized_prompt_reports_truncation_on_done() {
        let be = CountBackend::new().with_vocab(1024); // seq_len 64
        let mut s = sched(4);
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![7; 100], 2));
        s.admit(q);
        while s.in_flight() > 0 {
            s.step(&be).unwrap();
        }
        let mut truncated = None;
        while let Ok(ev) = rx.try_recv() {
            if let TokenEvent::Done { truncated: t, .. } = ev {
                truncated = Some(t);
            }
        }
        assert_eq!(truncated, Some(36), "100-token prompt into a 64 window drops 36");
    }

    #[test]
    fn abort_all_sends_terminal_events() {
        let be = CountBackend::new().with_vocab(16);
        let mut s = sched(4);
        let (q, rx) = queued(1, GenerateRequest::greedy(vec![1, 2], 100));
        s.admit(q);
        s.step(&be).unwrap();
        s.abort_all(FinishReason::Shutdown);
        assert_eq!(s.in_flight(), 0);
        let (toks, reason) = drain(&rx);
        assert_eq!(toks.len(), 1);
        assert_eq!(reason, Some(FinishReason::Shutdown));
    }
}
