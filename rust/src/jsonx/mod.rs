//! Minimal JSON codec (the vendor set has no serde).
//!
//! Covers the full JSON grammar we exchange with the Python build path:
//! objects, arrays, strings (with escapes incl. \uXXXX), numbers, bools,
//! null.  Used for `artifacts/manifest.json`, metrics dumps and bench
//! output.  Not performance-critical — manifests are ~100 KB.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Builder helpers for writer-side code.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            cp
                        };
                        out.push(
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// --------------------------------------------------------------------------
// Writer
// --------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"t"],"n":-7,"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).expect("manifest parses");
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 0);
        }
    }
}
