//! Synthetic multi-domain corpus (the WikiText stand-in — see DESIGN.md
//! §4 substitutions) and batching.
//!
//! The generator is a mixture of per-domain order-1 Markov chains over a
//! shared vocabulary with Zipf-distributed unigram mass.  Two properties
//! matter for the experiments and are tested below:
//!
//! * **Skewed token frequencies** (Zipf) — drives router load imbalance,
//!   exercising the load-balance loss.
//! * **Domain structure** — distinct transition matrices per domain give
//!   experts something to specialize on (Fig. 5's diversity claim).

use crate::tensor::IntTensor;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub n_domains: usize,
    /// Zipf exponent for unigram mass (1.0 ~ natural language).
    pub zipf_s: f64,
    /// Tokens emitted between domain switches (expected).
    pub domain_run_len: usize,
    /// Per-domain branching factor: # of likely successors per token.
    pub branching: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 512,
            n_domains: 4,
            zipf_s: 1.1,
            domain_run_len: 64,
            branching: 8,
            seed: 0,
        }
    }
}

/// Streaming token source.
pub struct SyntheticCorpus {
    cfg: CorpusConfig,
    rng: Rng,
    /// successors[domain][token] -> candidate next tokens
    successors: Vec<Vec<Vec<u32>>>,
    zipf_cdf: Vec<f64>,
    domain: usize,
    prev: u32,
    run_left: usize,
}

impl SyntheticCorpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        // Zipf unigram distribution over ranked ids
        let mut mass: Vec<f64> = (1..=cfg.vocab)
            .map(|r| 1.0 / (r as f64).powf(cfg.zipf_s))
            .collect();
        let total: f64 = mass.iter().sum();
        let mut acc = 0.0;
        for m in mass.iter_mut() {
            acc += *m / total;
            *m = acc;
        }
        // Per-domain successor tables: each token gets `branching`
        // candidates drawn from the Zipf distribution by a domain-forked rng
        let successors = (0..cfg.n_domains)
            .map(|d| {
                let mut drng = rng.fork(0xD0 + d as u64);
                (0..cfg.vocab)
                    .map(|_| {
                        (0..cfg.branching)
                            .map(|_| sample_cdf(&mass, &mut drng) as u32)
                            .collect()
                    })
                    .collect()
            })
            .collect();
        SyntheticCorpus {
            rng,
            successors,
            zipf_cdf: mass,
            domain: 0,
            prev: 0,
            run_left: cfg.domain_run_len,
            cfg,
        }
    }

    /// Emit the next token.
    pub fn next_token(&mut self) -> u32 {
        if self.run_left == 0 {
            self.domain = self.rng.below(self.cfg.n_domains);
            self.run_left = 1 + self.rng.below(self.cfg.domain_run_len * 2);
        }
        self.run_left -= 1;
        // 85% Markov successor, 15% Zipf resample (noise / unconditional mass)
        let tok = if self.rng.f64() < 0.85 {
            let cands = &self.successors[self.domain][self.prev as usize];
            cands[self.rng.below(cands.len())]
        } else {
            sample_cdf(&self.zipf_cdf, &mut self.rng) as u32
        };
        self.prev = tok;
        tok
    }

    pub fn fill(&mut self, out: &mut [i32]) {
        for v in out.iter_mut() {
            *v = self.next_token() as i32;
        }
    }
}

fn sample_cdf(cdf: &[f64], rng: &mut Rng) -> usize {
    let u = rng.f64();
    match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
        Ok(i) => i,
        Err(i) => i.min(cdf.len() - 1),
    }
}

/// Next-token-prediction batches: tokens (b, l) and targets shifted by 1.
pub struct Batcher {
    corpus: SyntheticCorpus,
    pub batch: usize,
    pub seq_len: usize,
}

impl Batcher {
    pub fn new(corpus: SyntheticCorpus, batch: usize, seq_len: usize) -> Self {
        Batcher {
            corpus,
            batch,
            seq_len,
        }
    }

    /// (tokens, targets), both (batch, seq_len) i32.
    pub fn next_batch(&mut self) -> (IntTensor, IntTensor) {
        let (b, l) = (self.batch, self.seq_len);
        let mut stream = vec![0i32; b * (l + 1)];
        self.corpus.fill(&mut stream);
        let mut toks = IntTensor::zeros(&[b, l]);
        let mut tgts = IntTensor::zeros(&[b, l]);
        for i in 0..b {
            let row = &stream[i * (l + 1)..(i + 1) * (l + 1)];
            toks.data[i * l..(i + 1) * l].copy_from_slice(&row[..l]);
            tgts.data[i * l..(i + 1) * l].copy_from_slice(&row[1..]);
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let mut c = SyntheticCorpus::new(CorpusConfig::default());
        for _ in 0..10_000 {
            assert!((c.next_token() as usize) < 512);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = SyntheticCorpus::new(CorpusConfig::default());
        let mut b = SyntheticCorpus::new(CorpusConfig::default());
        let va: Vec<u32> = (0..100).map(|_| a.next_token()).collect();
        let vb: Vec<u32> = (0..100).map(|_| b.next_token()).collect();
        assert_eq!(va, vb);
    }

    #[test]
    fn frequencies_are_zipf_skewed() {
        let mut c = SyntheticCorpus::new(CorpusConfig::default());
        let mut counts = vec![0u64; 512];
        for _ in 0..200_000 {
            counts[c.next_token() as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // top-16 tokens should carry far more than uniform mass (16/512 = 3%)
        let top16: u64 = sorted[..16].iter().sum();
        assert!(top16 as f64 / 200_000.0 > 0.25, "top16 mass {top16}");
    }

    #[test]
    fn domains_have_distinct_statistics() {
        // bigram distributions conditioned on the same prev token differ
        // across domains
        let c = SyntheticCorpus::new(CorpusConfig::default());
        let tok = 1usize;
        let a: &Vec<u32> = &c.successors[0][tok];
        let b: &Vec<u32> = &c.successors[1][tok];
        assert_ne!(a, b);
    }

    #[test]
    fn batcher_shapes_and_shift() {
        let c = SyntheticCorpus::new(CorpusConfig::default());
        let mut b = Batcher::new(c, 4, 16);
        let (toks, tgts) = b.next_batch();
        assert_eq!(toks.shape, vec![4, 16]);
        assert_eq!(tgts.shape, vec![4, 16]);
        // target row is the token row shifted left by one
        for i in 0..4 {
            assert_eq!(
                &toks.data[i * 16 + 1..(i + 1) * 16],
                &tgts.data[i * 16..(i + 1) * 16 - 1]
            );
        }
    }

    #[test]
    fn batches_vary() {
        let c = SyntheticCorpus::new(CorpusConfig::default());
        let mut b = Batcher::new(c, 2, 8);
        let (t1, _) = b.next_batch();
        let (t2, _) = b.next_batch();
        assert_ne!(t1.data, t2.data);
    }
}
