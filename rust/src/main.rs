//! `bmoe` — CLI entrypoint for the ButterflyMoE coordinator/driver.

use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use butterfly_moe::cli::{Args, USAGE};
use butterfly_moe::config::RuntimeConfig;
use butterfly_moe::coordinator::{Coordinator, PjrtLmBackend, SchedulerConfig};
use butterfly_moe::runtime::Engine;
use butterfly_moe::train::Trainer;
use butterfly_moe::util::human_bytes;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    if args.has_switch("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    let mut rt = RuntimeConfig::default();
    if let Some(path) = args.flag("config-file") {
        rt.load_file(Path::new(path))?;
    }
    for (k, v) in [
        ("artifacts_dir", args.flag("artifacts")),
        ("config", args.flag("config")),
        ("steps", args.flag("steps")),
        ("lr", args.flag("lr")),
        ("seed", args.flag("seed")),
        ("port", args.flag("port")),
        ("max_batch", args.flag("max-batch")),
        ("max_wait_ms", args.flag("max-wait-ms")),
        ("prefill_chunk", args.flag("prefill-chunk")),
        ("max_new_tokens", args.flag("max-new-tokens")),
        ("temperature", args.flag("temperature")),
        ("top_k", args.flag("top-k")),
        ("expert_cache_mb", args.flag("expert-cache-mb")),
        ("workers", args.flag("workers")),
        ("n_layers", args.flag("layers")),
        ("model_path", args.flag("model")),
        ("load_mode", args.flag("load")),
        ("kernel_isa", args.flag("kernel-isa")),
        ("fleet", args.flag("fleet")),
        ("sessions_per_worker", args.flag("sessions-per-worker")),
        ("route_queue", args.flag("route-queue")),
        ("client_cap", args.flag("client-cap")),
        ("health_interval_ms", args.flag("health-interval-ms")),
        ("failover_retries", args.flag("failover-retries")),
        ("fault", args.flag("fault")),
        ("trace_sample", args.flag("trace-sample")),
        ("log_json", args.flag("log-json")),
        ("out_dir", args.flag("out")),
    ] {
        if let Some(v) = v {
            rt.set(k, v)?;
        }
    }
    for (k, v) in &args.overrides {
        rt.set(k, v)?;
    }
    if args.has_switch("exact") {
        rt.set("exact", "true")?;
    }

    match args.subcommand.as_deref().unwrap() {
        "info" => cmd_info(&rt),
        "quickstart" => cmd_quickstart(&rt),
        "train" => cmd_train(&rt, &args),
        "eval" => cmd_eval(&rt, &args),
        "serve" => cmd_serve(&rt, &args),
        "route" => cmd_route(&rt, &args),
        "pack-model" => cmd_pack_model(&rt, &args),
        "verify-model" => cmd_verify_model(&rt, &args),
        "bench-client" => cmd_bench_client(&rt, &args),
        "tables" => cmd_tables(&rt),
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

/// Synthesize the seeded multi-layer native model and pack it into a
/// `.bmoe` artifact.  `bmoe serve --native --model <file>` then serves
/// token streams bit-identical to `bmoe serve --native` with the same
/// shape flags and seed (pinned by rust/tests/artifact.rs).
fn cmd_pack_model(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use butterfly_moe::artifact::{synthesize, SynthSpec};
    let out = args.flag_or("out", "model.bmoe");
    let spec = SynthSpec {
        d_model: args.flag_parse("d-model")?.unwrap_or(256),
        d_ff: args.flag_parse("d-ff")?.unwrap_or(1024),
        n_experts: args.flag_parse("experts")?.unwrap_or(16),
        top_k: args.flag_parse("top-k-experts")?.unwrap_or(2),
        n_layers: rt.n_layers,
        vocab: args.flag_parse("vocab")?.unwrap_or(512),
        seq_len: args.flag_parse("seq-len")?.unwrap_or(32),
        depth: args.flag_parse("depth")?,
        seed: rt.seed,
    };
    let sw = butterfly_moe::util::Stopwatch::start();
    let model = synthesize(&spec);
    let built_ms = sw.millis();
    let sw = butterfly_moe::util::Stopwatch::start();
    let stats = model.pack(Path::new(&out))?;
    println!(
        "packed {} layers x {} experts (d={}, d_ff={}, top-{}) -> {}",
        spec.n_layers, spec.n_experts, spec.d_model, spec.d_ff, spec.top_k, out
    );
    println!(
        "  {} in {} tensors ({} alignment pads); synthesize {:.0} ms, pack {:.0} ms",
        human_bytes(stats.file_bytes as f64),
        stats.tensors,
        stats.pads,
        built_ms,
        sw.millis(),
    );
    println!("  serve it:  bmoe serve --native --model {out}");
    Ok(())
}

/// Verify a packed model artifact's integrity record: preflight the
/// payload accounting against the directory, then check every tensor's
/// CRC-32 against the manifest.  Exits nonzero on any mismatch,
/// truncation, or when the artifact records no checksums (packed before
/// integrity support).
fn cmd_verify_model(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use butterfly_moe::artifact::{LoadMode, ModelArtifact};
    let path = match args.positional.first() {
        Some(p) => p.clone(),
        None if !rt.model_path.is_empty() => rt.model_path.clone(),
        None => bail!("verify-model: name the artifact (positional or --model)"),
    };
    let mode = LoadMode::parse(&rt.load_mode)?;
    let sw = butterfly_moe::util::Stopwatch::start();
    let art = ModelArtifact::load_verified(Path::new(&path), mode)?;
    let integ = art.integrity.as_ref().expect("load_verified implies integrity");
    println!(
        "{path}: OK — {} tensors verified, {} payload (crc {:#010x}) in {:.0} ms",
        integ.checksums.len(),
        human_bytes(integ.payload_bytes as f64),
        integ.payload_crc,
        sw.millis(),
    );
    Ok(())
}

/// Drive a running `bmoe serve` instance over the streaming session
/// protocol and report client-observed TTFT, per-session latency, and
/// sustained token throughput.
fn cmd_bench_client(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let n: usize = args.flag_parse("requests")?.unwrap_or(100);
    let vocab: usize = args.flag_parse("vocab")?.unwrap_or(512);
    let mut stream = std::net::TcpStream::connect(("127.0.0.1", rt.port))
        .with_context(|| format!("connect to 127.0.0.1:{} (is `bmoe serve` running?)", rt.port))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut rng = butterfly_moe::util::Rng::new(rt.seed);
    let mut ttfts = Vec::with_capacity(n);
    let mut totals = Vec::with_capacity(n);
    let mut tokens = 0u64;
    let bench_t0 = std::time::Instant::now();
    for i in 0..n {
        let len = 3 + rng.below(10);
        let prompt: Vec<String> = (0..len).map(|_| rng.below(vocab).to_string()).collect();
        let t0 = std::time::Instant::now();
        writeln!(
            stream,
            "GEN {} {} {} {} -1 {}",
            rt.max_new_tokens,
            rt.temperature,
            rt.top_k,
            rt.seed.wrapping_add(i as u64),
            prompt.join(" ")
        )?;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let line = line.trim();
            anyhow::ensure!(!line.starts_with("ERR"), "server error: {line}");
            if let Some(rest) = line.strip_prefix("TOK ") {
                let mut f = rest.split_whitespace();
                if f.next() == Some("0") {
                    ttfts.push(t0.elapsed().as_secs_f64());
                }
                tokens += 1;
            } else if line.starts_with("END ") {
                totals.push(t0.elapsed().as_secs_f64());
                break;
            } else {
                anyhow::bail!("unexpected server line: {line}");
            }
        }
    }
    writeln!(stream, "QUIT")?;
    let wall = bench_t0.elapsed().as_secs_f64();
    use butterfly_moe::util::stats;
    println!(
        "{n} sessions, {tokens} tokens in {wall:.1}s -> {:.0} tok/s",
        tokens as f64 / wall
    );
    println!(
        "  ttft  p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms",
        1e3 * stats::percentile(&ttfts, 50.0),
        1e3 * stats::percentile(&ttfts, 95.0),
        1e3 * stats::percentile(&ttfts, 99.0),
    );
    println!(
        "  total p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | mean {:.2} ms",
        1e3 * stats::percentile(&totals, 50.0),
        1e3 * stats::percentile(&totals, 95.0),
        1e3 * stats::percentile(&totals, 99.0),
        1e3 * stats::mean(&totals),
    );
    Ok(())
}

fn engine(rt: &RuntimeConfig) -> Result<Engine> {
    Engine::new(Path::new(&rt.artifacts_dir))
}

fn cmd_info(rt: &RuntimeConfig) -> Result<()> {
    let eng = engine(rt)?;
    println!("platform: {}", eng.platform());
    println!("configs:");
    for (name, c) in &eng.manifest.configs {
        println!(
            "  {name}: d={} d_ff={} E={} top{} blocks={} vocab={} arch={}",
            c.d_model, c.d_ff, c.n_experts, c.top_k, c.n_blocks, c.vocab, c.arch.name()
        );
    }
    println!("artifacts:");
    for a in eng.manifest.artifacts.values() {
        println!("  {:<32} kind={:<10} cfg={}", a.name, a.kind, a.config);
    }
    Ok(())
}

fn cmd_quickstart(rt: &RuntimeConfig) -> Result<()> {
    use butterfly_moe::memmodel::{butterfly_bytes, LayerShape, Method};
    let eng = engine(rt)?;
    let cfg = eng.manifest.config(&rt.config)?.clone();
    let shape: LayerShape = cfg.layer_shape();
    println!("== ButterflyMoE quickstart ({}) ==", rt.config);
    println!(
        "layer d_model={} d_ff={} experts={} top-{}",
        cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    );
    println!(
        "expert memory: butterfly {} vs standard {} ({:.1}x)",
        human_bytes(butterfly_bytes(cfg.n_experts, shape)),
        human_bytes(Method::StandardMoe.bytes(cfg.n_experts, shape)),
        Method::ButterflyMoe.ratio(cfg.n_experts, shape)
    );
    drop(eng);
    let (backend, _join) = PjrtLmBackend::start(Path::new(&rt.artifacts_dir), &rt.config, None)?;
    let next = butterfly_moe::coordinator::greedy_next(&backend, &[vec![1, 2, 3, 4, 5]])?;
    println!("forward OK; next token for [1,2,3,4,5] -> {}", next[0]);
    std::process::exit(0); // engine thread holds the process otherwise
}

fn cmd_train(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    let eng = engine(rt)?;
    let trainer = Trainer::new(&eng, rt.clone());
    let ckpt = args.flag("from").map(Path::new);
    let report = trainer.run(&rt.config, ckpt)?;
    let csv = Path::new(&rt.out_dir).join(format!("{}_loss.csv", rt.config));
    report.write_csv(&csv)?;
    let final_ckpt = Path::new(&rt.out_dir).join(format!("{}_final.bmoe", rt.config));
    report.save_checkpoint(&final_ckpt)?;
    println!(
        "trained {} for {} steps in {:.1}s: loss {:.4} (tail ce {:.4})",
        rt.config,
        report.logs.len(),
        report.total_secs,
        report.final_loss(),
        report.tail_ce(20),
    );
    println!("loss curve: {}", csv.display());
    println!("checkpoint: {}", final_ckpt.display());
    Ok(())
}

fn cmd_eval(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    let eng = engine(rt)?;
    let trainer = Trainer::new(&eng, rt.clone());
    let names = eng
        .manifest
        .params
        .get(&rt.config)
        .context("params entry")?
        .names
        .clone();
    let params = match args.flag("from") {
        Some(p) => butterfly_moe::train::load_checkpoint_values(Path::new(p), &names)?,
        None => eng.load_params(&rt.config)?,
    };
    let n = args.flag_parse::<usize>("batches")?.unwrap_or(8);
    let ce = trainer.eval(&rt.config, &params, n)?;
    println!("{}: held-out CE over {n} batches = {ce:.4}", rt.config);
    Ok(())
}

fn cmd_serve(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use butterfly_moe::artifact::{synthesize, LoadMode, ModelArtifact, SynthSpec};
    use butterfly_moe::coordinator::{Backend, NativeLmBackend};
    use butterfly_moe::moe::MoeLayer;
    use butterfly_moe::obs;
    obs::init(rt.trace_sample, &rt.log_json)?;
    butterfly_moe::faults::init_from(&rt.fault)?;
    // Pin the kernel ISA before any kernel runs: --kernel-isa, else the
    // BMOE_KERNEL_ISA env var, else runtime detection.  Every path is
    // bit-identical (f32) / exactly equal (i8) — see kernels::dispatch.
    let isa = butterfly_moe::kernels::dispatch::force(&rt.kernel_isa)?;
    let backend: Arc<dyn Backend> = if args.has_switch("native") {
        // pure-rust edge backend: serves without compiled artifacts (and
        // without a PJRT runtime) — a packed .bmoe model file, or the
        // seeded synthetic stand-in when no --model is given
        let act_quant = !rt.exact;
        obs::log(
            "serve",
            format!(
                "numerics: {} | kernel ISA: {isa}",
                if act_quant {
                    "W1.58A8 quantized substrate GEMM (opt out: --exact)"
                } else {
                    "exact f32 substrate GEMM (--exact)"
                },
            ),
        );
        let workers = butterfly_moe::parallel::resolve_workers(rt.workers);
        let pool = Arc::new(butterfly_moe::parallel::WorkerPool::new(workers));
        obs::log(
            "serve",
            format!("workers: {workers} (decoded streams are worker-count invariant)"),
        );
        let cache_bytes = (rt.expert_cache_mb * 1048576.0) as usize;
        let backend = if !rt.model_path.is_empty() {
            let mode = LoadMode::parse(&rt.load_mode)?;
            let sw = butterfly_moe::util::Stopwatch::start();
            // --verify: check every tensor checksum before serving (heap
            // loads verify eagerly either way; this forces it for mmap)
            let artifact = if args.has_switch("verify") {
                ModelArtifact::load_verified(Path::new(&rt.model_path), mode)?
            } else {
                ModelArtifact::load(Path::new(&rt.model_path), mode)?
            };
            let backend = NativeLmBackend::from_artifact_opts(
                &artifact,
                rt.max_batch,
                Some(pool),
                cache_bytes,
                act_quant,
            )?;
            let (borrowed, copied) = artifact.zero_copy_stats();
            obs::log(
                "serve",
                format!(
                    "model: {} — {} layers, {} ({} load in {:.1} ms; \
                     {borrowed} tensors zero-copy, {copied} copied)",
                    rt.model_path,
                    artifact.manifest.n_layers,
                    human_bytes(artifact.file_bytes() as f64),
                    mode.name(),
                    sw.millis(),
                ),
            );
            backend
        } else {
            let model = synthesize(&SynthSpec::serve_default(rt.n_layers, rt.seed));
            NativeLmBackend::from_synth_opts(
                model,
                rt.max_batch,
                Some(pool),
                cache_bytes,
                act_quant,
            )
        };
        if cache_bytes > 0 && act_quant {
            // the residency cache serves the exact f32 synthesis path
            // only; under the A8 default the stack assembler attaches
            // no cache at all (see coordinator::backend::attach_stack)
            obs::log(
                "serve",
                format!(
                    "warning: --expert-cache-mb {} is bypassed under the W1.58A8 default; \
                     pass --exact to serve from the cache",
                    rt.expert_cache_mb
                ),
            );
        } else if cache_bytes > 0 {
            // per-layer budget: the serving dial splits evenly across
            // blocks (a split that rounds to zero attaches no cache)
            match backend.layers()[0].expert_cache() {
                Some(cache) => {
                    obs::log(
                        "serve",
                        format!(
                            "expert cache: {} per layer x {} layers = {} resident experts \
                             max per layer ({} each)",
                            human_bytes(cache.budget_bytes() as f64),
                            backend.n_layers(),
                            cache.capacity_experts(),
                            human_bytes(cache.entry_bytes() as f64),
                        ),
                    );
                    if !cache.enabled() {
                        obs::log(
                            "serve",
                            format!(
                                "warning: --expert-cache-mb {} splits below one working set \
                                 per layer ({}); cache DISABLED, serving pure sub-linear",
                                rt.expert_cache_mb,
                                human_bytes(cache.entry_bytes() as f64),
                            ),
                        );
                    }
                }
                None => obs::log(
                    "serve",
                    format!(
                        "warning: --expert-cache-mb {} rounds to zero bytes per layer; \
                         cache DISABLED, serving pure sub-linear",
                        rt.expert_cache_mb
                    ),
                ),
            }
        }
        Arc::new(backend)
    } else {
        if rt.expert_cache_mb > 0.0 {
            obs::log("serve", "note: --expert-cache-mb applies to the --native backend only");
        }
        if rt.workers > 0 {
            obs::log("serve", "note: --workers applies to the --native backend only");
        }
        if !rt.model_path.is_empty() {
            obs::log(
                "serve",
                "note: --model names a native .bmoe artifact; the PJRT backend \
                 loads checkpoints via --from instead",
            );
        }
        let ckpt = args.flag("from").map(Path::new);
        let (backend, _join) =
            PjrtLmBackend::start(Path::new(&rt.artifacts_dir), &rt.config, ckpt)?;
        Arc::new(backend)
    };
    obs::log("serve", format!("backend: {}", backend.name()));
    if !args.has_switch("no-warmup") {
        // drive every bucket once and pre-materialize the cache working
        // set so the first real request's TTFT pays neither cost
        butterfly_moe::coordinator::warm(backend.as_ref())?;
    }
    let coord = Coordinator::start(
        backend,
        SchedulerConfig::new(rt.max_batch, Duration::from_millis(rt.max_wait_ms))
            .with_prefill_chunk(rt.prefill_chunk),
    );
    let stop = Arc::new(AtomicBool::new(false));
    {
        let coord = coord.clone();
        let metrics_stop = stop.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_secs(5));
            if metrics_stop.load(std::sync::atomic::Ordering::SeqCst) {
                break;
            }
            obs::log("metrics", coord.metrics.snapshot().summary());
        });
    }
    butterfly_moe::coordinator::server::serve_tcp(coord, rt.port, stop)
}

/// Fleet front door: spawn and supervise `--fleet` child `bmoe serve
/// --native` processes (each `--port 0`, discovered via their
/// `[listening]` lines) and load-balance streaming sessions across
/// them.  With `--load mmap` every worker borrows the same packed
/// model pages from the page cache, so fleet RSS grows sub-linearly in
/// worker count (measured by benches/router_load.rs).
fn cmd_route(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use butterfly_moe::obs;
    use butterfly_moe::router::{run, worker::ProcessLauncher, RouterConfig};
    obs::init(rt.trace_sample, &rt.log_json)?;
    butterfly_moe::faults::init_from(&rt.fault)?;
    let bin = std::env::current_exe().context("locate the bmoe binary for worker spawns")?;
    // Workers inherit the serve-relevant settings; --port 0 is appended
    // by the launcher so each picks its own ephemeral port.
    let mut wargs: Vec<String> = vec!["--native".into()];
    if !rt.model_path.is_empty() {
        wargs.extend([
            "--model".into(),
            rt.model_path.clone(),
            "--load".into(),
            rt.load_mode.clone(),
        ]);
    } else {
        obs::log("route", "no --model: every worker synthesizes its own seeded stand-in model");
        wargs.extend(["--layers".into(), rt.n_layers.to_string()]);
    }
    for (flag, value) in [
        ("--max-batch", rt.max_batch.to_string()),
        ("--max-wait-ms", rt.max_wait_ms.to_string()),
        ("--prefill-chunk", rt.prefill_chunk.to_string()),
        ("--workers", rt.workers.to_string()),
        ("--seed", rt.seed.to_string()),
    ] {
        wargs.extend([flag.into(), value]);
    }
    if rt.expert_cache_mb > 0.0 {
        wargs.extend(["--expert-cache-mb".into(), rt.expert_cache_mb.to_string()]);
    }
    // Numerics and kernel-ISA pins pass through: every worker must run
    // the same substrate GEMM and the same kernel path, or failover
    // replay verification (router::proxy) would diverge mid-stream.
    if rt.exact {
        wargs.push("--exact".into());
    }
    if !rt.kernel_isa.is_empty() {
        wargs.extend(["--kernel-isa".into(), rt.kernel_isa.clone()]);
    }
    if args.has_switch("no-warmup") {
        wargs.push("--no-warmup".into());
    }
    // Observability passes through: each worker samples its own hot
    // path (the router's METRICS aggregation relabels per worker), and
    // all processes append to the same JSONL sink (O_APPEND, one line
    // per write).  A `-` sink stays router-local: worker stdout is the
    // [listening] discovery channel, not a log stream.
    if rt.trace_sample > 0 {
        wargs.extend(["--trace-sample".into(), rt.trace_sample.to_string()]);
    }
    if !rt.log_json.is_empty() && rt.log_json != "-" {
        wargs.extend(["--log-json".into(), rt.log_json.clone()]);
    }
    // Fault plans pass through: worker-side points (stall, wire
    // corruption, artifact bit rot) live in the serve processes, while
    // the router keeps the spawn/kill points — one spec drives both.
    if !rt.fault.is_empty() {
        wargs.extend(["--fault".into(), rt.fault.clone()]);
    }
    let cfg = RouterConfig {
        port: rt.port,
        fleet: rt.fleet,
        sessions_per_worker: rt.sessions_per_worker,
        max_queue: rt.route_queue,
        client_cap: rt.client_cap,
        health_interval: Duration::from_millis(rt.health_interval_ms),
        failover_retries: rt.failover_retries,
        ..RouterConfig::default()
    };
    obs::log(
        "route",
        &format!(
            "spawning {} x `{} serve {}`",
            cfg.fleet,
            bin.display(),
            wargs.join(" ")
        ),
    );
    run(cfg, Arc::new(ProcessLauncher::new(bin, wargs)))
}

fn cmd_tables(rt: &RuntimeConfig) -> Result<()> {
    // The analytic tables print without artifacts; measured ones live in
    // cargo bench targets (see DESIGN.md §6 experiment index).
    let _ = rt;
    butterfly_moe::bench::paper_tables::print_all(Path::new("runs/tables"))
}
