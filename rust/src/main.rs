//! `bmoe` — CLI entrypoint for the ButterflyMoE coordinator/driver.

use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use butterfly_moe::cli::{Args, USAGE};
use butterfly_moe::config::RuntimeConfig;
use butterfly_moe::coordinator::{Coordinator, PjrtLmBackend, SchedulerConfig};
use butterfly_moe::runtime::Engine;
use butterfly_moe::train::Trainer;
use butterfly_moe::util::human_bytes;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    if args.has_switch("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    let mut rt = RuntimeConfig::default();
    if let Some(path) = args.flag("config-file") {
        rt.load_file(Path::new(path))?;
    }
    for (k, v) in [
        ("artifacts_dir", args.flag("artifacts")),
        ("config", args.flag("config")),
        ("steps", args.flag("steps")),
        ("lr", args.flag("lr")),
        ("seed", args.flag("seed")),
        ("port", args.flag("port")),
        ("max_batch", args.flag("max-batch")),
        ("max_wait_ms", args.flag("max-wait-ms")),
        ("max_new_tokens", args.flag("max-new-tokens")),
        ("temperature", args.flag("temperature")),
        ("top_k", args.flag("top-k")),
        ("expert_cache_mb", args.flag("expert-cache-mb")),
        ("workers", args.flag("workers")),
        ("out_dir", args.flag("out")),
    ] {
        if let Some(v) = v {
            rt.set(k, v)?;
        }
    }
    for (k, v) in &args.overrides {
        rt.set(k, v)?;
    }

    match args.subcommand.as_deref().unwrap() {
        "info" => cmd_info(&rt),
        "quickstart" => cmd_quickstart(&rt),
        "train" => cmd_train(&rt, &args),
        "eval" => cmd_eval(&rt, &args),
        "serve" => cmd_serve(&rt, &args),
        "bench-client" => cmd_bench_client(&rt, &args),
        "tables" => cmd_tables(&rt),
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

/// Drive a running `bmoe serve` instance over the streaming session
/// protocol and report client-observed TTFT, per-session latency, and
/// sustained token throughput.
fn cmd_bench_client(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let n: usize = args.flag_parse("requests")?.unwrap_or(100);
    let vocab: usize = args.flag_parse("vocab")?.unwrap_or(512);
    let mut stream = std::net::TcpStream::connect(("127.0.0.1", rt.port))
        .with_context(|| format!("connect to 127.0.0.1:{} (is `bmoe serve` running?)", rt.port))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut rng = butterfly_moe::util::Rng::new(rt.seed);
    let mut ttfts = Vec::with_capacity(n);
    let mut totals = Vec::with_capacity(n);
    let mut tokens = 0u64;
    let bench_t0 = std::time::Instant::now();
    for i in 0..n {
        let len = 3 + rng.below(10);
        let prompt: Vec<String> = (0..len).map(|_| rng.below(vocab).to_string()).collect();
        let t0 = std::time::Instant::now();
        writeln!(
            stream,
            "GEN {} {} {} {} -1 {}",
            rt.max_new_tokens,
            rt.temperature,
            rt.top_k,
            rt.seed.wrapping_add(i as u64),
            prompt.join(" ")
        )?;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let line = line.trim();
            anyhow::ensure!(!line.starts_with("ERR"), "server error: {line}");
            if let Some(rest) = line.strip_prefix("TOK ") {
                let mut f = rest.split_whitespace();
                if f.next() == Some("0") {
                    ttfts.push(t0.elapsed().as_secs_f64());
                }
                tokens += 1;
            } else if line.starts_with("END ") {
                totals.push(t0.elapsed().as_secs_f64());
                break;
            } else {
                anyhow::bail!("unexpected server line: {line}");
            }
        }
    }
    writeln!(stream, "QUIT")?;
    let wall = bench_t0.elapsed().as_secs_f64();
    use butterfly_moe::util::stats;
    println!(
        "{n} sessions, {tokens} tokens in {wall:.1}s -> {:.0} tok/s",
        tokens as f64 / wall
    );
    println!(
        "  ttft  p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms",
        1e3 * stats::percentile(&ttfts, 50.0),
        1e3 * stats::percentile(&ttfts, 95.0),
        1e3 * stats::percentile(&ttfts, 99.0),
    );
    println!(
        "  total p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | mean {:.2} ms",
        1e3 * stats::percentile(&totals, 50.0),
        1e3 * stats::percentile(&totals, 95.0),
        1e3 * stats::percentile(&totals, 99.0),
        1e3 * stats::mean(&totals),
    );
    Ok(())
}

fn engine(rt: &RuntimeConfig) -> Result<Engine> {
    Engine::new(Path::new(&rt.artifacts_dir))
}

fn cmd_info(rt: &RuntimeConfig) -> Result<()> {
    let eng = engine(rt)?;
    println!("platform: {}", eng.platform());
    println!("configs:");
    for (name, c) in &eng.manifest.configs {
        println!(
            "  {name}: d={} d_ff={} E={} top{} blocks={} vocab={} arch={}",
            c.d_model, c.d_ff, c.n_experts, c.top_k, c.n_blocks, c.vocab, c.arch.name()
        );
    }
    println!("artifacts:");
    for a in eng.manifest.artifacts.values() {
        println!("  {:<32} kind={:<10} cfg={}", a.name, a.kind, a.config);
    }
    Ok(())
}

fn cmd_quickstart(rt: &RuntimeConfig) -> Result<()> {
    use butterfly_moe::memmodel::{butterfly_bytes, LayerShape, Method};
    let eng = engine(rt)?;
    let cfg = eng.manifest.config(&rt.config)?.clone();
    let shape: LayerShape = cfg.layer_shape();
    println!("== ButterflyMoE quickstart ({}) ==", rt.config);
    println!(
        "layer d_model={} d_ff={} experts={} top-{}",
        cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    );
    println!(
        "expert memory: butterfly {} vs standard {} ({:.1}x)",
        human_bytes(butterfly_bytes(cfg.n_experts, shape)),
        human_bytes(Method::StandardMoe.bytes(cfg.n_experts, shape)),
        Method::ButterflyMoe.ratio(cfg.n_experts, shape)
    );
    drop(eng);
    let (backend, _join) = PjrtLmBackend::start(Path::new(&rt.artifacts_dir), &rt.config, None)?;
    let next = butterfly_moe::coordinator::greedy_next(&backend, &[vec![1, 2, 3, 4, 5]])?;
    println!("forward OK; next token for [1,2,3,4,5] -> {}", next[0]);
    std::process::exit(0); // engine thread holds the process otherwise
}

fn cmd_train(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    let eng = engine(rt)?;
    let trainer = Trainer::new(&eng, rt.clone());
    let ckpt = args.flag("from").map(Path::new);
    let report = trainer.run(&rt.config, ckpt)?;
    let csv = Path::new(&rt.out_dir).join(format!("{}_loss.csv", rt.config));
    report.write_csv(&csv)?;
    let final_ckpt = Path::new(&rt.out_dir).join(format!("{}_final.bmoe", rt.config));
    report.save_checkpoint(&final_ckpt)?;
    println!(
        "trained {} for {} steps in {:.1}s: loss {:.4} (tail ce {:.4})",
        rt.config,
        report.logs.len(),
        report.total_secs,
        report.final_loss(),
        report.tail_ce(20),
    );
    println!("loss curve: {}", csv.display());
    println!("checkpoint: {}", final_ckpt.display());
    Ok(())
}

fn cmd_eval(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    let eng = engine(rt)?;
    let trainer = Trainer::new(&eng, rt.clone());
    let names = eng
        .manifest
        .params
        .get(&rt.config)
        .context("params entry")?
        .names
        .clone();
    let params = match args.flag("from") {
        Some(p) => butterfly_moe::train::load_checkpoint_values(Path::new(p), &names)?,
        None => eng.load_params(&rt.config)?,
    };
    let n = args.flag_parse::<usize>("batches")?.unwrap_or(8);
    let ce = trainer.eval(&rt.config, &params, n)?;
    println!("{}: held-out CE over {n} batches = {ce:.4}", rt.config);
    Ok(())
}

fn cmd_serve(rt: &RuntimeConfig, args: &Args) -> Result<()> {
    use butterfly_moe::coordinator::{Backend, NativeMoeBackend};
    use butterfly_moe::expertcache::ExpertCacheConfig;
    let backend: Arc<dyn Backend> = if args.has_switch("native") {
        // pure-rust edge backend: serves without compiled artifacts (and
        // without a PJRT runtime)
        let mut rng = butterfly_moe::util::Rng::new(rt.seed);
        let mut layer =
            butterfly_moe::moe::ButterflyMoeLayer::random(256, 1024, 16, 2, None, &mut rng);
        let workers = butterfly_moe::parallel::resolve_workers(rt.workers);
        layer.attach_worker_pool(Arc::new(butterfly_moe::parallel::WorkerPool::new(workers)));
        eprintln!("[serve] workers: {workers} (decoded streams are worker-count invariant)");
        if rt.expert_cache_mb > 0.0 {
            let cache =
                layer.attach_expert_cache(ExpertCacheConfig::with_budget_mb(rt.expert_cache_mb));
            eprintln!(
                "[serve] expert cache: budget {} = {} resident experts max ({} each)",
                human_bytes(cache.budget_bytes() as f64),
                cache.capacity_experts(),
                human_bytes(cache.entry_bytes() as f64),
            );
            if !cache.enabled() {
                eprintln!(
                    "[serve] warning: --expert-cache-mb {} is smaller than one working set \
                     ({}); cache DISABLED, serving pure sub-linear",
                    rt.expert_cache_mb,
                    human_bytes(cache.entry_bytes() as f64),
                );
            }
        }
        Arc::new(NativeMoeBackend::new(Arc::new(layer), 512, 32, rt.max_batch))
    } else {
        if rt.expert_cache_mb > 0.0 {
            eprintln!("[serve] note: --expert-cache-mb applies to the --native backend only");
        }
        if rt.workers > 0 {
            eprintln!("[serve] note: --workers applies to the --native backend only");
        }
        let ckpt = args.flag("from").map(Path::new);
        let (backend, _join) =
            PjrtLmBackend::start(Path::new(&rt.artifacts_dir), &rt.config, ckpt)?;
        Arc::new(backend)
    };
    eprintln!("[serve] backend: {}", backend.name());
    if !args.has_switch("no-warmup") {
        // drive every bucket once and pre-materialize the cache working
        // set so the first real request's TTFT pays neither cost
        butterfly_moe::coordinator::warm(backend.as_ref())?;
    }
    let coord = Coordinator::start(
        backend,
        SchedulerConfig::new(rt.max_batch, Duration::from_millis(rt.max_wait_ms)),
    );
    let stop = Arc::new(AtomicBool::new(false));
    {
        let coord = coord.clone();
        let metrics_stop = stop.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(Duration::from_secs(5));
            if metrics_stop.load(std::sync::atomic::Ordering::SeqCst) {
                break;
            }
            eprintln!("[metrics] {}", coord.metrics.snapshot().summary());
        });
    }
    butterfly_moe::coordinator::server::serve_tcp(coord, rt.port, stop)
}

fn cmd_tables(rt: &RuntimeConfig) -> Result<()> {
    // The analytic tables print without artifacts; measured ones live in
    // cargo bench targets (see DESIGN.md §6 experiment index).
    let _ = rt;
    butterfly_moe::bench::paper_tables::print_all(Path::new("runs/tables"))
}
