//! Top-k gating network (Stage 3 of §3.4).
//!
//! Mirrors `model.py::topk_gate`: softmax over expert logits, keep the
//! top-k probabilities, renormalize them to sum to 1.

use crate::tensor::Tensor;

/// Routing decision for one token.
#[derive(Clone, Debug, PartialEq)]
pub struct Route {
    /// (expert index, renormalized weight), length k, sorted by weight desc
    pub experts: Vec<(usize, f32)>,
}

#[derive(Clone, Debug)]
pub struct GateNetwork {
    /// (n_experts, d_model) — logits = W x
    pub w: Tensor,
    pub top_k: usize,
}

impl GateNetwork {
    pub fn new(w: Tensor, top_k: usize) -> Self {
        assert_eq!(w.rank(), 2);
        assert!(top_k >= 1 && top_k <= w.shape[0]);
        GateNetwork { w, top_k }
    }

    pub fn n_experts(&self) -> usize {
        self.w.shape[0]
    }

    pub fn d_model(&self) -> usize {
        self.w.shape[1]
    }

    /// Route one token embedding.
    pub fn route(&self, x: &[f32]) -> Route {
        let e = self.n_experts();
        assert_eq!(x.len(), self.d_model());
        let mut logits = vec![0.0f32; e];
        for i in 0..e {
            logits[i] = crate::util::dot_f32(self.w.row(i), x);
        }
        softmax_inplace(&mut logits);
        let mut idx: Vec<usize> = (0..e).collect();
        // partial selection of top-k by probability
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.truncate(self.top_k);
        let total: f32 = idx.iter().map(|&i| logits[i]).sum();
        Route {
            experts: idx.into_iter().map(|i| (i, logits[i] / total)).collect(),
        }
    }

    /// Route a (t, d) batch; also returns per-expert load fractions
    /// (n_i / (k * t), the eq.-6 quantity — sums to 1).
    pub fn route_batch(&self, x: &[f32], t: usize) -> (Vec<Route>, Vec<f64>) {
        let d = self.d_model();
        assert_eq!(x.len(), t * d);
        let mut loads = vec![0.0f64; self.n_experts()];
        let routes: Vec<Route> = (0..t)
            .map(|i| {
                let r = self.route(&x[i * d..(i + 1) * d]);
                for &(e, _) in &r.experts {
                    loads[e] += 1.0;
                }
                r
            })
            .collect();
        let denom = (self.top_k * t.max(1)) as f64;
        for l in loads.iter_mut() {
            *l /= denom;
        }
        (routes, loads)
    }

    /// Invert routes into per-expert token lists: (token index, weight).
    pub fn dispatch(routes: &[Route], n_experts: usize) -> Vec<Vec<(usize, f32)>> {
        let mut per_expert: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_experts];
        for (t, r) in routes.iter().enumerate() {
            for &(e, w) in &r.experts {
                per_expert[e].push((t, w));
            }
        }
        per_expert
    }
}

pub fn softmax_inplace(v: &mut [f32]) {
    let max = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in v.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
}

/// Load-balance penalty, eq. (6): sum_i (load_i - 1/E)^2.
pub fn balance_penalty(loads: &[f64]) -> f64 {
    let e = loads.len() as f64;
    loads.iter().map(|l| (l - 1.0 / e) * (l - 1.0 / e)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gate(e: usize, d: usize, k: usize, seed: u64) -> GateNetwork {
        let mut rng = Rng::new(seed);
        GateNetwork::new(Tensor::rand_normal(&[e, d], 0.5, &mut rng), k)
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0, 2.0, 3.0, -1.0];
        softmax_inplace(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut v = vec![1000.0, 999.0];
        softmax_inplace(&mut v);
        assert!(v[0] > v[1] && v[0].is_finite());
    }

    #[test]
    fn route_weights_sum_to_one() {
        let g = gate(8, 16, 2, 1);
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(1.0)).collect();
            let r = g.route(&x);
            assert_eq!(r.experts.len(), 2);
            let s: f32 = r.experts.iter().map(|e| e.1).sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(r.experts[0].1 >= r.experts[1].1);
        }
    }

    #[test]
    fn k1_picks_argmax() {
        let g = gate(5, 8, 1, 3);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..8).map(|_| rng.normal_f32(1.0)).collect();
        let r = g.route(&x);
        assert_eq!(r.experts.len(), 1);
        // brute-force argmax of logits
        let mut best = (0, f32::NEG_INFINITY);
        for i in 0..5 {
            let l: f32 = g.w.row(i).iter().zip(&x).map(|(a, b)| a * b).sum();
            if l > best.1 {
                best = (i, l);
            }
        }
        assert_eq!(r.experts[0].0, best.0);
    }

    #[test]
    fn batch_loads_sum_to_one() {
        let g = gate(4, 8, 2, 5);
        let mut rng = Rng::new(6);
        let t = 50;
        let x: Vec<f32> = (0..t * 8).map(|_| rng.normal_f32(1.0)).collect();
        let (routes, loads) = g.route_batch(&x, t);
        assert_eq!(routes.len(), t);
        assert!((loads.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dispatch_inverts_routes() {
        let g = gate(4, 8, 2, 7);
        let mut rng = Rng::new(8);
        let t = 10;
        let x: Vec<f32> = (0..t * 8).map(|_| rng.normal_f32(1.0)).collect();
        let (routes, _) = g.route_batch(&x, t);
        let disp = GateNetwork::dispatch(&routes, 4);
        let total: usize = disp.iter().map(Vec::len).sum();
        assert_eq!(total, t * 2);
        for (e, toks) in disp.iter().enumerate() {
            for &(ti, w) in toks {
                assert!(routes[ti].experts.iter().any(|&(ei, wi)| ei == e && wi == w));
            }
        }
    }

    #[test]
    fn balance_penalty_zero_at_uniform() {
        assert!(balance_penalty(&[0.25; 4]) < 1e-12);
        assert!(balance_penalty(&[1.0, 0.0, 0.0, 0.0]) > 0.5);
    }

    /// Property-style randomized check of the gating invariant: across
    /// random shapes, top-k values and inputs, `dispatch` assigns every
    /// token to exactly `top_k` *distinct* in-range experts whose
    /// renormalized weights sum to ~1, and the per-expert load vector is
    /// exactly the dispatch histogram over `k·t`.
    #[test]
    fn dispatch_invariants_hold_for_random_inputs() {
        for trial in 0..60u64 {
            let mut meta = Rng::new(0xD15 + trial);
            let e = 2 + meta.below(14);
            let k = 1 + meta.below(e.min(4));
            let d = [8usize, 16, 32][meta.below(3)];
            let t = 1 + meta.below(24);
            let g = gate(e, d, k, 7000 + trial);
            let x: Vec<f32> = (0..t * d).map(|_| meta.normal_f32(1.5)).collect();
            let (routes, loads) = g.route_batch(&x, t);
            let disp = GateNetwork::dispatch(&routes, e);
            let ctx = format!("trial {trial}: e={e} k={k} d={d} t={t}");

            // every token appears in exactly k experts' lists, no expert
            // twice for the same token, indices in range by construction
            let mut per_token_count = vec![0usize; t];
            let mut per_token_weight = vec![0.0f32; t];
            for toks in &disp {
                let mut seen_this_expert = std::collections::HashSet::new();
                for &(ti, w) in toks {
                    assert!(ti < t, "{ctx}: token index out of range");
                    assert!(seen_this_expert.insert(ti), "{ctx}: token duplicated");
                    assert!(w > 0.0 && w <= 1.0 + 1e-6, "{ctx}: weight {w}");
                    per_token_count[ti] += 1;
                    per_token_weight[ti] += w;
                }
            }
            for ti in 0..t {
                assert_eq!(per_token_count[ti], k, "{ctx}: token {ti} expert count");
                assert!(
                    (per_token_weight[ti] - 1.0).abs() < 1e-4,
                    "{ctx}: token {ti} weights sum {}",
                    per_token_weight[ti]
                );
            }
            // routes themselves carry distinct expert ids per token
            for r in &routes {
                let mut ids: Vec<usize> = r.experts.iter().map(|&(ei, _)| ei).collect();
                assert!(ids.iter().all(|&ei| ei < e), "{ctx}: expert id range");
                ids.sort_unstable();
                ids.dedup();
                assert_eq!(ids.len(), k, "{ctx}: duplicate expert for one token");
            }
            // loads are the dispatch histogram over k*t, summing to 1
            let denom = (k * t) as f64;
            for (ei, toks) in disp.iter().enumerate() {
                assert!(
                    (loads[ei] - toks.len() as f64 / denom).abs() < 1e-12,
                    "{ctx}: load[{ei}]"
                );
            }
            assert!((loads.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{ctx}");
        }
    }
}
