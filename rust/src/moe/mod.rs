//! Native edge inference engine for ButterflyMoE layers.
//!
//! This is the deployment path the paper's edge claims are about: packed
//! ternary substrate + O(d log d) butterfly orbits, experts synthesized
//! on the fly (Alg. 1), true sparse top-k dispatch (the L2 jax graph uses
//! the dense-mask formulation instead; the two are parity-tested).

pub mod gating;
pub mod layer;

pub use gating::GateNetwork;
pub use layer::{ButterflyMoeLayer, DenseFfn, MoeLayer, StandardMoeLayer};

/// GELU, tanh approximation — bit-compatible with `jax.nn.gelu`
/// (approximate=True), which the L2 model uses.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_reference_points() {
        // values from jax.nn.gelu(approximate=True)
        assert!((gelu(0.0) - 0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-5);
        assert!((gelu(3.0) - 2.996363).abs() < 1e-4);
    }

    #[test]
    fn gelu_monotone_over_practical_range() {
        let mut prev = gelu(-6.0);
        let mut x = -6.0 + 0.05;
        // gelu is monotone on [-0.75..] and only ~1e-3 non-monotone dip
        // below; check global bounds instead of strict monotonicity.
        while x < 6.0 {
            let g = gelu(x);
            assert!(g >= -0.2 && g <= x.max(0.0) + 1e-3);
            prev = prev.min(g);
            x += 0.05;
        }
    }
}
