//! MoE FFN layers: ButterflyMoE (the paper), standard MoE and dense FFN
//! baselines.  All three share the trait [`MoeLayer`] so the coordinator,
//! examples and benches are generic over the expert parameterization.
//!
//! Forward semantics mirror `python/compile/model.py::moe_ffn_forward`
//! exactly (same gating, same GELU, same shared down projection) so the
//! native engine is numerically parity-testable against the AOT graphs.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::gating::GateNetwork;
use super::gelu;
use crate::artifact::ShTensor;
use crate::butterfly::Butterfly;
use crate::expertcache::{ExpertCacheConfig, ExpertResidencyCache};
use crate::kernels::{self, TernaryScratch};
use crate::obs::{self, trace::Stage};
use crate::parallel::{chunk_ranges, DisjointSliceMut, WorkerPool};
use crate::quant::{ternary_quantize, TernaryQuant};
use crate::tensor::store::TensorStore;
use crate::tensor::Tensor;
use crate::ternary::BitplaneTernary;
use crate::util::Rng;

/// Common interface over expert parameterizations.
pub trait MoeLayer: Send + Sync {
    fn d_model(&self) -> usize;
    fn d_ff(&self) -> usize;
    fn n_experts(&self) -> usize;

    /// Alg. 1: expert mixture only, x (t, d_model) -> h (t, d_ff).
    /// Returns per-expert load fractions alongside.
    fn experts_forward(&self, x: &[f32], t: usize, h: &mut [f32]) -> Vec<f64>;

    /// Full FFN block: experts -> GELU -> shared down projection.
    ///
    /// The down projection runs through the register-blocked micro-kernel
    /// tiles ([`crate::kernels`]) over row ranges — sequential uses one
    /// range, a [`worker_pool`](Self::worker_pool) shards `0..d_model`
    /// across tasks.  Every `y[i*d + r]` is computed by exactly one tile
    /// with the exact `dot_f32` association, so range boundaries (and
    /// therefore the worker count) never change a bit — no accumulation
    /// crosses a task boundary.  Row-sharding (over `d`, not tokens)
    /// keeps single-token decode steps parallel too.
    fn forward(&self, x: &[f32], t: usize, y: &mut [f32]) -> Vec<f64> {
        let (dff, d) = (self.d_ff(), self.d_model());
        let mut h = vec![0.0f32; t * dff];
        let loads = self.experts_forward(x, t, &mut h);
        for v in h.iter_mut() {
            *v = gelu(*v);
        }
        let wd = self.w_down();
        assert_eq!(y.len(), t * d);
        let _t = obs::stage_timer(Stage::DownProject, self.trace_layer());
        match self.worker_pool() {
            Some(pool) if pool.threads() > 1 => {
                let ranges = chunk_ranges(d, pool.threads() * 4);
                let ysh = DisjointSliceMut::new(y);
                let h = &h;
                pool.run(ranges.len(), &|w| {
                    let (lo, hi) = ranges[w];
                    down_project_rows(wd, h, t, d, dff, lo, hi, &ysh);
                });
            }
            _ => {
                let ysh = DisjointSliceMut::new(y);
                down_project_rows(wd, &h, t, d, dff, 0, d, &ysh);
            }
        }
        loads
    }

    /// Shared down projection, row-major `(d_model, d_ff)` data.  A
    /// slice (not a `Tensor`) so implementations may serve it from
    /// owned memory or borrowed from a model artifact's mapping
    /// ([`crate::artifact::ShTensor`]).
    fn w_down(&self) -> &[f32];

    /// Bytes of *expert-identity* storage — what Table 1 compares.
    /// (Shared substrate + per-expert params for ButterflyMoE; the N
    /// dense matrices for standard MoE.  Gate and shared down projection
    /// are excluded on both sides, as in the paper.)
    ///
    /// Residency-cache bytes are *working-set* bytes and are **not**
    /// counted here — attaching a cache never changes this accounting.
    fn expert_bytes(&self) -> usize;

    /// Expert-residency cache attached to this layer, if any — the
    /// serving engine loop drives its per-step `tick` and exposes its
    /// stats through this handle.
    fn expert_cache(&self) -> Option<&Arc<ExpertResidencyCache>> {
        None
    }

    /// Worker pool the hot path shards across, if any (`--workers`).
    /// `None` or a 1-thread pool is the sequential path; outputs are
    /// bit-identical either way (see [`crate::parallel`]).
    fn worker_pool(&self) -> Option<&Arc<WorkerPool>> {
        None
    }

    /// Index used as the `layer` label on sampled stage timings
    /// ([`crate::obs::trace`]); stacks set it at assembly, standalone
    /// layers report 0.
    fn trace_layer(&self) -> u32 {
        0
    }
}

/// Per-dispatch-block scratch: one expert's contiguous token block
/// (`xg`: gathered inputs, `hg`: that block's expert outputs) plus the
/// kernel scratch its synthesis task owns exclusively — the ternary
/// decode/quantize buffers ([`TernaryScratch`]) and the blocked
/// butterfly's transpose block (`bfly`).
///
/// This replaces the old single thread-local `(xg, hg)` pair: the
/// deterministic reduction needs every active expert's `hg` alive at
/// once (phase 2 below re-reads them in ascending expert order), so the
/// scratch is keyed by dispatch block — strictly finer than per-worker.
/// The blocks are retained in the layer across calls, so steady-state
/// decode does no allocation (including inside the kernels — the
/// `gemm_a8` `xq`/`scales`/sign buffers live here now, asserted by
/// `rust/tests/alloc_guard.rs`); they are *working-set* bytes, never
/// counted in `expert_bytes` (see `memmodel`).
#[derive(Default)]
struct DispatchBlock {
    xg: Vec<f32>,
    hg: Vec<f32>,
    kernel: TernaryScratch,
    bfly: Vec<f32>,
}

/// Down-projection rows `lo..hi` for all `t` tokens through the shared
/// register-blocked GEMM schedule ([`kernels::gemm_f32_sink`]):
/// `y[i*d + r] = dot_f32(w_down_r, h_i)`.
///
/// Each output carries the exact `dot_f32` association whichever tile
/// it landed in, so any `(lo, hi)` partition of `0..d` — including the
/// non-tile-aligned ranges `chunk_ranges` hands to worker tasks —
/// produces the same bits as one sequential pass (pinned by
/// `rust/tests/determinism.rs` and the kernel property tests).
#[allow(clippy::too_many_arguments)] // shape + row-window params of the sharded kernel
fn down_project_rows(
    wd: &[f32],
    h: &[f32],
    t: usize,
    d: usize,
    dff: usize,
    lo: usize,
    hi: usize,
    y: &DisjointSliceMut<f32>,
) {
    kernels::gemm_f32_sink(
        &wd[lo * dff..hi * dff],
        hi - lo,
        dff,
        h,
        t,
        1.0,
        lo,
        d,
        // SAFETY: row ranges are disjoint across tasks and the kernel
        // writes each (token, row) index exactly once, so every flat
        // index i*d + r (r in lo..hi) has exactly one writer.
        |i, v| unsafe { *y.index_mut(i) = v },
    );
}

/// Run `task(0..n)` on the pool, or inline when no pool is attached —
/// the claim order of the inline loop and a 1-thread pool are identical,
/// so "no pool", `--workers 1`, and `--workers N` all produce the same
/// bits.
fn run_on(pool: Option<&WorkerPool>, n: usize, task: &(dyn Fn(usize) + Sync)) {
    match pool {
        Some(p) => p.run(n, task),
        None => {
            for i in 0..n {
                task(i);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ButterflyMoE
// ---------------------------------------------------------------------------

/// One expert's orbit parameters (the substrate lives on the layer).
#[derive(Clone, Debug)]
pub struct OrbitExpert {
    pub theta: Butterfly, // over d_model, applied transposed on input
    pub phi: Butterfly,   // over d_ff, applied forward on output
}

pub struct ButterflyMoeLayer {
    pub gate: GateNetwork,
    /// Shared ternary substrate (d_ff, d_model), bitplane-packed.
    /// `Arc` so the residency cache can materialize decoded working sets
    /// without holding a self-reference into the layer.
    pub substrate: Arc<BitplaneTernary>,
    pub experts: Vec<OrbitExpert>,
    /// Shared down projection (d_model, d_ff); owned for in-memory
    /// layers, borrowed from the model mapping for artifact-loaded ones.
    pub w_down: ShTensor,
    /// Quantize activations to int8 in the substrate GEMM (W1.58A8, the
    /// deployment fast path — ~2x faster, ≲0.5% output error).
    /// Constructors default this to `false` so in-memory layers stay
    /// bit-parity-testable against the L2 graph and the exact-path
    /// determinism suite; **serving flips it to `true`** (the
    /// `NativeLmBackend::*_opts` stack assembly, opted out by
    /// `--exact`), gated by the fixture accuracy bound in
    /// `rust/tests/determinism.rs`.  With it set, forwards never
    /// consult the residency cache (see `experts_forward`).
    pub act_quant: bool,
    /// Optional residency cache of hot experts' decoded working sets
    /// (see [`crate::expertcache`]); `None` = pure sub-linear mode.
    cache: Option<Arc<ExpertResidencyCache>>,
    /// Optional worker pool the dispatch loop shards across
    /// (`--workers`); `None` = sequential.
    pool: Option<Arc<WorkerPool>>,
    /// Retained dispatch-block scratch (see [`DispatchBlock`]).  `try_lock`
    /// on the forward path: a second concurrent forward on the same
    /// layer falls back to a fresh local set instead of contending.
    scratch: Mutex<Vec<DispatchBlock>>,
    /// Test-only fault injection: the dispatch task for this expert
    /// panics (`"poisoned expert <e>"`) — exercises the pool's
    /// panic-propagation path from a real decode step.
    #[cfg(any(test, feature = "testutil"))]
    pub poison_expert: Option<usize>,
    /// `layer` label for sampled stage timings (set by stack assembly).
    trace_layer: u32,
    d_model: usize,
    d_ff: usize,
}

impl ButterflyMoeLayer {
    pub fn new(
        gate: GateNetwork,
        substrate: &TernaryQuant,
        experts: Vec<OrbitExpert>,
        w_down: Tensor,
    ) -> Self {
        Self::from_parts(
            gate,
            Arc::new(BitplaneTernary::from_quant(substrate)),
            experts,
            ShTensor::from_tensor(w_down),
        )
    }

    /// Assemble from already-built parts — the model-artifact loader's
    /// constructor (`crate::artifact::ModelArtifact::build_layers`),
    /// where the substrate planes, angle tables and `w_down` may all be
    /// borrowed from the file mapping.  Same validation as [`Self::new`].
    pub fn from_parts(
        gate: GateNetwork,
        substrate: Arc<BitplaneTernary>,
        experts: Vec<OrbitExpert>,
        w_down: ShTensor,
    ) -> Self {
        let (d_ff, d_model) = (substrate.rows, substrate.cols);
        assert_eq!(gate.d_model(), d_model);
        assert_eq!(gate.n_experts(), experts.len());
        for ex in &experts {
            assert_eq!(ex.theta.d, d_model);
            assert_eq!(ex.phi.d, d_ff);
        }
        assert_eq!(w_down.shape, vec![d_model, d_ff]);
        ButterflyMoeLayer {
            gate,
            substrate,
            experts,
            w_down,
            act_quant: false,
            cache: None,
            pool: None,
            scratch: Mutex::new(Vec::new()),
            #[cfg(any(test, feature = "testutil"))]
            poison_expert: None,
            trace_layer: 0,
            d_model,
            d_ff,
        }
    }

    /// Set the `layer` label sampled stage timings report for this
    /// layer (the stack assemblers call this with the block index).
    pub fn set_trace_layer(&mut self, layer: u32) {
        self.trace_layer = layer;
    }

    /// Row-major `(d_model, d_ff)` down-projection data (what the model
    /// packer serializes).
    pub fn w_down_data(&self) -> &[f32] {
        self.w_down.data()
    }

    /// Attach a worker pool: `experts_forward` shards its dispatch
    /// blocks and `forward` row-shards the down projection across it.
    /// Outputs stay bit-identical to the sequential path for any pool
    /// size (see [`crate::parallel`] for the sharding contract).
    pub fn attach_worker_pool(&mut self, pool: Arc<WorkerPool>) {
        self.pool = Some(pool);
    }

    /// Attach a byte-budgeted expert-residency cache (replacing any
    /// previous one, with fresh stats).  Returns the shared handle the
    /// engine loop uses for per-step `tick()`, warmup `prewarm()` and
    /// stats.  The cache accelerates the exact (f32) substrate path
    /// only; with `act_quant` set, forwards keep the synthesis path.
    pub fn attach_expert_cache(&mut self, cfg: ExpertCacheConfig) -> Arc<ExpertResidencyCache> {
        let cache = Arc::new(ExpertResidencyCache::new(
            cfg,
            self.substrate.clone(),
            self.experts.len(),
        ));
        self.cache = Some(cache.clone());
        cache
    }

    /// Random init mirroring `model.py::init_ffn_params`.
    pub fn random(
        d_model: usize,
        d_ff: usize,
        n_experts: usize,
        top_k: usize,
        depth: Option<usize>,
        rng: &mut Rng,
    ) -> Self {
        let scale = 1.0 / (d_model as f32).sqrt();
        let gate = GateNetwork::new(Tensor::rand_normal(&[n_experts, d_model], scale, rng), top_k);
        let w_base = Tensor::rand_normal(&[d_ff, d_model], scale, rng);
        let tq = ternary_quantize(&w_base);
        let din = depth.unwrap_or(Butterfly::max_depth(d_model));
        let dout = depth.unwrap_or(Butterfly::max_depth(d_ff));
        let experts = (0..n_experts)
            .map(|i| OrbitExpert {
                theta: Butterfly::random(d_model, din, 0.01, &mut rng.fork(i as u64 * 2)),
                phi: Butterfly::random(d_ff, dout, 0.01, &mut rng.fork(i as u64 * 2 + 1)),
            })
            .collect();
        let w_down = Tensor::rand_normal(&[d_model, d_ff], 1.0 / (d_ff as f32).sqrt(), rng);
        Self::new(gate, &tq, experts, w_down)
    }

    /// Load from a TensorStore with the aot.py `ffn.` naming scheme
    /// (`ffn.gate`, `ffn.w_base`, `ffn.theta` (E, depth, d/2), `ffn.phi`,
    /// `ffn.w_down`).
    pub fn from_store(store: &TensorStore, prefix: &str, top_k: usize) -> Result<Self> {
        let get = |name: &str| store.get_f32(&format!("{prefix}{name}"));
        let gate_w = get("gate")?.clone();
        let w_base = get("w_base")?;
        let theta = get("theta")?;
        let phi = get("phi")?;
        let w_down = get("w_down")?.clone();
        let (d_ff, d_model) = (w_base.shape[0], w_base.shape[1]);
        let e = theta.shape[0];
        let (depth_in, half_in) = (theta.shape[1], theta.shape[2]);
        let (depth_out, half_out) = (phi.shape[1], phi.shape[2]);
        anyhow::ensure!(half_in == d_model / 2 && half_out == d_ff / 2, "angle shape");
        let tq = ternary_quantize(w_base);
        let experts = (0..e)
            .map(|i| {
                let tslice = &theta.data[i * depth_in * half_in..(i + 1) * depth_in * half_in];
                let pslice = &phi.data[i * depth_out * half_out..(i + 1) * depth_out * half_out];
                OrbitExpert {
                    theta: Butterfly::from_angles(d_model, depth_in, tslice),
                    phi: Butterfly::from_angles(d_ff, depth_out, pslice),
                }
            })
            .collect();
        Ok(Self::new(
            GateNetwork::new(gate_w, top_k),
            &tq,
            experts,
            w_down,
        ))
    }

    /// Single-expert orbit forward (eq. 2) with caller scratch:
    /// out = B(phi)( Q(W) ( B(theta)^T x ) ).
    pub fn expert_forward(&self, e: usize, x: &[f32], scratch: &mut [f32], out: &mut [f32]) {
        debug_assert_eq!(x.len(), self.d_model);
        debug_assert_eq!(scratch.len(), self.d_model);
        debug_assert_eq!(out.len(), self.d_ff);
        let ex = &self.experts[e];
        scratch.copy_from_slice(x);
        ex.theta.apply_transpose(scratch);
        self.substrate.gemv(scratch, out);
        ex.phi.apply(out);
    }
}

impl MoeLayer for ButterflyMoeLayer {
    fn d_model(&self) -> usize {
        self.d_model
    }
    fn d_ff(&self) -> usize {
        self.d_ff
    }
    fn n_experts(&self) -> usize {
        self.experts.len()
    }
    fn w_down(&self) -> &[f32] {
        self.w_down.data()
    }

    /// Expert-major batched dispatch (§Perf iteration 3), sharded across
    /// the attached worker pool in two phases:
    ///
    /// 1. **Synthesis** (parallel over dispatch blocks): gather each
    ///    active expert's tokens contiguously, rotate the whole block,
    ///    run ONE substrate GEMM (weights decoded once per expert, not
    ///    once per token — or the cache's decoded fast path), rotate
    ///    back.  Each task owns its [`DispatchBlock`] exclusively.
    /// 2. **Reduction** (parallel over token-row ranges): the weighted
    ///    scatter into `h`.
    ///
    /// # Determinism invariant (documented + asserted)
    ///
    /// *Within* one expert the scattered token rows are disjoint, but
    /// *across* experts they collide whenever top-k ≥ 2 routes two
    /// experts to the same token — so float accumulation order into a
    /// token's row matters.  The reduction therefore shards by **token
    /// row** (disjoint ranges, `chunk_ranges` asserts exact cover) and,
    /// inside each row, accumulates experts in **ascending expert
    /// order** — the exact association of the sequential loop.  Output
    /// is bit-identical for any worker count; `rust/tests/determinism.rs`
    /// pins this.
    fn experts_forward(&self, x: &[f32], t: usize, h: &mut [f32]) -> Vec<f64> {
        let (d, dff) = (self.d_model, self.d_ff);
        assert_eq!(x.len(), t * d);
        assert_eq!(h.len(), t * dff);
        h.fill(0.0);
        let (routes, loads) = self.gate.route_batch(x, t);
        let dispatch = GateNetwork::dispatch(&routes, self.n_experts());
        // The cache serves the exact (f32) substrate path only; W1.58A8
        // activation quantization keeps the synthesis path.
        let cache = if self.act_quant {
            None
        } else {
            self.cache.as_deref()
        };
        if let Some(c) = cache {
            c.observe(&loads);
        }
        // Active dispatch blocks, ascending expert index (the reduction
        // below relies on this order).
        let active: Vec<(usize, &[(usize, f32)])> = dispatch
            .iter()
            .enumerate()
            .filter(|(_, toks)| !toks.is_empty())
            .map(|(e, toks)| (e, toks.as_slice()))
            .collect();
        let mut local_blocks = Vec::new();
        // Scratch contents are rewritten every call, so a poisoned mutex
        // (a panicking expert unwound through a prior forward) is safe
        // to clear; only contention falls back to a fresh local set.
        let mut guard = match self.scratch.try_lock() {
            Ok(g) => Some(g),
            Err(std::sync::TryLockError::Poisoned(p)) => Some(p.into_inner()),
            Err(std::sync::TryLockError::WouldBlock) => None,
        };
        let blocks: &mut Vec<DispatchBlock> = match guard.as_deref_mut() {
            Some(b) => b,
            None => &mut local_blocks,
        };
        if blocks.len() < active.len() {
            blocks.resize_with(active.len(), DispatchBlock::default);
        }
        let blocks = &mut blocks[..active.len()];
        let pool = self.pool.as_deref();

        // Phase 1 — synthesis, one task per dispatch block.
        {
            let shards = DisjointSliceMut::new(&mut *blocks);
            let synth = |i: usize| {
                let (e, toks) = active[i];
                // SAFETY: task i is the only writer of block i.
                let block = unsafe { shards.index_mut(i) };
                #[cfg(any(test, feature = "testutil"))]
                if self.poison_expert == Some(e) {
                    panic!("poisoned expert {e}");
                }
                let ex = &self.experts[e];
                let n = toks.len();
                {
                    let _t = obs::stage_timer(Stage::Gather, self.trace_layer);
                    block.xg.clear();
                    block.xg.reserve(n * d);
                    for &(ti, _) in toks {
                        block.xg.extend_from_slice(&x[ti * d..(ti + 1) * d]);
                    }
                }
                {
                    let _t = obs::stage_timer(Stage::Rotate, self.trace_layer);
                    ex.theta.apply_transpose_batch_with(&mut block.xg, &mut block.bfly);
                }
                block.hg.resize(n * dff, 0.0);
                // Fast path: a resident expert is served from its decoded
                // working set — bit-identical arithmetic to the synthesis
                // path below (both route through the same micro-kernel,
                // see `kernels`), with the bitplane decode hoisted out
                // (see `expertcache` module docs for why this form and
                // not the fully folded dense matrix).  The `_with`
                // variants reuse this block's retained kernel scratch:
                // steady-state decode allocates nothing.
                match cache.and_then(|c| c.lookup(e)) {
                    Some(dec) => {
                        let _t = obs::stage_timer(Stage::CachedGemm, self.trace_layer);
                        dec.gemm(&block.xg, n, &mut block.hg)
                    }
                    None if self.act_quant => {
                        let _t = obs::stage_timer(Stage::TernaryGemm, self.trace_layer);
                        self.substrate
                            .gemm_a8_with(&block.xg, n, &mut block.hg, &mut block.kernel)
                    }
                    None => {
                        let _t = obs::stage_timer(Stage::TernaryGemm, self.trace_layer);
                        self.substrate
                            .gemm_with(&block.xg, n, &mut block.hg, &mut block.kernel)
                    }
                }
                {
                    let _t = obs::stage_timer(Stage::Rotate, self.trace_layer);
                    ex.phi.apply_batch_with(&mut block.hg, &mut block.bfly);
                }
            };
            run_on(pool, active.len(), &synth);
        }

        // Phase 2 — deterministic reduction: token-row ranges partition
        // 0..t disjointly; per row, experts accumulate in ascending
        // order exactly as the sequential loop did.
        let blocks: &[DispatchBlock] = blocks;
        let parts = pool.map_or(1, WorkerPool::threads);
        let ranges = chunk_ranges(t, parts);
        {
            let hsh = DisjointSliceMut::new(h);
            let scatter = |w: usize| {
                let (lo, hi) = ranges[w];
                for (block, &(_e, toks)) in blocks.iter().zip(&active) {
                    for (row, &(ti, wt)) in toks.iter().enumerate() {
                        if ti < lo || ti >= hi {
                            continue;
                        }
                        let src = &block.hg[row * dff..(row + 1) * dff];
                        // SAFETY: token ranges are disjoint across tasks.
                        let dst = unsafe { hsh.slice_mut(ti * dff, dff) };
                        for (hv, &ov) in dst.iter_mut().zip(src) {
                            *hv += wt * ov;
                        }
                    }
                }
            };
            let _t = obs::stage_timer(Stage::Reduce, self.trace_layer);
            run_on(pool, ranges.len(), &scatter);
        }
        loads
    }

    fn expert_bytes(&self) -> usize {
        // Paper accounting (Prop. 1): ternary substrate at 1.58 bits +
        // FP16 angles.  ceil at byte granularity.
        let substrate = (self.d_ff * self.d_model) as f64 * 1.58 / 8.0;
        let angles: usize = self
            .experts
            .iter()
            .map(|e| e.theta.bytes_fp16() + e.phi.bytes_fp16())
            .sum();
        substrate.ceil() as usize + angles
    }

    fn expert_cache(&self) -> Option<&Arc<ExpertResidencyCache>> {
        self.cache.as_ref()
    }

    fn worker_pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    fn trace_layer(&self) -> u32 {
        self.trace_layer
    }
}

// ---------------------------------------------------------------------------
// Standard MoE baseline
// ---------------------------------------------------------------------------

pub struct StandardMoeLayer {
    pub gate: GateNetwork,
    /// n_experts dense matrices (d_ff, d_model), f32
    pub w_up: Vec<Tensor>,
    pub w_down: Tensor,
    d_model: usize,
    d_ff: usize,
}

impl StandardMoeLayer {
    pub fn new(gate: GateNetwork, w_up: Vec<Tensor>, w_down: Tensor) -> Self {
        let (d_ff, d_model) = (w_up[0].shape[0], w_up[0].shape[1]);
        assert_eq!(gate.d_model(), d_model);
        assert_eq!(gate.n_experts(), w_up.len());
        StandardMoeLayer {
            gate,
            w_up,
            w_down,
            d_model,
            d_ff,
        }
    }

    pub fn random(
        d_model: usize,
        d_ff: usize,
        n_experts: usize,
        top_k: usize,
        rng: &mut Rng,
    ) -> Self {
        let scale = 1.0 / (d_model as f32).sqrt();
        let gate = GateNetwork::new(Tensor::rand_normal(&[n_experts, d_model], scale, rng), top_k);
        let w_up = (0..n_experts)
            .map(|_| Tensor::rand_normal(&[d_ff, d_model], scale, rng))
            .collect();
        let w_down = Tensor::rand_normal(&[d_model, d_ff], 1.0 / (d_ff as f32).sqrt(), rng);
        Self::new(gate, w_up, w_down)
    }
}

impl MoeLayer for StandardMoeLayer {
    fn d_model(&self) -> usize {
        self.d_model
    }
    fn d_ff(&self) -> usize {
        self.d_ff
    }
    fn n_experts(&self) -> usize {
        self.w_up.len()
    }
    fn w_down(&self) -> &[f32] {
        &self.w_down.data
    }

    fn experts_forward(&self, x: &[f32], t: usize, h: &mut [f32]) -> Vec<f64> {
        let (d, dff) = (self.d_model, self.d_ff);
        h.fill(0.0);
        let (routes, loads) = self.gate.route_batch(x, t);
        let dispatch = GateNetwork::dispatch(&routes, self.n_experts());
        for (e, toks) in dispatch.iter().enumerate() {
            let w = &self.w_up[e];
            for &(ti, wt) in toks {
                let xi = &x[ti * d..(ti + 1) * d];
                let hrow = &mut h[ti * dff..(ti + 1) * dff];
                for r in 0..dff {
                    hrow[r] += wt * crate::util::dot_f32(w.row(r), xi);
                }
            }
        }
        loads
    }

    fn expert_bytes(&self) -> usize {
        self.w_up.iter().map(Tensor::nbytes).sum()
    }
}

// ---------------------------------------------------------------------------
// Dense FFN baseline
// ---------------------------------------------------------------------------

pub struct DenseFfn {
    pub w_up: Tensor,
    pub w_down_t: Tensor,
}

impl DenseFfn {
    pub fn random(d_model: usize, d_ff: usize, rng: &mut Rng) -> Self {
        DenseFfn {
            w_up: Tensor::rand_normal(&[d_ff, d_model], 1.0 / (d_model as f32).sqrt(), rng),
            w_down_t: Tensor::rand_normal(&[d_model, d_ff], 1.0 / (d_ff as f32).sqrt(), rng),
        }
    }
}

impl MoeLayer for DenseFfn {
    fn d_model(&self) -> usize {
        self.w_up.shape[1]
    }
    fn d_ff(&self) -> usize {
        self.w_up.shape[0]
    }
    fn n_experts(&self) -> usize {
        1
    }
    fn w_down(&self) -> &[f32] {
        &self.w_down_t.data
    }

    fn experts_forward(&self, x: &[f32], t: usize, h: &mut [f32]) -> Vec<f64> {
        let (d, dff) = (self.d_model(), self.d_ff());
        for i in 0..t {
            let xi = &x[i * d..(i + 1) * d];
            let hrow = &mut h[i * dff..(i + 1) * dff];
            for r in 0..dff {
                hrow[r] = crate::util::dot_f32(self.w_up.row(r), xi);
            }
        }
        vec![1.0]
    }

    fn expert_bytes(&self) -> usize {
        self.w_up.nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn layer(seed: u64) -> ButterflyMoeLayer {
        testutil::butterfly_layer(16, 32, 4, 2, seed)
    }

    #[test]
    fn shapes_and_counts() {
        let l = layer(1);
        assert_eq!(l.d_model(), 16);
        assert_eq!(l.d_ff(), 32);
        assert_eq!(l.n_experts(), 4);
    }

    #[test]
    fn forward_shapes_and_finite() {
        let l = layer(2);
        let mut rng = Rng::new(3);
        let t = 5;
        let x: Vec<f32> = (0..t * 16).map(|_| rng.normal_f32(1.0)).collect();
        let mut y = vec![0.0f32; t * 16];
        let loads = l.forward(&x, t, &mut y);
        assert!((loads.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(y.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn experts_forward_is_convex_mix_of_expert_outputs() {
        let l = layer(4);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(1.0)).collect();
        let mut h = vec![0.0f32; 32];
        l.experts_forward(&x, 1, &mut h);
        // manual recomputation from the route
        let r = l.gate.route(&x);
        let mut want = vec![0.0f32; 32];
        let mut scratch = vec![0.0f32; 16];
        let mut out = vec![0.0f32; 32];
        for &(e, w) in &r.experts {
            l.expert_forward(e, &x, &mut scratch, &mut out);
            for (wv, &ov) in want.iter_mut().zip(&out) {
                *wv += w * ov;
            }
        }
        for (a, b) in h.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn experts_produce_distinct_outputs() {
        let l = layer(6);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32(1.0)).collect();
        let mut scratch = vec![0.0f32; 16];
        let mut y0 = vec![0.0f32; 32];
        let mut y1 = vec![0.0f32; 32];
        l.expert_forward(0, &x, &mut scratch, &mut y0);
        l.expert_forward(1, &x, &mut scratch, &mut y1);
        let diff: f32 = y0.iter().zip(&y1).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-4);
    }

    #[test]
    fn identity_rotations_reduce_to_substrate_gemv() {
        let mut rng = Rng::new(8);
        let mut l = ButterflyMoeLayer::random(8, 16, 2, 1, None, &mut rng);
        for e in l.experts.iter_mut() {
            e.theta = Butterfly::identity(8, 3);
            e.phi = Butterfly::identity(16, 4);
        }
        let x: Vec<f32> = (0..8).map(|_| rng.normal_f32(1.0)).collect();
        let mut scratch = vec![0.0f32; 8];
        let mut out = vec![0.0f32; 16];
        l.expert_forward(0, &x, &mut scratch, &mut out);
        let mut want = vec![0.0f32; 16];
        l.substrate.gemv(&x, &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn butterfly_expert_bytes_sublinear_vs_standard() {
        // d=512, d_ff=2048, 64 experts: paper's Table 1 comparison.
        let mut rng = Rng::new(9);
        // construct tiny then scale-check the formulas via a small layer
        let b = ButterflyMoeLayer::random(64, 128, 4, 2, None, &mut rng);
        let s = StandardMoeLayer::random(64, 128, 4, 2, &mut rng);
        assert!(b.expert_bytes() < s.expert_bytes() / 10);
    }

    #[test]
    fn standard_moe_forward_runs() {
        let mut rng = Rng::new(10);
        let l = StandardMoeLayer::random(16, 32, 4, 2, &mut rng);
        let x: Vec<f32> = (0..3 * 16).map(|_| rng.normal_f32(1.0)).collect();
        let mut y = vec![0.0f32; 3 * 16];
        let loads = l.forward(&x, 3, &mut y);
        assert!((loads.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn dense_ffn_forward_runs() {
        let mut rng = Rng::new(11);
        let l = DenseFfn::random(16, 32, &mut rng);
        let x: Vec<f32> = (0..2 * 16).map(|_| rng.normal_f32(1.0)).collect();
        let mut y = vec![0.0f32; 2 * 16];
        l.forward(&x, 2, &mut y);
        assert!(y.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn cached_forward_bit_identical_to_synthesis() {
        let plain = layer(20);
        let mut cached = layer(20); // identical weights (same seed)
        let cache = cached.attach_expert_cache(ExpertCacheConfig::with_budget_bytes(
            4 * crate::expertcache::decoded_expert_bytes(32, 16),
        ));
        cache.prewarm(); // budget holds all 4 experts: every route hits
        let mut rng = Rng::new(21);
        for t in [1usize, 3, 7] {
            let x: Vec<f32> = (0..t * 16).map(|_| rng.normal_f32(1.0)).collect();
            let mut ha = vec![0.0f32; t * 32];
            let mut hb = vec![0.0f32; t * 32];
            let la = plain.experts_forward(&x, t, &mut ha);
            let lb = cached.experts_forward(&x, t, &mut hb);
            assert_eq!(ha, hb, "cached path must be bit-identical (t={t})");
            assert_eq!(la, lb);
            cache.tick();
        }
        let s = cache.snapshot();
        assert!(s.hits > 0, "prewarmed experts must serve hits");
        assert!(s.resident_bytes <= s.budget_bytes);
    }

    #[test]
    fn parallel_experts_forward_bit_identical_for_any_worker_count() {
        // larger shape so several experts and tokens are active at once
        let sequential = testutil::butterfly_layer(32, 64, 8, 2, 40);
        let x = testutil::normal_vec(9 * 32, 41);
        let mut want = vec![0.0f32; 9 * 64];
        let want_loads = sequential.experts_forward(&x, 9, &mut want);
        for workers in [1usize, 2, 3, 8] {
            let mut l = testutil::butterfly_layer(32, 64, 8, 2, 40);
            l.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
            let mut h = vec![0.0f32; 9 * 64];
            let loads = l.experts_forward(&x, 9, &mut h);
            assert_eq!(h, want, "workers={workers}: not bit-identical");
            assert_eq!(loads, want_loads, "workers={workers}: loads differ");
        }
    }

    #[test]
    fn parallel_full_forward_bit_identical_down_projection_included() {
        let sequential = testutil::butterfly_layer(32, 64, 8, 2, 42);
        let x = testutil::normal_vec(5 * 32, 43);
        let mut want = vec![0.0f32; 5 * 32];
        sequential.forward(&x, 5, &mut want);
        for workers in [1usize, 4] {
            let mut l = testutil::butterfly_layer(32, 64, 8, 2, 42);
            l.attach_worker_pool(Arc::new(WorkerPool::new(workers)));
            let mut y = vec![0.0f32; 5 * 32];
            l.forward(&x, 5, &mut y);
            assert_eq!(y, want, "workers={workers}");
        }
    }

    #[test]
    fn parallel_cached_forward_bit_identical_too() {
        let plain = layer(30);
        let mut cached = layer(30);
        cached.attach_worker_pool(Arc::new(WorkerPool::new(4)));
        let cache = cached.attach_expert_cache(ExpertCacheConfig::with_budget_bytes(
            4 * crate::expertcache::decoded_expert_bytes(32, 16),
        ));
        cache.prewarm();
        let x = testutil::normal_vec(6 * 16, 31);
        let mut ha = vec![0.0f32; 6 * 32];
        let mut hb = vec![0.0f32; 6 * 32];
        plain.experts_forward(&x, 6, &mut ha);
        cached.experts_forward(&x, 6, &mut hb);
        assert_eq!(ha, hb, "parallel + cached must still be bit-identical");
        assert!(cache.snapshot().hits > 0);
    }

    #[test]
    fn poisoned_expert_fails_forward_with_payload_pool_survives() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let pool = Arc::new(WorkerPool::new(4));
        let mut l = testutil::butterfly_layer(32, 64, 8, 2, 50);
        l.attach_worker_pool(pool.clone());
        let x = testutil::normal_vec(4 * 32, 51);
        // poison an expert that this batch actually routes to
        let loads = {
            let mut h = vec![0.0f32; 4 * 64];
            l.experts_forward(&x, 4, &mut h)
        };
        let hot = loads.iter().position(|&v| v > 0.0).unwrap();
        l.poison_expert = Some(hot);
        let err = catch_unwind(AssertUnwindSafe(|| {
            let mut h = vec![0.0f32; 4 * 64];
            l.experts_forward(&x, 4, &mut h);
        }))
        .expect_err("poisoned expert must fail the step");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("poisoned expert"), "payload: {msg}");
        // the condvar protocol recovered: same pool serves the next step
        l.poison_expert = None;
        let mut h = vec![0.0f32; 4 * 64];
        l.experts_forward(&x, 4, &mut h);
        assert!(h.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn depth_truncation_changes_params_not_shapes() {
        let mut rng = Rng::new(12);
        let l2 = ButterflyMoeLayer::random(64, 128, 2, 1, Some(2), &mut rng);
        let l6 = ButterflyMoeLayer::random(64, 128, 2, 1, Some(6), &mut rng);
        assert!(l2.expert_bytes() < l6.expert_bytes());
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32(1.0)).collect();
        let mut y = vec![0.0f32; 64];
        l2.forward(&x, 1, &mut y);
        l6.forward(&x, 1, &mut y);
    }
}
