//! Training driver: runs the AOT-compiled train-step artifact in a loop,
//! owns the LR schedule, logs the loss curve, writes checkpoints.
//!
//! All compute (fwd + bwd + AdamW) is inside one compiled HLO module; the
//! driver shuttles the parameter tuple between steps.  (The published
//! `xla` crate cannot split an on-device tuple buffer into per-tensor
//! buffers, so state makes a host round-trip per step — measured and
//! acceptable at these model sizes, see EXPERIMENTS.md §Perf.)

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::RuntimeConfig;
use crate::data::{Batcher, CorpusConfig, SyntheticCorpus};
use crate::runtime::{Engine, Value};
use crate::tensor::store::{Entry, TensorStore};
use crate::util::Stopwatch;

/// Per-step record for the loss curve.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub ce: f32,
    pub balance: f32,
    pub step_secs: f64,
}

pub struct TrainReport {
    pub config: String,
    pub logs: Vec<StepLog>,
    pub final_params: Vec<Value>,
    pub param_names: Vec<String>,
    pub total_secs: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.logs.last().map(|l| l.loss).unwrap_or(f32::NAN)
    }

    /// Mean CE over the last `n` steps (smoother than the last point).
    pub fn tail_ce(&self, n: usize) -> f32 {
        let tail = &self.logs[self.logs.len().saturating_sub(n)..];
        tail.iter().map(|l| l.ce).sum::<f32>() / tail.len().max(1) as f32
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,ce,balance,step_secs")?;
        for l in &self.logs {
            writeln!(
                f,
                "{},{},{},{},{:.6}",
                l.step, l.loss, l.ce, l.balance, l.step_secs
            )?;
        }
        Ok(())
    }

    /// Save final params as a BMOE checkpoint readable by both sides.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut store = TensorStore::default();
        for (name, v) in self.param_names.iter().zip(&self.final_params) {
            match v {
                Value::F32(t) => store.insert(name, Entry::F32(t.clone())),
                Value::I32(t) => store.insert(name, Entry::I32(t.clone())),
            }
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        store.write(path)
    }
}

/// Linear-warmup constant LR schedule.
pub fn lr_at(step: usize, cfg: &RuntimeConfig) -> f32 {
    let lr = cfg.lr as f32;
    if step < cfg.warmup_steps {
        lr * (step + 1) as f32 / cfg.warmup_steps as f32
    } else {
        lr
    }
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub rt: RuntimeConfig,
    /// progress callback every `log_every` steps
    pub log_every: usize,
    pub quiet: bool,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, rt: RuntimeConfig) -> Self {
        Trainer {
            engine,
            rt,
            log_every: 20,
            quiet: false,
        }
    }

    /// Train `config` from its exported init params (or a checkpoint).
    pub fn run(&self, config: &str, init_from: Option<&Path>) -> Result<TrainReport> {
        let art_name = format!("{config}__train_step");
        let spec = self.engine.manifest.artifact(&art_name)?.clone();
        let mcfg = self.engine.manifest.config(config)?.clone();
        let p = spec.train_param_count();

        // batch shape from the artifact's `tokens` input
        let tok_spec = &spec.inputs[3 * p + 2];
        let (batch, seq_len) = (tok_spec.shape[0], tok_spec.shape[1]);

        let param_names: Vec<String> = self
            .engine
            .manifest
            .params
            .get(config)
            .map(|ps| ps.names.clone())
            .unwrap_or_else(|| (0..p).map(|i| format!("param.{i}")).collect());

        let mut params = match init_from {
            None => self.engine.load_params(config)?,
            Some(ckpt) => load_checkpoint_values(ckpt, &param_names)?,
        };
        anyhow::ensure!(params.len() == p, "param count mismatch");
        let mut m = Engine::zeros_like(&params);
        let mut v = Engine::zeros_like(&params);
        let mut step_v = Value::scalar_i32(0);

        let corpus = SyntheticCorpus::new(CorpusConfig {
            vocab: mcfg.vocab,
            seed: self.rt.seed,
            ..CorpusConfig::default()
        });
        let mut batcher = Batcher::new(corpus, batch, seq_len);

        let total_sw = Stopwatch::start();
        let mut logs = Vec::with_capacity(self.rt.steps);
        for step in 0..self.rt.steps {
            let sw = Stopwatch::start();
            let (toks, tgts) = batcher.next_batch();
            let mut inputs = Vec::with_capacity(3 * p + 4);
            inputs.extend(params.drain(..));
            inputs.extend(m.drain(..));
            inputs.extend(v.drain(..));
            inputs.push(step_v.clone());
            inputs.push(Value::scalar_f32(lr_at(step, &self.rt)));
            inputs.push(Value::I32(toks));
            inputs.push(Value::I32(tgts));

            let mut out = self.engine.run(&art_name, &inputs)?;
            // outputs: [P params, P m, P v, step, loss, ce, bal, load]
            let rest = out.split_off(3 * p);
            params = out.drain(..p).collect();
            m = out.drain(..p).collect();
            v = out;
            step_v = rest[0].clone();
            let loss = rest[1].as_f32()?.data[0];
            let ce = rest[2].as_f32()?.data[0];
            let bal = rest[3].as_f32()?.data[0];
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");

            logs.push(StepLog {
                step,
                loss,
                ce,
                balance: bal,
                step_secs: sw.secs(),
            });
            if !self.quiet && (step % self.log_every == 0 || step + 1 == self.rt.steps) {
                crate::obs::log(
                    &format!("train {config}"),
                    &format!(
                        "step {step:>5} loss {loss:.4} ce {ce:.4} bal {bal:.5} ({:.0} ms)",
                        sw.millis()
                    ),
                );
            }
            if self.rt.checkpoint_every > 0
                && step > 0
                && step % self.rt.checkpoint_every == 0
            {
                let report = TrainReport {
                    config: config.to_string(),
                    logs: logs.clone(),
                    final_params: params.clone(),
                    param_names: param_names.clone(),
                    total_secs: total_sw.secs(),
                };
                report.save_checkpoint(&self.ckpt_path(config, step))?;
            }
        }
        Ok(TrainReport {
            config: config.to_string(),
            logs,
            final_params: params,
            param_names,
            total_secs: total_sw.secs(),
        })
    }

    pub fn ckpt_path(&self, config: &str, step: usize) -> PathBuf {
        Path::new(&self.rt.out_dir).join(format!("{config}_step{step}.bmoe"))
    }

    /// Evaluate CE with the eval artifact on `n_batches` held-out batches.
    pub fn eval(&self, config: &str, params: &[Value], n_batches: usize) -> Result<f32> {
        let art = format!("{config}__eval");
        let spec = self.engine.manifest.artifact(&art)?.clone();
        let mcfg = self.engine.manifest.config(config)?.clone();
        let p = spec.inputs.len() - 2;
        anyhow::ensure!(params.len() == p, "eval param count");
        let tok_spec = &spec.inputs[p];
        let corpus = SyntheticCorpus::new(CorpusConfig {
            vocab: mcfg.vocab,
            seed: self.rt.seed + 0xEE,
            ..CorpusConfig::default()
        });
        let mut batcher = Batcher::new(corpus, tok_spec.shape[0], tok_spec.shape[1]);
        let mut total = 0.0f32;
        for _ in 0..n_batches {
            let (toks, tgts) = batcher.next_batch();
            let mut inputs: Vec<Value> = params.to_vec();
            inputs.push(Value::I32(toks));
            inputs.push(Value::I32(tgts));
            let out = self.engine.run(&art, &inputs)?;
            total += out[0].as_f32()?.data[0];
        }
        Ok(total / n_batches as f32)
    }
}

/// Get-or-train a checkpoint for `config` at `steps` steps, cached under
/// `dir` — shared by the Fig. 4 / Fig. 5 benches so repeated runs are
/// instant.  Returns the checkpoint path.
pub fn ensure_checkpoint(
    engine: &Engine,
    config: &str,
    steps: usize,
    dir: &Path,
) -> Result<PathBuf> {
    let path = dir.join(format!("{config}_s{steps}.bmoe"));
    if path.exists() {
        return Ok(path);
    }
    crate::obs::log(
        "ensure_checkpoint",
        &format!("training {config} for {steps} steps (cached at {})", path.display()),
    );
    let rt = RuntimeConfig {
        steps,
        lr: 3e-3,
        warmup_steps: (steps / 10).max(1),
        checkpoint_every: 0,
        out_dir: dir.to_string_lossy().into_owned(),
        ..Default::default()
    };
    let mut trainer = Trainer::new(engine, rt);
    trainer.quiet = true;
    let report = trainer.run(config, None)?;
    report.save_checkpoint(&path)?;
    report.write_csv(&dir.join(format!("{config}_s{steps}_loss.csv")))?;
    Ok(path)
}

/// Load checkpoint values in a given name order.
pub fn load_checkpoint_values(path: &Path, names: &[String]) -> Result<Vec<Value>> {
    let store = TensorStore::read(path)?;
    names
        .iter()
        .map(|n| {
            let e = store
                .get(n)
                .with_context(|| format!("checkpoint missing '{n}'"))?;
            match e {
                Entry::F32(t) => Ok(Value::F32(t.clone())),
                Entry::I32(t) => Ok(Value::I32(t.clone())),
                Entry::U8 { .. } => anyhow::bail!("unexpected u8 tensor"),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_warms_up() {
        let rt = RuntimeConfig {
            lr: 1.0,
            warmup_steps: 10,
            ..Default::default()
        };
        assert!((lr_at(0, &rt) - 0.1).abs() < 1e-6);
        assert!((lr_at(4, &rt) - 0.5).abs() < 1e-6);
        assert!((lr_at(10, &rt) - 1.0).abs() < 1e-6);
        assert!((lr_at(500, &rt) - 1.0).abs() < 1e-6);
    }
}
