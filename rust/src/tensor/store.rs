//! BMOE tensor container — Rust side of the spec in
//! `python/compile/bmoe_io.py` (little-endian; normative byte layout
//! also in DESIGN.md §3, kept in sync with that docstring).
//!
//! Reads initial params exported by `aot.py`; writes checkpoints from the
//! training driver so Python tooling can inspect them symmetrically.
//! The cross-language byte format is pinned by `golden_bytes_exact`
//! below (a python-written fixture embedded verbatim) so neither writer
//! can silently drift.
//!
//! Audit notes (spec vs both implementations):
//! * dtype codes, dim widths, endianness and field order agree exactly;
//!   the golden fixture proves byte-for-byte write parity.
//! * rank-0 tensors: both readers accept `ndim = 0` (1 element), and the
//!   Rust writer emits it; numpy's `ascontiguousarray` promotes 0-d to
//!   1-d, so the python *writer* stores scalars as shape `(1,)` — both
//!   forms decode to one element everywhere.
//! * the Rust writer used to truncate oversized names/ranks/dims with
//!   bare `as` casts; it now rejects them (`write` errors) instead of
//!   writing a corrupt container.
//!
//! This deserializing reader is the right tool for checkpoints and
//! params.  Model artifacts go through the zero-copy
//! [`crate::artifact::MappedStore`] instead.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{IntTensor, Tensor};

const MAGIC: &[u8; 6] = b"BMOE1\x00";

/// A named tensor of any supported dtype.
#[derive(Clone, Debug)]
pub enum Entry {
    F32(Tensor),
    I32(IntTensor),
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

impl Entry {
    pub fn shape(&self) -> &[usize] {
        match self {
            Entry::F32(t) => &t.shape,
            Entry::I32(t) => &t.shape,
            Entry::U8 { shape, .. } => shape,
        }
    }
    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            Entry::F32(t) => Some(t),
            _ => None,
        }
    }
}

/// Ordered named-tensor store (order is load-bearing: it must match the
/// flattened parameter order recorded in the manifest).
#[derive(Clone, Debug, Default)]
pub struct TensorStore {
    pub names: Vec<String>,
    pub by_name: BTreeMap<String, Entry>,
}

impl TensorStore {
    pub fn insert(&mut self, name: &str, e: Entry) {
        if !self.by_name.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.by_name.insert(name.to_string(), e);
    }

    pub fn get(&self, name: &str) -> Option<&Entry> {
        self.by_name.get(name)
    }

    pub fn get_f32(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .and_then(Entry::as_f32)
            .with_context(|| format!("tensor '{name}' missing or not f32"))
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Tensors in insertion order (== file order == manifest order).
    pub fn iter_ordered(&self) -> impl Iterator<Item = (&String, &Entry)> {
        self.names.iter().map(move |n| (n, &self.by_name[n]))
    }

    pub fn read(path: &Path) -> Result<TensorStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad magic {magic:?}", path.display());
        }
        let count = read_u32(&mut f)?;
        let mut store = TensorStore::default();
        for _ in 0..count {
            let nlen = read_u16(&mut f)? as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let mut hdr = [0u8; 2];
            f.read_exact(&mut hdr)?;
            let (code, ndim) = (hdr[0], hdr[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let n: usize = if ndim == 0 { 1 } else { shape.iter().product() };
            let entry = match code {
                0 => {
                    let mut raw = vec![0u8; n * 4];
                    f.read_exact(&mut raw)?;
                    let data = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Entry::F32(Tensor { shape, data })
                }
                1 => {
                    let mut raw = vec![0u8; n * 4];
                    f.read_exact(&mut raw)?;
                    let data = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Entry::I32(IntTensor { shape, data })
                }
                2 => {
                    let mut data = vec![0u8; n];
                    f.read_exact(&mut data)?;
                    Entry::U8 { shape, data }
                }
                _ => bail!("{}: unknown dtype code {code}", path.display()),
            };
            store.insert(&name, entry);
        }
        Ok(store)
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.names.len() as u32).to_le_bytes())?;
        for (name, e) in self.iter_ordered() {
            let nb = name.as_bytes();
            anyhow::ensure!(
                nb.len() <= u16::MAX as usize,
                "tensor name '{}…' exceeds the u16 name_len field",
                &name[..name.len().min(32)]
            );
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            let (code, shape): (u8, &[usize]) = match e {
                Entry::F32(t) => (0, &t.shape),
                Entry::I32(t) => (1, &t.shape),
                Entry::U8 { shape, .. } => (2, shape),
            };
            anyhow::ensure!(
                shape.len() <= u8::MAX as usize,
                "tensor '{name}': rank {} exceeds the u8 ndim field",
                shape.len()
            );
            f.write_all(&[code, shape.len() as u8])?;
            for &d in shape {
                anyhow::ensure!(
                    d <= u32::MAX as usize,
                    "tensor '{name}': dim {d} exceeds the u32 dims field"
                );
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            match e {
                Entry::F32(t) => {
                    for v in &t.data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Entry::I32(t) => {
                    for v in &t.data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Entry::U8 { data, .. } => f.write_all(data)?,
            }
        }
        Ok(())
    }
}

fn read_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("bmoe_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bmoe");
        let mut s = TensorStore::default();
        s.insert(
            "w.0",
            Entry::F32(Tensor::from_vec(&[2, 3], vec![1., -2., 3., 4., 5., 6.5])),
        );
        s.insert(
            "ids",
            Entry::I32(IntTensor::from_vec(&[4], vec![1, -2, 3, 4])),
        );
        s.insert(
            "scalar",
            Entry::F32(Tensor::from_vec(&[], vec![7.25])),
        );
        s.insert(
            "packed",
            Entry::U8 {
                shape: vec![3],
                data: vec![0, 127, 255],
            },
        );
        s.write(&path).unwrap();
        let back = TensorStore::read(&path).unwrap();
        assert_eq!(back.names, s.names);
        assert_eq!(back.get_f32("w.0").unwrap().data, vec![1., -2., 3., 4., 5., 6.5]);
        assert_eq!(back.get_f32("scalar").unwrap().data, vec![7.25]);
        match back.get("packed").unwrap() {
            Entry::U8 { data, .. } => assert_eq!(data, &vec![0, 127, 255]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn reads_python_export_if_present() {
        let path = Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/tiny.params.bmoe"
        ));
        if path.exists() {
            let s = TensorStore::read(path).unwrap();
            assert!(s.len() > 10);
            // embeddings present with the documented naming scheme
            assert!(s.names.iter().any(|n| n.contains("embed")));
        }
    }

    /// The exact bytes `python/compile/bmoe_io.py::write_bmoe` produces
    /// for this store (generated once, embedded verbatim): the
    /// cross-language format can never silently drift — any layout
    /// change on either side fails this test.
    const GOLDEN: &[u8] = &[
        0x42, 0x4d, 0x4f, 0x45, 0x31, 0x00, 0x03, 0x00, 0x00, 0x00, 0x01, 0x00, 0x77, 0x00,
        0x02, 0x02, 0x00, 0x00, 0x00, 0x03, 0x00, 0x00, 0x00, 0x00, 0x00, 0x80, 0x3f, 0x00,
        0x00, 0x00, 0xc0, 0x00, 0x00, 0x40, 0x40, 0x00, 0x00, 0x80, 0x40, 0x00, 0x00, 0xa0,
        0x40, 0x00, 0x00, 0xd0, 0x40, 0x03, 0x00, 0x69, 0x64, 0x73, 0x01, 0x01, 0x04, 0x00,
        0x00, 0x00, 0x01, 0x00, 0x00, 0x00, 0xfe, 0xff, 0xff, 0xff, 0x03, 0x00, 0x00, 0x00,
        0x04, 0x00, 0x00, 0x00, 0x06, 0x00, 0x70, 0x61, 0x63, 0x6b, 0x65, 0x64, 0x02, 0x01,
        0x03, 0x00, 0x00, 0x00, 0x00, 0x7f, 0xff,
    ];

    fn golden_store() -> TensorStore {
        let mut s = TensorStore::default();
        s.insert(
            "w",
            Entry::F32(Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.0, 4.0, 5.0, 6.5])),
        );
        s.insert("ids", Entry::I32(IntTensor::from_vec(&[4], vec![1, -2, 3, 4])));
        s.insert(
            "packed",
            Entry::U8 {
                shape: vec![3],
                data: vec![0, 127, 255],
            },
        );
        s
    }

    #[test]
    fn golden_bytes_exact() {
        // write parity: the Rust writer emits byte-for-byte what the
        // normative python writer produced for the same store
        let dir = std::env::temp_dir().join("bmoe_store_golden");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("golden.bmoe");
        golden_store().write(&path).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            GOLDEN,
            "Rust writer drifted from the python-written golden bytes"
        );
        // read parity: the golden bytes decode to the same store
        let gpath = dir.join("golden_in.bmoe");
        std::fs::write(&gpath, GOLDEN).unwrap();
        let back = TensorStore::read(&gpath).unwrap();
        assert_eq!(back.names, vec!["w", "ids", "packed"]);
        assert_eq!(back.get_f32("w").unwrap().shape, vec![2, 3]);
        assert_eq!(
            back.get_f32("w").unwrap().data,
            vec![1.0, -2.0, 3.0, 4.0, 5.0, 6.5]
        );
        match back.get("ids").unwrap() {
            Entry::I32(t) => assert_eq!(t.data, vec![1, -2, 3, 4]),
            _ => panic!("wrong dtype"),
        }
        match back.get("packed").unwrap() {
            Entry::U8 { data, .. } => assert_eq!(data, &vec![0, 127, 255]),
            _ => panic!("wrong dtype"),
        }
        // the zero-copy reader agrees with the deserializing one
        let m = crate::artifact::MappedStore::open(&gpath, crate::artifact::LoadMode::Heap)
            .unwrap();
        let (shape, w) = m.f32("w").unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(w.as_slice(), &back.get_f32("w").unwrap().data[..]);
    }

    #[test]
    fn writer_rejects_field_overflow_instead_of_truncating() {
        let dir = std::env::temp_dir().join("bmoe_store_overflow");
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = TensorStore::default();
        s.insert(
            &"n".repeat(u16::MAX as usize + 1),
            Entry::F32(Tensor::from_vec(&[1], vec![0.0])),
        );
        assert!(s.write(&dir.join("overflow.bmoe")).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("bmoe_store_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bmoe");
        std::fs::write(&path, b"NOTBMOE").unwrap();
        assert!(TensorStore::read(&path).is_err());
    }
}
