//! Host tensors (row-major f32/i32) and the BMOE tensor container.

pub mod store;

/// Row-major f32 tensor.  The native engine only needs rank <= 4.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != len {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn rand_normal(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Bytes of f32 storage (for memory accounting of dense baselines).
    pub fn nbytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Row view for 2-D tensors.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let w = self.shape[1];
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Dense matmul helper (tests/baselines only; hot paths live in
    /// `ternary::` and `butterfly::`):  self (m,k) @ other^T (n,k) -> (m,n).
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let xi = self.row(i);
            for j in 0..n {
                out.data[i * n + j] = crate::util::dot_f32(xi, other.row(j));
            }
        }
        let _ = k;
        out
    }

    /// Max |a-b| against another tensor (parity tests).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Integer tensor (token ids).
#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        IntTensor {
            shape: shape.to_vec(),
            data,
        }
    }
    pub fn zeros(shape: &[usize]) -> Self {
        IntTensor {
            shape: shape.to_vec(),
            data: vec![0; shape.iter().product()],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.rank(), 2);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn matmul_nt_small() {
        // x (2,3) @ w^T where w (2,3): out[i][j] = dot(x_i, w_j)
        let x = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let w = Tensor::from_vec(&[2, 3], vec![1., 0., 0., 0., 1., 0.]);
        let y = x.matmul_nt(&w);
        assert_eq!(y.shape, vec![2, 2]);
        assert_eq!(y.data, vec![1., 2., 4., 5.]);
    }

    #[test]
    fn row_views() {
        let mut t = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(t.row(1), &[3., 4.]);
        t.row_mut(0)[1] = 9.0;
        assert_eq!(t.data, vec![1., 9., 3., 4.]);
    }
}
