//! Read-only file mapping over the vendored `mman` shim (DESIGN.md §4:
//! the `libc` crate is not in the offline vendor set, so the three POSIX
//! calls the loader needs are raw `extern "C"` declarations in
//! `vendor/mman`).
//!
//! The mapping is `PROT_READ` + `MAP_SHARED`: every serve process that
//! maps the same model file shares its page-cache pages, which is the
//! substrate-sharing story of DESIGN.md §3.  On targets without the
//! shim (non-unix, 32-bit) [`Mmap::map`] returns an error and callers
//! fall back to [`LoadMode::Heap`](crate::artifact::LoadMode).

use std::path::Path;

use anyhow::{Context, Result};

/// A read-only shared mapping of an entire file.
pub struct Mmap {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is read-only for its whole lifetime and the
// pointer is never handed out mutably.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Whether this target can map files at all.
    pub fn supported() -> bool {
        cfg!(all(unix, target_pointer_width = "64"))
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn map(path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let len = f.metadata()?.len() as usize;
        anyhow::ensure!(len > 0, "{}: empty file", path.display());
        let fd = f.as_raw_fd();
        // sanity-read the first bytes through the shim's pread so a
        // wholly unreadable file fails with a clean error, not SIGBUS
        let mut probe = [0u8; 8];
        let got = unsafe {
            mman::sys::pread(fd, probe.as_mut_ptr() as *mut core::ffi::c_void, probe.len(), 0)
        };
        anyhow::ensure!(got > 0, "{}: unreadable", path.display());
        let ptr = unsafe {
            mman::sys::mmap(
                std::ptr::null_mut(),
                len,
                mman::sys::PROT_READ,
                mman::sys::MAP_SHARED,
                fd,
                0,
            )
        };
        anyhow::ensure!(ptr != mman::sys::MAP_FAILED, "mmap({}) failed", path.display());
        // the fd may close now: the mapping holds its own reference
        Ok(Mmap {
            ptr: ptr as *mut u8,
            len,
        })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn map(path: &Path) -> Result<Mmap> {
        anyhow::bail!(
            "mmap is not available on this target ({}); load with LoadMode::Heap",
            path.display()
        )
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len come from a successful mmap that lives until
        // Drop; the mapping is never written.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        unsafe {
            mman::sys::munmap(self.ptr as *mut core::ffi::c_void, self.len);
        }
    }
}

#[cfg(all(test, unix, target_pointer_width = "64"))]
mod tests {
    use super::*;

    #[test]
    fn maps_a_real_file() {
        let dir = std::env::temp_dir().join("bmoe_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &data).unwrap();
        let m = Mmap::map(&path).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.bytes(), &data[..]);
        assert!(Mmap::supported());
    }

    #[test]
    fn missing_and_empty_files_error() {
        let dir = std::env::temp_dir().join("bmoe_mmap_test2");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Mmap::map(&dir.join("nope.bin")).is_err());
        let empty = dir.join("empty.bin");
        std::fs::write(&empty, b"").unwrap();
        assert!(Mmap::map(&empty).is_err());
    }
}
