//! Native model artifacts: a versioned multi-layer model format over the
//! BMOE1 tensor container, a packer, and an mmap-backed zero-copy loader
//! (normative spec: DESIGN.md §3).
//!
//! The paper's point is that N experts' identities fit in
//! O(d² + N·d·log d) bytes — small enough for an edge device's *disk and
//! page cache*, not just its RAM.  This module makes the native engine
//! model-file-driven so that story holds end to end:
//!
//! * [`pack_model`] writes any [`ButterflyMoeLayer`] stack (plus embed /
//!   readout and a JSON [`ModelManifest`]) into one `.bmoe` file, with
//!   `__pad.*` filler tensors 64-aligning every bulk tensor's payload.
//! * [`ModelArtifact::load`] opens the file in [`LoadMode::Mmap`]
//!   (borrow tensor payloads straight from the mapping — cold start is
//!   page faults, not deserialization, and concurrent serve processes
//!   share the substrate's page-cache pages) or [`LoadMode::Heap`] (read
//!   + eager decode: the deserialization baseline the cold-start bench
//!   compares against).  The two modes are bit-identical by construction
//!   — they read the same bytes — which `rust/tests/artifact.rs` and the
//!   multi-layer cases in `rust/tests/determinism.rs` pin.
//! * [`synthesize`] builds the seeded multi-layer stand-in model that
//!   `bmoe serve --native` (without `--model`) and `bmoe pack-model`
//!   share, so a packed-then-loaded model is bit-identical to the
//!   in-memory one it came from.
//!
//! File-size accounting lives in [`crate::memmodel::model_file_bytes`]
//! and is pinned against real packed artifacts in the tests.

pub mod mapped;
pub mod mmapfile;
pub mod shared;

use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

pub use mapped::{LoadMode, MappedStore, RawEntry};
pub use mmapfile::Mmap;
pub use shared::{Backing, Pod, SharedSlice, ShTensor};

use crate::butterfly::Butterfly;
use crate::jsonx::Json;
use crate::moe::layer::OrbitExpert;
use crate::moe::{ButterflyMoeLayer, GateNetwork, MoeLayer};
use crate::tensor::Tensor;
use crate::ternary::BitplaneTernary;
use crate::util::Rng;

/// Name of the embedded JSON manifest tensor (always written first).
pub const MANIFEST_TENSOR: &str = "__model__";

/// Alignment of bulk tensor payloads in a packed model (64 covers every
/// element width we borrow — f32 and u64 — plus cache-line alignment).
pub const DATA_ALIGN: usize = 64;

/// Current model-format version ([`ModelManifest::version`]).
pub const FORMAT_VERSION: u64 = 1;

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

/// The versioned model manifest embedded as the `__model__` tensor —
/// everything a loader needs to validate shapes before touching a single
/// weight page (DESIGN.md §3 lists the schema normatively).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelManifest {
    pub version: u64,
    pub vocab: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    /// butterfly stages of the input transform (over `d_model`)
    pub depth_in: usize,
    /// butterfly stages of the output transform (over `d_ff`)
    pub depth_out: usize,
}

/// Integrity record embedded in the `__model__` manifest since the
/// checksum-era packer: per-tensor CRC-32s plus whole-payload totals, so
/// a truncated or bit-rotted artifact is rejected *before* any decode
/// (DESIGN.md §8).  Optional on read — manifests packed before this
/// existed still load; they just can't be verified.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelIntegrity {
    /// Total bytes of checksummed tensor payloads (everything except the
    /// manifest itself and `__pad.*` fillers, whose sizes depend on the
    /// manifest's own length — excluding them keeps the record
    /// non-circular).
    pub payload_bytes: u64,
    /// CRC-32 over the checksummed payloads concatenated in file order.
    pub payload_crc: u32,
    /// Per-tensor CRC-32 keyed by tensor name.
    pub checksums: std::collections::BTreeMap<String, u32>,
}

/// Is `name` covered by the integrity record?
fn integrity_covers(name: &str) -> bool {
    name != MANIFEST_TENSOR && !name.starts_with("__pad.")
}

impl ModelIntegrity {
    /// JSON fields spliced into the manifest object (no outer braces).
    fn to_json_fields(&self) -> String {
        let sums: Vec<String> = self
            .checksums
            .iter()
            .map(|(k, v)| format!("\"{k}\":{v}"))
            .collect();
        format!(
            "\"payload_bytes\":{},\"payload_crc\":{},\"checksums\":{{{}}}",
            self.payload_bytes,
            self.payload_crc,
            sums.join(",")
        )
    }

    /// Parse from the manifest bytes.  `Ok(None)` when the manifest
    /// predates integrity records.
    pub fn parse(bytes: &[u8]) -> Result<Option<ModelIntegrity>> {
        let text = std::str::from_utf8(bytes).context("model manifest is not utf-8")?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("model manifest: {e}"))?;
        let Some(payload_bytes) = j.get("payload_bytes").and_then(Json::as_f64) else {
            return Ok(None);
        };
        let payload_crc = j
            .get("payload_crc")
            .and_then(Json::as_f64)
            .context("manifest has payload_bytes but no payload_crc")? as u32;
        let obj = j
            .get("checksums")
            .and_then(Json::as_obj)
            .context("manifest has payload_bytes but no checksums object")?;
        let mut checksums = std::collections::BTreeMap::new();
        for (k, v) in obj {
            let c = v
                .as_f64()
                .with_context(|| format!("checksum for tensor '{k}' is not a number"))?;
            checksums.insert(k.clone(), c as u32);
        }
        Ok(Some(ModelIntegrity {
            payload_bytes: payload_bytes as u64,
            payload_crc,
            checksums,
        }))
    }
}

impl ModelManifest {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"format\":\"bmoe-model\",\"version\":{},\"vocab\":{},\"seq_len\":{},\
             \"d_model\":{},\"d_ff\":{},\"n_layers\":{},\"n_experts\":{},\"top_k\":{},\
             \"depth_in\":{},\"depth_out\":{}}}",
            self.version,
            self.vocab,
            self.seq_len,
            self.d_model,
            self.d_ff,
            self.n_layers,
            self.n_experts,
            self.top_k,
            self.depth_in,
            self.depth_out,
        )
    }

    pub fn parse(bytes: &[u8]) -> Result<ModelManifest> {
        let text = std::str::from_utf8(bytes).context("model manifest is not utf-8")?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("model manifest: {e}"))?;
        let fmt = j
            .get("format")
            .and_then(Json::as_str)
            .context("manifest missing 'format'")?;
        anyhow::ensure!(fmt == "bmoe-model", "not a bmoe model manifest (format='{fmt}')");
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing key '{k}'"))
        };
        let version = get("version")? as u64;
        anyhow::ensure!(
            version == FORMAT_VERSION,
            "unsupported model format version {version} (this build reads {FORMAT_VERSION})"
        );
        let m = ModelManifest {
            version,
            vocab: get("vocab")?,
            seq_len: get("seq_len")?,
            d_model: get("d_model")?,
            d_ff: get("d_ff")?,
            n_layers: get("n_layers")?,
            n_experts: get("n_experts")?,
            top_k: get("top_k")?,
            depth_in: get("depth_in")?,
            depth_out: get("depth_out")?,
        };
        anyhow::ensure!(
            m.d_model.is_power_of_two() && m.d_ff.is_power_of_two(),
            "d_model/d_ff must be powers of two (butterfly constraint)"
        );
        anyhow::ensure!(m.n_layers >= 1, "model has no layers");
        anyhow::ensure!(m.vocab >= 1 && m.seq_len >= 1, "empty vocab/seq_len");
        anyhow::ensure!(
            m.top_k >= 1 && m.top_k <= m.n_experts,
            "top_k out of range"
        );
        // loud load-time rejection instead of an out-of-bounds (or
        // shift-overflow) panic inside stage() on the first decode step
        let max_in = crate::butterfly::Butterfly::max_depth(m.d_model);
        let max_out = crate::butterfly::Butterfly::max_depth(m.d_ff);
        anyhow::ensure!(
            m.depth_in >= 1 && m.depth_in <= max_in,
            "depth_in {} out of range 1..={max_in} for d_model {}",
            m.depth_in,
            m.d_model
        );
        anyhow::ensure!(
            m.depth_out >= 1 && m.depth_out <= max_out,
            "depth_out {} out of range 1..={max_out} for d_ff {}",
            m.depth_out,
            m.d_ff
        );
        Ok(m)
    }
}

// ---------------------------------------------------------------------------
// Packer
// ---------------------------------------------------------------------------

/// What [`pack_model`] wrote.
#[derive(Clone, Copy, Debug)]
pub struct PackStats {
    pub file_bytes: u64,
    pub tensors: usize,
    /// `__pad.*` alignment fillers among `tensors`
    pub pads: usize,
}

struct PackWriter {
    f: BufWriter<std::fs::File>,
    off: usize,
    count: u32,
    pads: usize,
}

impl PackWriter {
    fn create(path: &Path) -> Result<PackWriter> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut f = BufWriter::new(file);
        f.write_all(mapped::MAGIC)?;
        f.write_all(&0u32.to_le_bytes())?; // count, patched in finish()
        Ok(PackWriter {
            f,
            off: 10,
            count: 0,
            pads: 0,
        })
    }

    fn header_len(name: &str, ndim: usize) -> usize {
        2 + name.len() + 2 + 4 * ndim
    }

    /// Write one tensor entry, unaligned.
    fn raw_tensor(&mut self, name: &str, code: u8, shape: &[usize], data: &[u8]) -> Result<()> {
        anyhow::ensure!(name.len() <= u16::MAX as usize, "tensor name too long");
        anyhow::ensure!(shape.len() <= u8::MAX as usize, "tensor rank too high");
        let elems: usize = if shape.is_empty() {
            1
        } else {
            shape.iter().product()
        };
        let itemsize = match code {
            mapped::DTYPE_F32 | mapped::DTYPE_I32 => 4,
            mapped::DTYPE_U8 => 1,
            _ => bail!("unknown dtype code {code}"),
        };
        anyhow::ensure!(
            elems * itemsize == data.len(),
            "tensor '{name}': {} bytes for shape {shape:?}",
            data.len()
        );
        self.f.write_all(&(name.len() as u16).to_le_bytes())?;
        self.f.write_all(name.as_bytes())?;
        self.f.write_all(&[code, shape.len() as u8])?;
        for &d in shape {
            anyhow::ensure!(d <= u32::MAX as usize, "dim too large");
            self.f.write_all(&(d as u32).to_le_bytes())?;
        }
        self.f.write_all(data)?;
        self.off += Self::header_len(name, shape.len()) + data.len();
        self.count += 1;
        Ok(())
    }

    /// Write one tensor whose *data payload* starts [`DATA_ALIGN`]-aligned,
    /// inserting a `__pad.N` filler tensor first when needed.  Files
    /// without pads still load (the reader copy-falls-back), so this is
    /// an optimization the packer guarantees, not a format requirement.
    fn aligned_tensor(&mut self, name: &str, code: u8, shape: &[usize], data: &[u8]) -> Result<()> {
        let h = Self::header_len(name, shape.len());
        if (self.off + h) % DATA_ALIGN != 0 {
            let pname = format!("__pad.{}", self.pads);
            let ph = Self::header_len(&pname, 1);
            let p = (DATA_ALIGN - ((self.off + ph + h) % DATA_ALIGN)) % DATA_ALIGN;
            self.raw_tensor(&pname, mapped::DTYPE_U8, &[p], &vec![0u8; p])?;
            self.pads += 1;
            debug_assert_eq!((self.off + h) % DATA_ALIGN, 0);
        }
        self.raw_tensor(name, code, shape, data)
    }

    fn finish(mut self) -> Result<PackStats> {
        self.f.seek(SeekFrom::Start(6))?;
        self.f.write_all(&self.count.to_le_bytes())?;
        self.f.flush()?;
        Ok(PackStats {
            file_bytes: self.off as u64,
            tensors: self.count as usize,
            pads: self.pads,
        })
    }
}

fn f32_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u64_bytes(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// One tensor staged for packing (two-pass: checksums over the staged
/// payloads go *into* the manifest, which is written first).
struct Pending {
    name: String,
    code: u8,
    shape: Vec<usize>,
    data: Vec<u8>,
    /// bulk tensors get a `__pad.*` filler so their payload is
    /// [`DATA_ALIGN`]-aligned; scalars skip it
    aligned: bool,
}

/// Stage every model tensor (everything except the manifest) in file
/// order.
fn stage_tensors(
    m: &ModelManifest,
    embed: &[f32],
    readout: &[f32],
    layers: &[ButterflyMoeLayer],
) -> Result<Vec<Pending>> {
    let mut out = Vec::new();
    let mut push = |name: String, code: u8, shape: Vec<usize>, data: Vec<u8>, aligned: bool| {
        out.push(Pending {
            name,
            code,
            shape,
            data,
            aligned,
        });
    };
    push(
        "embed".into(),
        mapped::DTYPE_F32,
        vec![m.vocab, m.d_model],
        f32_bytes(embed),
        true,
    );
    push(
        "readout".into(),
        mapped::DTYPE_F32,
        vec![m.vocab, m.d_model],
        f32_bytes(readout),
        true,
    );
    let (half_in, half_out) = (m.d_model / 2, m.d_ff / 2);
    for (l, layer) in layers.iter().enumerate() {
        anyhow::ensure!(
            layer.d_model() == m.d_model
                && layer.d_ff() == m.d_ff
                && layer.n_experts() == m.n_experts,
            "layer {l} shape disagrees with manifest"
        );
        let sub = &layer.substrate;
        let wpr = sub.words_per_row();
        let prefix = format!("layers.{l}");
        push(
            format!("{prefix}.gate"),
            mapped::DTYPE_F32,
            vec![m.n_experts, m.d_model],
            f32_bytes(&layer.gate.w.data),
            true,
        );
        push(
            format!("{prefix}.substrate.gamma"),
            mapped::DTYPE_F32,
            vec![],
            sub.gamma.to_le_bytes().to_vec(),
            false,
        );
        push(
            format!("{prefix}.substrate.plus"),
            mapped::DTYPE_U8,
            vec![m.d_ff, wpr * 8],
            u64_bytes(sub.plus_words()),
            true,
        );
        push(
            format!("{prefix}.substrate.minus"),
            mapped::DTYPE_U8,
            vec![m.d_ff, wpr * 8],
            u64_bytes(sub.minus_words()),
            true,
        );
        // stacked per-expert tables: angles then serving (cos, sin)
        let mut theta = Vec::with_capacity(m.n_experts * m.depth_in * half_in);
        let mut theta_cs = Vec::with_capacity(2 * theta.capacity());
        let mut phi = Vec::with_capacity(m.n_experts * m.depth_out * half_out);
        let mut phi_cs = Vec::with_capacity(2 * phi.capacity());
        for ex in &layer.experts {
            anyhow::ensure!(
                ex.theta.depth == m.depth_in && ex.phi.depth == m.depth_out,
                "expert depth disagrees with manifest"
            );
            theta.extend_from_slice(ex.theta.angles());
            theta_cs.extend_from_slice(ex.theta.cs_table());
            phi.extend_from_slice(ex.phi.angles());
            phi_cs.extend_from_slice(ex.phi.cs_table());
        }
        push(
            format!("{prefix}.theta"),
            mapped::DTYPE_F32,
            vec![m.n_experts, m.depth_in, half_in],
            f32_bytes(&theta),
            true,
        );
        push(
            format!("{prefix}.theta_cs"),
            mapped::DTYPE_F32,
            vec![m.n_experts, m.depth_in, half_in, 2],
            f32_bytes(&theta_cs),
            true,
        );
        push(
            format!("{prefix}.phi"),
            mapped::DTYPE_F32,
            vec![m.n_experts, m.depth_out, half_out],
            f32_bytes(&phi),
            true,
        );
        push(
            format!("{prefix}.phi_cs"),
            mapped::DTYPE_F32,
            vec![m.n_experts, m.depth_out, half_out, 2],
            f32_bytes(&phi_cs),
            true,
        );
        push(
            format!("{prefix}.w_down"),
            mapped::DTYPE_F32,
            vec![m.d_model, m.d_ff],
            f32_bytes(layer.w_down_data()),
            true,
        );
    }
    Ok(out)
}

/// Pack a ButterflyMoE layer stack (+ embed/readout) into a `.bmoe`
/// model artifact at `path`.  Tensor naming and layout are normative in
/// DESIGN.md §3; both the raw angle tensors (provenance / python
/// interop) and the precomputed `*_cs` (cos, sin) serving tables are
/// written, so a loaded model performs bit-identical arithmetic to the
/// in-memory stack that was packed — no trig at load time.
///
/// Two passes: tensors are staged first so their CRC-32s and total
/// payload length land *inside* the manifest (written first in the
/// file), giving loaders an integrity record to preflight against
/// (DESIGN.md §8).
pub fn pack_model(
    path: &Path,
    manifest: &ModelManifest,
    embed: &[f32],
    readout: &[f32],
    layers: &[ButterflyMoeLayer],
) -> Result<PackStats> {
    use crate::util::crc32::{crc32, crc32_update};
    let m = manifest;
    anyhow::ensure!(m.n_layers == layers.len(), "manifest/layer-count mismatch");
    anyhow::ensure!(embed.len() == m.vocab * m.d_model, "embed shape mismatch");
    anyhow::ensure!(readout.len() == m.vocab * m.d_model, "readout shape mismatch");
    let staged = stage_tensors(m, embed, readout, layers)?;
    let mut checksums = std::collections::BTreeMap::new();
    let mut payload_bytes = 0u64;
    let mut payload_crc = 0u32;
    for t in &staged {
        checksums.insert(t.name.clone(), crc32(&t.data));
        payload_bytes += t.data.len() as u64;
        payload_crc = crc32_update(payload_crc, &t.data);
    }
    let integrity = ModelIntegrity {
        payload_bytes,
        payload_crc,
        checksums,
    };
    // splice the integrity fields into the manifest object
    let mut json = m.to_json();
    anyhow::ensure!(json.pop() == Some('}'), "manifest json not an object");
    json.push(',');
    json.push_str(&integrity.to_json_fields());
    json.push('}');
    let mut w = PackWriter::create(path)?;
    w.raw_tensor(
        MANIFEST_TENSOR,
        mapped::DTYPE_U8,
        &[json.len()],
        json.as_bytes(),
    )?;
    for t in &staged {
        if t.aligned {
            w.aligned_tensor(&t.name, t.code, &t.shape, &t.data)?;
        } else {
            w.raw_tensor(&t.name, t.code, &t.shape, &t.data)?;
        }
    }
    w.finish()
}

// ---------------------------------------------------------------------------
// Loader
// ---------------------------------------------------------------------------

/// A loaded `.bmoe` model: manifest + directory + (mapped or heap)
/// backing bytes.  Layers built from it borrow the backing through
/// [`SharedSlice`], so keep the artifact's `Arc` alive only if you need
/// its stats — the layers themselves keep the backing alive.
pub struct ModelArtifact {
    pub manifest: ModelManifest,
    /// Checksum record, when the packer recorded one (older artifacts:
    /// `None` — they load, but cannot be verified).
    pub integrity: Option<ModelIntegrity>,
    pub path: PathBuf,
    store: MappedStore,
}

impl ModelArtifact {
    /// Open `path` in `mode`.  A [`LoadMode::Mmap`] request on a target
    /// without mmap support (non-unix / 32-bit) silently degrades to
    /// [`LoadMode::Heap`] — identical bits, no zero-copy win; the
    /// artifact's [`mode`](Self::mode) reports what actually happened.
    ///
    /// Integrity (DESIGN.md §8): when the manifest carries a checksum
    /// record, the directory's payload accounting is preflighted against
    /// it unconditionally (a truncated file fails here with a clean
    /// error, not a SIGBUS mid-decode), and [`LoadMode::Heap`] loads —
    /// which have every byte in hand anyway — verify all checksums
    /// eagerly.  Mmap loads skip the eager pass by default (it would
    /// fault in every page and defeat the lazy cold start); opt in with
    /// [`ModelArtifact::load_verified`] or `bmoe verify-model`.
    pub fn load(path: &Path, mode: LoadMode) -> Result<ModelArtifact> {
        Self::load_opts(path, mode, false)
    }

    /// [`load`](Self::load), but always verify every tensor checksum
    /// before returning; errors when the artifact has no checksum record.
    pub fn load_verified(path: &Path, mode: LoadMode) -> Result<ModelArtifact> {
        Self::load_opts(path, mode, true)
    }

    fn load_opts(path: &Path, mode: LoadMode, verify: bool) -> Result<ModelArtifact> {
        let mode = if mode == LoadMode::Mmap && !Mmap::supported() {
            LoadMode::Heap
        } else {
            mode
        };
        let store = MappedStore::open(path, mode)?;
        let mbytes = store.bytes(MANIFEST_TENSOR).with_context(|| {
            format!("{}: not a model artifact (no {MANIFEST_TENSOR} tensor)", path.display())
        })?;
        let manifest = ModelManifest::parse(mbytes)?;
        let integrity = ModelIntegrity::parse(mbytes)?;
        let art = ModelArtifact {
            manifest,
            integrity,
            path: path.to_path_buf(),
            store,
        };
        if let Some(integ) = &art.integrity {
            let present: u64 = art
                .store
                .entries()
                .iter()
                .filter(|e| integrity_covers(&e.name))
                .map(|e| e.byte_len as u64)
                .sum();
            anyhow::ensure!(
                present == integ.payload_bytes,
                "{}: payload is {present} bytes but the manifest records {} — \
                 artifact truncated or tensors missing",
                path.display(),
                integ.payload_bytes
            );
        }
        if verify || art.mode() == LoadMode::Heap {
            if art.integrity.is_some() {
                art.verify_checksums()?;
            } else if verify {
                anyhow::bail!(
                    "{}: no checksums recorded (packed before integrity support); \
                     re-pack to enable verification",
                    path.display()
                );
            }
        }
        Ok(art)
    }

    /// Check every covered tensor's bytes against the manifest's CRC-32
    /// record, plus the whole-payload totals.  Errors name the first
    /// corrupt tensor.  In mmap mode this faults in the entire file.
    pub fn verify_checksums(&self) -> Result<()> {
        use crate::util::crc32::{crc32, crc32_update};
        let integ = self.integrity.as_ref().with_context(|| {
            format!("{}: no checksums recorded in manifest", self.path.display())
        })?;
        let mut running = 0u32;
        let mut seen = 0usize;
        for e in self.store.entries() {
            if !integrity_covers(&e.name) {
                continue;
            }
            let data = self.store.bytes(&e.name)?;
            let want = *integ.checksums.get(&e.name).with_context(|| {
                format!("tensor '{}' has no recorded checksum", e.name)
            })?;
            let got = crc32(data);
            anyhow::ensure!(
                got == want,
                "tensor '{}': checksum mismatch (file {got:#010x}, manifest {want:#010x}) — \
                 artifact corrupt",
                e.name
            );
            running = crc32_update(running, data);
            seen += 1;
        }
        anyhow::ensure!(
            seen == integ.checksums.len(),
            "manifest records {} checksums but the file has {seen} covered tensors",
            integ.checksums.len()
        );
        anyhow::ensure!(
            running == integ.payload_crc,
            "whole-payload checksum mismatch (file {running:#010x}, manifest {:#010x})",
            integ.payload_crc
        );
        Ok(())
    }

    pub fn mode(&self) -> LoadMode {
        self.store.mode()
    }

    /// The underlying container directory — extra (non-model) tensors a
    /// fixture or tool stored alongside the model, e.g. the
    /// `expected.*` reference outputs of the cross-language fixture.
    pub fn store(&self) -> &MappedStore {
        &self.store
    }

    /// Bytes of the file image backing this model (the `memmodel`
    /// file-bytes accounting is pinned against this).
    pub fn file_bytes(&self) -> usize {
        self.store.file_bytes()
    }

    /// `(borrowed in place, decoded to owned)` tensor counts so far.
    pub fn zero_copy_stats(&self) -> (usize, usize) {
        self.store.zero_copy_stats()
    }

    fn sh_tensor(&self, name: &str, want: &[usize]) -> Result<ShTensor> {
        let (shape, data) = self.store.f32(name)?;
        anyhow::ensure!(
            shape == want,
            "tensor '{name}': shape {shape:?}, expected {want:?}"
        );
        Ok(ShTensor::new(shape, data))
    }

    /// Token embedding table `(vocab, d_model)`.
    pub fn embed(&self) -> Result<ShTensor> {
        let m = &self.manifest;
        self.sh_tensor("embed", &[m.vocab, m.d_model])
    }

    /// Readout projection `(vocab, d_model)`.
    pub fn readout(&self) -> Result<ShTensor> {
        let m = &self.manifest;
        self.sh_tensor("readout", &[m.vocab, m.d_model])
    }

    /// Build the full layer stack, borrowing bitplanes, angle tables and
    /// dense projections from the backing (mmap mode) or from the eager
    /// heap decode (heap mode) — identical bits either way.
    pub fn build_layers(&self) -> Result<Vec<ButterflyMoeLayer>> {
        (0..self.manifest.n_layers)
            .map(|l| self.build_layer(l))
            .collect()
    }

    fn build_layer(&self, l: usize) -> Result<ButterflyMoeLayer> {
        let m = &self.manifest;
        let (d, dff, e) = (m.d_model, m.d_ff, m.n_experts);
        let (half_in, half_out) = (d / 2, dff / 2);
        let prefix = format!("layers.{l}");
        let gate = {
            // decoded owned (f32_owned): the gate is re-materialized as a
            // Tensor either way, so it counts as a copy in the zero-copy
            // telemetry instead of a phantom borrow
            let (shape, data) = self.store.f32_owned(&format!("{prefix}.gate"))?;
            anyhow::ensure!(shape == [e, d], "layer {l}: gate shape {shape:?}");
            GateNetwork::new(Tensor::from_vec(&[e, d], data), m.top_k)
        };
        let gamma = self.store.f32_scalar(&format!("{prefix}.substrate.gamma"))?;
        let wpr = d.div_ceil(64);
        let plane = |which: &str| -> Result<SharedSlice<u64>> {
            let name = format!("{prefix}.substrate.{which}");
            let (shape, words) = self.store.u64_words(&name)?;
            anyhow::ensure!(
                shape == [dff, wpr * 8],
                "'{name}': shape {shape:?}, expected [{dff}, {}]",
                wpr * 8
            );
            Ok(words)
        };
        let substrate =
            BitplaneTernary::from_planes(dff, d, gamma, plane("plus")?, plane("minus")?);
        let angle_table = |which: &str, depth: usize, half: usize| -> Result<SharedSlice<f32>> {
            let name = format!("{prefix}.{which}");
            let (shape, data) = self.store.f32(&name)?;
            anyhow::ensure!(
                shape == [e, depth, half],
                "'{name}': shape {shape:?}, expected [{e}, {depth}, {half}]"
            );
            Ok(data)
        };
        let cs_table = |which: &str, depth: usize, half: usize| -> Result<SharedSlice<f32>> {
            let name = format!("{prefix}.{which}");
            let (shape, data) = self.store.f32(&name)?;
            anyhow::ensure!(
                shape == [e, depth, half, 2],
                "'{name}': shape {shape:?}, expected [{e}, {depth}, {half}, 2]"
            );
            Ok(data)
        };
        let theta = angle_table("theta", m.depth_in, half_in)?;
        let theta_cs = cs_table("theta_cs", m.depth_in, half_in)?;
        let phi = angle_table("phi", m.depth_out, half_out)?;
        let phi_cs = cs_table("phi_cs", m.depth_out, half_out)?;
        let experts = (0..e)
            .map(|i| {
                let (na, nc) = (m.depth_in * half_in, m.depth_in * half_in * 2);
                let (pa, pc) = (m.depth_out * half_out, m.depth_out * half_out * 2);
                OrbitExpert {
                    theta: Butterfly::from_shared(
                        d,
                        m.depth_in,
                        theta.sub(i * na, na),
                        theta_cs.sub(i * nc, nc),
                    ),
                    phi: Butterfly::from_shared(
                        dff,
                        m.depth_out,
                        phi.sub(i * pa, pa),
                        phi_cs.sub(i * pc, pc),
                    ),
                }
            })
            .collect();
        let w_down = self.sh_tensor(&format!("{prefix}.w_down"), &[d, dff])?;
        Ok(ButterflyMoeLayer::from_parts(
            gate,
            Arc::new(substrate),
            experts,
            w_down,
        ))
    }
}

// ---------------------------------------------------------------------------
// Synthetic model (seeded stand-in shared by serve / pack-model / tests)
// ---------------------------------------------------------------------------

/// Shape + seed of a synthesized model.  `bmoe serve --native` (without
/// `--model`) and `bmoe pack-model` build from the *same* spec, so a
/// packed-then-loaded model is bit-identical to the in-memory stand-in.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub seq_len: usize,
    /// butterfly depth override (`None` = full `log2 d` depth)
    pub depth: Option<usize>,
    pub seed: u64,
}

impl SynthSpec {
    /// The serve default: the shape `bmoe serve --native` has always used.
    pub fn serve_default(n_layers: usize, seed: u64) -> SynthSpec {
        SynthSpec {
            d_model: 256,
            d_ff: 1024,
            n_experts: 16,
            top_k: 2,
            n_layers,
            vocab: 512,
            seq_len: 32,
            depth: None,
            seed,
        }
    }

    /// The paper shape (Table 1 / Prop. 1): d=512, d_ff=2048, 64 experts.
    pub fn paper(n_layers: usize, seed: u64) -> SynthSpec {
        SynthSpec {
            d_model: 512,
            d_ff: 2048,
            n_experts: 64,
            top_k: 2,
            n_layers,
            vocab: 512,
            seq_len: 32,
            depth: None,
            seed,
        }
    }

    pub fn manifest(&self) -> ModelManifest {
        ModelManifest {
            version: FORMAT_VERSION,
            vocab: self.vocab,
            seq_len: self.seq_len,
            d_model: self.d_model,
            d_ff: self.d_ff,
            n_layers: self.n_layers,
            n_experts: self.n_experts,
            top_k: self.top_k,
            depth_in: self.depth.unwrap_or(Butterfly::max_depth(self.d_model)),
            depth_out: self.depth.unwrap_or(Butterfly::max_depth(self.d_ff)),
        }
    }
}

/// A synthesized in-memory model: what [`pack_model`] packs and what the
/// native backend serves directly when no `--model` file is given.
pub struct SynthModel {
    pub manifest: ModelManifest,
    pub embed: Tensor,
    pub readout: Tensor,
    pub layers: Vec<ButterflyMoeLayer>,
}

impl SynthModel {
    pub fn pack(&self, path: &Path) -> Result<PackStats> {
        pack_model(
            path,
            &self.manifest,
            &self.embed.data,
            &self.readout.data,
            &self.layers,
        )
    }
}

/// Deterministically synthesize a multi-layer model from `spec` (pure
/// function of the spec: same spec ⇒ same weights, across processes).
pub fn synthesize(spec: &SynthSpec) -> SynthModel {
    let manifest = spec.manifest();
    let mut lrng = Rng::new(spec.seed);
    let layers = (0..spec.n_layers)
        .map(|l| {
            ButterflyMoeLayer::random(
                spec.d_model,
                spec.d_ff,
                spec.n_experts,
                spec.top_k,
                spec.depth,
                &mut lrng.fork(l as u64),
            )
        })
        .collect();
    // embed/readout seeding matches the historical NativeMoeBackend
    // stand-in at seed 0 (0xE13BED)
    let mut erng = Rng::new(0xE13BED ^ spec.seed);
    let embed = Tensor::rand_normal(&[spec.vocab, spec.d_model], 0.1, &mut erng);
    let readout = Tensor::rand_normal(&[spec.vocab, spec.d_model], 0.1, &mut erng);
    SynthModel {
        manifest,
        embed,
        readout,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SynthSpec {
        SynthSpec {
            d_model: 16,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_layers: 2,
            vocab: 32,
            seq_len: 8,
            depth: None,
            seed: 7,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("bmoe_artifact_mod_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = tiny_spec().manifest();
        let back = ModelManifest::parse(m.to_json().as_bytes()).unwrap();
        assert_eq!(m, back);
        assert!(ModelManifest::parse(b"{}").is_err());
        assert!(ModelManifest::parse(b"{\"format\":\"other\"}").is_err());
        // future versions are rejected loudly, not misread
        let future = m.to_json().replace("\"version\":1", "\"version\":99");
        assert!(ModelManifest::parse(future.as_bytes()).is_err());
    }

    #[test]
    fn pack_then_load_heap_reproduces_every_tensor() {
        let model = synthesize(&tiny_spec());
        let path = tmp("roundtrip.bmoe");
        let stats = model.pack(&path).unwrap();
        assert_eq!(stats.file_bytes, std::fs::metadata(&path).unwrap().len());
        let art = ModelArtifact::load(&path, LoadMode::Heap).unwrap();
        assert_eq!(art.manifest, model.manifest);
        assert_eq!(art.embed().unwrap().data(), &model.embed.data[..]);
        assert_eq!(art.readout().unwrap().data(), &model.readout.data[..]);
        let layers = art.build_layers().unwrap();
        assert_eq!(layers.len(), 2);
        for (a, b) in layers.iter().zip(&model.layers) {
            assert_eq!(a.gate.w.data, b.gate.w.data);
            assert_eq!(a.substrate.gamma, b.substrate.gamma);
            assert_eq!(a.substrate.plus_words(), b.substrate.plus_words());
            assert_eq!(a.substrate.minus_words(), b.substrate.minus_words());
            assert_eq!(a.w_down_data(), b.w_down_data());
            for (ea, eb) in a.experts.iter().zip(&b.experts) {
                assert_eq!(ea.theta.cs_table(), eb.theta.cs_table());
                assert_eq!(ea.theta.angles(), eb.theta.angles());
                assert_eq!(ea.phi.cs_table(), eb.phi.cs_table());
            }
        }
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    #[test]
    fn mmap_load_borrows_bulk_tensors_in_place() {
        let model = synthesize(&tiny_spec());
        let path = tmp("mapped.bmoe");
        model.pack(&path).unwrap();
        let art = ModelArtifact::load(&path, LoadMode::Mmap).unwrap();
        let layers = art.build_layers().unwrap();
        let _embed = art.embed().unwrap();
        let (borrowed, copied) = art.zero_copy_stats();
        // gate tensors are copied into the GateNetwork (small); every
        // bulk tensor — planes, angle/cs tables, w_down, embed — must
        // have been borrowed from the packed (aligned) file
        assert!(borrowed >= 2 * 7 + 1, "borrowed={borrowed} copied={copied}");
        assert!(!layers[0].experts[0].theta.cs_table().is_empty());
        // heap vs mmap: identical values
        let heap = ModelArtifact::load(&path, LoadMode::Heap).unwrap();
        let hl = heap.build_layers().unwrap();
        assert_eq!(
            layers[1].experts[2].phi.cs_table(),
            hl[1].experts[2].phi.cs_table()
        );
        assert_eq!(layers[0].substrate.plus_words(), hl[0].substrate.plus_words());
    }

    #[test]
    fn load_rejects_non_model_containers() {
        // a plain tensor store without __model__ must fail cleanly
        let path = tmp("plain.bmoe");
        let mut s = crate::tensor::store::TensorStore::default();
        s.insert(
            "w",
            crate::tensor::store::Entry::F32(Tensor::from_vec(&[2], vec![1.0, 2.0])),
        );
        s.write(&path).unwrap();
        assert!(ModelArtifact::load(&path, LoadMode::Heap).is_err());
    }

    #[test]
    fn integrity_record_roundtrips_and_verifies() {
        let model = synthesize(&tiny_spec());
        let path = tmp("integrity.bmoe");
        model.pack(&path).unwrap();
        // heap load verifies eagerly; reaching here means it passed
        let art = ModelArtifact::load(&path, LoadMode::Heap).unwrap();
        let integ = art.integrity.as_ref().expect("packer records integrity");
        assert!(integ.payload_bytes > 0);
        assert!(
            integ.checksums.contains_key("embed")
                && integ.checksums.contains_key("layers.1.w_down"),
            "per-tensor checksums recorded: {:?}",
            integ.checksums.keys().collect::<Vec<_>>()
        );
        assert!(!integ.checksums.keys().any(|k| k.starts_with("__pad.")));
        art.verify_checksums().unwrap();
        // explicit verification works in both modes
        ModelArtifact::load_verified(&path, LoadMode::Mmap).unwrap();
        ModelArtifact::load_verified(&path, LoadMode::Heap).unwrap();
    }

    #[test]
    fn truncated_artifact_is_rejected_cleanly() {
        let model = synthesize(&tiny_spec());
        let packed = tmp("trunc_src.bmoe");
        model.pack(&packed).unwrap();
        let mut bytes = std::fs::read(&packed).unwrap();
        bytes.truncate(bytes.len() - 100);
        let path = tmp("trunc.bmoe");
        std::fs::write(&path, &bytes).unwrap();
        for mode in [LoadMode::Heap, LoadMode::Mmap] {
            let err = ModelArtifact::load(&path, mode).unwrap_err();
            assert!(
                format!("{err:#}").contains("truncated"),
                "{mode:?}: {err:#}"
            );
        }
    }

    #[test]
    fn bit_flip_is_caught_before_any_decode() {
        let model = synthesize(&tiny_spec());
        let clean = tmp("flip_src.bmoe");
        model.pack(&clean).unwrap();
        // flip one byte inside a known tensor payload (not the directory)
        let off = {
            let art = ModelArtifact::load(&clean, LoadMode::Heap).unwrap();
            art.store().entry("embed").unwrap().off
        };
        let mut bytes = std::fs::read(&clean).unwrap();
        bytes[off + 5] ^= 0x40;
        let path = tmp("flip.bmoe");
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelArtifact::load(&path, LoadMode::Heap).unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum mismatch"),
            "heap load must verify eagerly: {err:#}"
        );
        if Mmap::supported() {
            // mmap load stays lazy (no eager page-in) but opt-in
            // verification catches the same corruption
            let art = ModelArtifact::load(&path, LoadMode::Mmap).unwrap();
            assert!(art.verify_checksums().is_err());
            assert!(ModelArtifact::load_verified(&path, LoadMode::Mmap).is_err());
        }
    }

    #[test]
    fn artifacts_without_checksums_still_load() {
        // a pre-integrity artifact: plain manifest JSON, no checksum keys
        let m = tiny_spec().manifest();
        let json = m.to_json();
        let path = tmp("legacy.bmoe");
        let mut s = crate::tensor::store::TensorStore::default();
        s.insert(
            MANIFEST_TENSOR,
            crate::tensor::store::Entry::U8 {
                shape: vec![json.len()],
                data: json.clone().into_bytes(),
            },
        );
        s.write(&path).unwrap();
        let art = ModelArtifact::load(&path, LoadMode::Heap).unwrap();
        assert!(art.integrity.is_none(), "legacy manifest has no integrity");
        assert_eq!(art.manifest, m);
        // but explicit verification of an unverifiable artifact is an error
        let err = ModelArtifact::load_verified(&path, LoadMode::Heap).unwrap_err();
        assert!(format!("{err:#}").contains("no checksums"), "{err:#}");
    }

    #[test]
    fn preflight_rejects_wrong_payload_accounting() {
        // integrity claims far more payload than the file holds — the
        // missing-tensor shape of truncation, caught before any decode
        let m = tiny_spec().manifest();
        let mut json = m.to_json();
        json.pop();
        json.push_str(",\"payload_bytes\":999999,\"payload_crc\":0,\"checksums\":{}}");
        let path = tmp("preflight.bmoe");
        let mut s = crate::tensor::store::TensorStore::default();
        s.insert(
            MANIFEST_TENSOR,
            crate::tensor::store::Entry::U8 {
                shape: vec![json.len()],
                data: json.into_bytes(),
            },
        );
        s.insert(
            "embed",
            crate::tensor::store::Entry::F32(Tensor::from_vec(&[2], vec![1.0, 2.0])),
        );
        s.write(&path).unwrap();
        let err = ModelArtifact::load(&path, LoadMode::Heap).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated or tensors missing"),
            "{err:#}"
        );
    }

    #[test]
    fn synthesize_is_deterministic() {
        let a = synthesize(&tiny_spec());
        let b = synthesize(&tiny_spec());
        assert_eq!(a.embed.data, b.embed.data);
        assert_eq!(
            a.layers[1].experts[3].theta.cs_table(),
            b.layers[1].experts[3].theta.cs_table()
        );
        let mut other = tiny_spec();
        other.seed = 8;
        let c = synthesize(&other);
        assert_ne!(a.embed.data, c.embed.data);
    }
}
