//! Zero-copy reader over the BMOE1 container (DESIGN.md §3).
//!
//! [`crate::tensor::store::TensorStore`] deserializes every tensor into
//! owned memory — right for checkpoints, wrong for cold-starting a
//! model: a multi-layer artifact is dominated by the per-expert angle
//! tables and dense projections, and copying them on every serve start
//! is exactly the deserialization pass the mmap path exists to skip.
//! [`MappedStore`] parses only the container *directory* (names, dtypes,
//! shapes, data ranges — a few hundred bytes) and hands out
//! [`SharedSlice`]s that reference the backing bytes in place.
//!
//! Data offsets in a BMOE1 file are not naturally aligned (headers have
//! byte granularity), so the model packer inserts `__pad.*` filler
//! tensors to 64-align the bulk tensors (see `super::pack`).  Files
//! written without pads (e.g. by `python/compile/bmoe_io.py`) still
//! load — misaligned tensors silently take the decode-copy path with
//! identical values.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::mmapfile::Mmap;
use super::shared::{Backing, Pod, SharedSlice};

pub const MAGIC: &[u8; 6] = b"BMOE1\x00";

/// dtype codes of the BMOE1 container (normative list in DESIGN.md §3).
pub const DTYPE_F32: u8 = 0;
pub const DTYPE_I32: u8 = 1;
pub const DTYPE_U8: u8 = 2;

/// How to load a model file (the `--load` serving flag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadMode {
    /// `mmap` the file and borrow tensor data in place: cold start is
    /// page faults, and concurrent processes share page-cache pages.
    Mmap,
    /// Read the file and eagerly decode every tensor into owned memory —
    /// the deserialization baseline the cold-start bench compares
    /// against.  Bit-identical values to [`LoadMode::Mmap`].
    Heap,
}

impl LoadMode {
    pub fn parse(s: &str) -> Result<LoadMode> {
        Ok(match s {
            "mmap" => LoadMode::Mmap,
            "heap" => LoadMode::Heap,
            _ => bail!("unknown load mode '{s}' (expected mmap|heap)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            LoadMode::Mmap => "mmap",
            LoadMode::Heap => "heap",
        }
    }
}

/// One directory entry: where a tensor's bytes live in the backing.
#[derive(Clone, Debug)]
pub struct RawEntry {
    pub name: String,
    pub dtype: u8,
    pub shape: Vec<usize>,
    /// byte offset of the data payload in the file
    pub off: usize,
    /// payload length in bytes
    pub byte_len: usize,
}

impl RawEntry {
    pub fn elems(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape.iter().product()
        }
    }
}

/// Directory over a BMOE1 file plus its shared backing bytes.
pub struct MappedStore {
    backing: Arc<Backing>,
    entries: Vec<RawEntry>,
    index: BTreeMap<String, usize>,
    mode: LoadMode,
    /// tensors handed out as in-place borrows vs decoded copies (the
    /// quickstart/bench zero-copy report)
    borrowed: std::sync::atomic::AtomicUsize,
    copied: std::sync::atomic::AtomicUsize,
}

impl MappedStore {
    /// Open `path` in the given mode and parse the directory.
    pub fn open(path: &Path, mode: LoadMode) -> Result<MappedStore> {
        let backing = match mode {
            LoadMode::Mmap => Backing::Mapped(Mmap::map(path)?),
            LoadMode::Heap => {
                let mut bytes =
                    std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
                // chaos hook (inert without a fault plan): simulate bit
                // rot on the loaded image to exercise the checksum path
                if let Some(off) = crate::faults::artifact_bitflip(&mut bytes) {
                    crate::obs::log(
                        "faults",
                        &format!("flipped artifact byte at offset {off} of {}", path.display()),
                    );
                }
                Backing::Heap(bytes)
            }
        };
        Self::parse(Arc::new(backing), mode).with_context(|| format!("parse {}", path.display()))
    }

    fn parse(backing: Arc<Backing>, mode: LoadMode) -> Result<MappedStore> {
        fn take<'a>(b: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
            anyhow::ensure!(*off + n <= b.len(), "truncated container at byte {off}");
            let s = &b[*off..*off + n];
            *off += n;
            Ok(s)
        }
        let mut entries;
        let mut index = BTreeMap::new();
        {
            let b = backing.bytes();
            anyhow::ensure!(b.len() >= 10, "file too short for a BMOE1 header");
            anyhow::ensure!(&b[..6] == MAGIC, "bad magic {:?}", &b[..6]);
            let count = u32::from_le_bytes([b[6], b[7], b[8], b[9]]) as usize;
            // every entry needs >= 4 header bytes, so a corrupt count
            // field fails here instead of driving a huge preallocation
            anyhow::ensure!(
                count <= (b.len() - 10) / 4,
                "implausible tensor count {count} for a {}-byte file",
                b.len()
            );
            let mut off = 10usize;
            entries = Vec::with_capacity(count);
            for i in 0..count {
                let nlen = {
                    let s = take(b, &mut off, 2)?;
                    u16::from_le_bytes([s[0], s[1]]) as usize
                };
                let name = String::from_utf8(take(b, &mut off, nlen)?.to_vec())
                    .with_context(|| format!("tensor {i}: name not utf-8"))?;
                let hdr = take(b, &mut off, 2)?;
                let (dtype, ndim) = (hdr[0], hdr[1] as usize);
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    let s = take(b, &mut off, 4)?;
                    shape.push(u32::from_le_bytes([s[0], s[1], s[2], s[3]]) as usize);
                }
                // checked size arithmetic: crafted dims must not wrap
                // into a small byte_len that passes the bounds check
                let elems: usize = if ndim == 0 {
                    1
                } else {
                    shape
                        .iter()
                        .try_fold(1usize, |a, &d| a.checked_mul(d))
                        .with_context(|| format!("tensor '{name}': shape {shape:?} overflows"))?
                };
                let itemsize = match dtype {
                    DTYPE_F32 | DTYPE_I32 => 4,
                    DTYPE_U8 => 1,
                    other => bail!("tensor '{name}': unknown dtype code {other}"),
                };
                let byte_len = elems
                    .checked_mul(itemsize)
                    .with_context(|| format!("tensor '{name}': byte length overflows"))?;
                // off <= b.len() after the header takes; subtract-side
                // comparison cannot overflow the way `off + byte_len` can
                anyhow::ensure!(byte_len <= b.len() - off, "tensor '{name}': data truncated");
                index.insert(name.clone(), entries.len());
                entries.push(RawEntry {
                    name,
                    dtype,
                    shape,
                    off,
                    byte_len,
                });
                off += byte_len;
            }
        }
        Ok(MappedStore {
            backing,
            entries,
            index,
            mode,
            borrowed: Default::default(),
            copied: Default::default(),
        })
    }

    pub fn mode(&self) -> LoadMode {
        self.mode
    }

    /// Total bytes of the underlying file image.
    pub fn file_bytes(&self) -> usize {
        self.backing.len()
    }

    pub fn entries(&self) -> &[RawEntry] {
        &self.entries
    }

    pub fn entry(&self, name: &str) -> Result<&RawEntry> {
        self.index
            .get(name)
            .map(|&i| &self.entries[i])
            .with_context(|| format!("tensor '{name}' missing from model artifact"))
    }

    /// `(tensors borrowed in place, tensors decoded to owned copies)`.
    pub fn zero_copy_stats(&self) -> (usize, usize) {
        use std::sync::atomic::Ordering::Relaxed;
        (self.borrowed.load(Relaxed), self.copied.load(Relaxed))
    }

    fn slice<T: Pod>(&self, e: &RawEntry) -> SharedSlice<T> {
        let s = SharedSlice::from_backing(
            &self.backing,
            e.off,
            e.byte_len,
            self.mode == LoadMode::Heap,
        );
        use std::sync::atomic::Ordering::Relaxed;
        if s.is_borrowed() {
            self.borrowed.fetch_add(1, Relaxed);
        } else {
            self.copied.fetch_add(1, Relaxed);
        }
        s
    }

    /// An f32 tensor's shape and (possibly borrowed) data.
    pub fn f32(&self, name: &str) -> Result<(Vec<usize>, SharedSlice<f32>)> {
        let e = self.entry(name)?;
        anyhow::ensure!(e.dtype == DTYPE_F32, "tensor '{name}' is not f32");
        Ok((e.shape.clone(), self.slice(e)))
    }

    /// An f32 tensor decoded into an owned `Vec` — for tensors the
    /// caller re-materializes anyway (e.g. gate weights copied into a
    /// `Tensor`), so the zero-copy telemetry counts them as copies, not
    /// borrows.
    pub fn f32_owned(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let e = self.entry(name)?;
        anyhow::ensure!(e.dtype == DTYPE_F32, "tensor '{name}' is not f32");
        let b = &self.backing.bytes()[e.off..e.off + e.byte_len];
        let v = b
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        self.copied.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok((e.shape.clone(), v))
    }

    /// A scalar f32 (rank 0 or single-element) tensor's value.
    pub fn f32_scalar(&self, name: &str) -> Result<f32> {
        let (_, s) = self.f32(name)?;
        anyhow::ensure!(s.len() == 1, "tensor '{name}' is not a scalar");
        Ok(s.as_slice()[0])
    }

    /// A U8 tensor reinterpreted as little-endian u64 words (the packed
    /// bitplane encoding; DESIGN.md §3).  The byte length must be a
    /// multiple of 8.
    pub fn u64_words(&self, name: &str) -> Result<(Vec<usize>, SharedSlice<u64>)> {
        let e = self.entry(name)?;
        anyhow::ensure!(e.dtype == DTYPE_U8, "tensor '{name}' is not u8");
        anyhow::ensure!(
            e.byte_len % 8 == 0,
            "tensor '{name}': {} bytes is not a whole number of u64 words",
            e.byte_len
        );
        Ok((e.shape.clone(), self.slice(e)))
    }

    /// Raw payload bytes (the embedded JSON manifest).
    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        let e = self.entry(name)?;
        Ok(&self.backing.bytes()[e.off..e.off + e.byte_len])
    }

    /// An i32 tensor decoded to owned values (fixture metadata; never on
    /// the hot path, so no borrow variant).
    pub fn i32(&self, name: &str) -> Result<(Vec<usize>, Vec<i32>)> {
        let e = self.entry(name)?;
        anyhow::ensure!(e.dtype == DTYPE_I32, "tensor '{name}' is not i32");
        let b = &self.backing.bytes()[e.off..e.off + e.byte_len];
        let v = b
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok((e.shape.clone(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::store::{Entry, TensorStore};
    use crate::tensor::{IntTensor, Tensor};

    fn sample(path: &Path) {
        let mut s = TensorStore::default();
        s.insert(
            "a",
            Entry::F32(Tensor::from_vec(&[2, 2], vec![1.0, -2.0, 0.5, 4.0])),
        );
        s.insert("ids", Entry::I32(IntTensor::from_vec(&[3], vec![5, -6, 7])));
        s.insert(
            "raw",
            Entry::U8 {
                shape: vec![16],
                data: (0..16u8).collect(),
            },
        );
        s.write(path).unwrap();
    }

    #[test]
    fn heap_store_reads_what_tensorstore_wrote() {
        let dir = std::env::temp_dir().join("bmoe_mapped_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.bmoe");
        sample(&path);
        let m = MappedStore::open(&path, LoadMode::Heap).unwrap();
        assert_eq!(m.entries().len(), 3);
        let (shape, a) = m.f32("a").unwrap();
        assert_eq!(shape, vec![2, 2]);
        assert_eq!(a.as_slice(), &[1.0, -2.0, 0.5, 4.0]);
        assert!(!a.is_borrowed(), "heap mode must eagerly copy");
        let (_, ids) = m.i32("ids").unwrap();
        assert_eq!(ids, vec![5, -6, 7]);
        let (shape, words) = m.u64_words("raw").unwrap();
        assert_eq!(shape, vec![16]);
        assert_eq!(words.len(), 2);
        assert!(m.f32("missing").is_err());
        assert!(m.f32("ids").is_err(), "dtype mismatch must error");
        assert_eq!(m.file_bytes(), std::fs::metadata(&path).unwrap().len() as usize);
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    #[test]
    fn mmap_store_matches_heap_store() {
        let dir = std::env::temp_dir().join("bmoe_mapped_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.bmoe");
        sample(&path);
        let heap = MappedStore::open(&path, LoadMode::Heap).unwrap();
        let map = MappedStore::open(&path, LoadMode::Mmap).unwrap();
        let (_, ah) = heap.f32("a").unwrap();
        let (_, am) = map.f32("a").unwrap();
        assert_eq!(ah.as_slice(), am.as_slice());
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("bmoe_mapped_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bmoe");
        std::fs::write(&path, b"NOTBMOE123").unwrap();
        assert!(MappedStore::open(&path, LoadMode::Heap).is_err());
        // truncated: valid magic + count but no entries
        std::fs::write(&path, [&MAGIC[..], &5u32.to_le_bytes()].concat()).unwrap();
        assert!(MappedStore::open(&path, LoadMode::Heap).is_err());
    }
}
