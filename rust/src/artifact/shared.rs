//! Shared zero-copy storage: slices backed either by owned heap memory
//! or by a byte buffer (file mapping / heap file image) shared through
//! an [`Arc`].
//!
//! This is the mechanism that lets the hot-path structures
//! ([`crate::butterfly::Butterfly`]'s (cos, sin) table,
//! [`crate::ternary::BitplaneTernary`]'s bitplanes, the dense
//! projections) reference a model artifact's bytes *in place*: an
//! mmap-loaded model pays page faults on first touch instead of a
//! deserialization pass, and concurrent serve processes mapping the same
//! file share its page-cache pages (see DESIGN.md §3).
//!
//! Borrowing is only performed when it is bit-exact and well-defined:
//! the element type must be 4/8-byte aligned at its absolute address and
//! the host must be little-endian (the on-disk byte order of the BMOE1
//! container).  Otherwise [`SharedSlice::from_backing`] silently decodes
//! into an owned copy — same values, same downstream bits, just without
//! the zero-copy win.

use std::sync::Arc;

use crate::artifact::mmapfile::Mmap;

/// Backing storage shared by every slice borrowed from one loaded file:
/// a read-only file mapping, or the file image read onto the heap.
pub enum Backing {
    Mapped(Mmap),
    Heap(Vec<u8>),
}

impl Backing {
    pub fn bytes(&self) -> &[u8] {
        match self {
            Backing::Mapped(m) => m.bytes(),
            Backing::Heap(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes().is_empty()
    }
}

/// Element types that may be reinterpreted from little-endian file bytes.
/// Sealed to the two the artifact format stores in bulk.
pub trait Pod: Copy + Send + Sync + 'static {
    const WIDTH: usize;
    fn from_le(bytes: &[u8]) -> Self;
}

impl Pod for f32 {
    const WIDTH: usize = 4;
    #[inline]
    fn from_le(b: &[u8]) -> f32 {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl Pod for u64 {
    const WIDTH: usize = 8;
    #[inline]
    fn from_le(b: &[u8]) -> u64 {
        u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
    }
}

/// A `[T]` that is either owned or borrowed from a shared [`Backing`].
///
/// The borrowed form keeps the backing alive through an [`Arc`], so the
/// slice is `'static`-safe to move into layers, backends and worker
/// threads.  [`SharedSlice::as_slice`] is a pointer cast — no copy, no
/// lock — which is what makes it usable from the decode hot path.
pub enum SharedSlice<T: Pod> {
    Owned(Vec<T>),
    Borrowed {
        backing: Arc<Backing>,
        /// byte offset into `backing.bytes()`; absolute address is
        /// `T`-aligned (checked at construction)
        off: usize,
        /// length in elements
        len: usize,
    },
}

impl<T: Pod> SharedSlice<T> {
    pub fn owned(v: Vec<T>) -> Self {
        SharedSlice::Owned(v)
    }

    pub fn len(&self) -> usize {
        match self {
            SharedSlice::Owned(v) => v.len(),
            SharedSlice::Borrowed { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when this slice references the backing in place (the
    /// zero-copy path) rather than an owned decode.
    pub fn is_borrowed(&self) -> bool {
        matches!(self, SharedSlice::Borrowed { .. })
    }

    #[inline]
    pub fn as_slice(&self) -> &[T] {
        match self {
            SharedSlice::Owned(v) => v,
            SharedSlice::Borrowed { backing, off, len } => {
                let bytes = backing.bytes();
                debug_assert!(off + len * T::WIDTH <= bytes.len());
                let ptr = bytes[*off..].as_ptr();
                debug_assert_eq!(ptr as usize % std::mem::align_of::<T>(), 0);
                // SAFETY: bounds and alignment were checked at
                // construction (and re-asserted above); the backing is
                // immutable and kept alive by the Arc; T is Pod, so any
                // bit pattern is a valid value.
                unsafe { std::slice::from_raw_parts(ptr as *const T, *len) }
            }
        }
    }

    /// Build from `byte_len` bytes at `off` in `backing`.  Borrows in
    /// place when the absolute address is `T`-aligned on a little-endian
    /// host and `force_copy` is false; otherwise decodes an owned copy
    /// (identical values either way).  `byte_len` must be a multiple of
    /// `T::WIDTH` and in bounds (checked by the caller, re-asserted).
    pub fn from_backing(
        backing: &Arc<Backing>,
        off: usize,
        byte_len: usize,
        force_copy: bool,
    ) -> Self {
        assert_eq!(byte_len % T::WIDTH, 0, "byte length not a multiple of element width");
        let bytes = backing.bytes();
        assert!(off + byte_len <= bytes.len(), "tensor data out of bounds");
        let len = byte_len / T::WIDTH;
        let aligned = (bytes[off..].as_ptr() as usize) % std::mem::align_of::<T>() == 0;
        if cfg!(target_endian = "little") && aligned && !force_copy {
            return SharedSlice::Borrowed {
                backing: backing.clone(),
                off,
                len,
            };
        }
        let mut v = Vec::with_capacity(len);
        for chunk in bytes[off..off + byte_len].chunks_exact(T::WIDTH) {
            v.push(T::from_le(chunk));
        }
        SharedSlice::Owned(v)
    }

    /// Element sub-range `[start, start + len)` sharing the same backing
    /// (borrowed stays borrowed; owned copies the sub-range).  Used to
    /// carve per-expert angle tables out of one stacked tensor.
    pub fn sub(&self, start: usize, len: usize) -> SharedSlice<T> {
        assert!(start + len <= self.len(), "sub-slice out of range");
        match self {
            SharedSlice::Owned(v) => SharedSlice::Owned(v[start..start + len].to_vec()),
            SharedSlice::Borrowed { backing, off, .. } => SharedSlice::Borrowed {
                backing: backing.clone(),
                off: off + start * T::WIDTH,
                len,
            },
        }
    }
}

impl<T: Pod> Clone for SharedSlice<T> {
    fn clone(&self) -> Self {
        match self {
            SharedSlice::Owned(v) => SharedSlice::Owned(v.clone()),
            SharedSlice::Borrowed { backing, off, len } => SharedSlice::Borrowed {
                backing: backing.clone(),
                off: *off,
                len: *len,
            },
        }
    }
}

impl<T: Pod> std::fmt::Debug for SharedSlice<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SharedSlice::Owned(v) => write!(f, "SharedSlice::Owned(len={})", v.len()),
            SharedSlice::Borrowed { off, len, .. } => {
                write!(f, "SharedSlice::Borrowed(off={off}, len={len})")
            }
        }
    }
}

/// Row-major f32 tensor over [`SharedSlice`] storage — the shared-or-
/// owned twin of [`crate::tensor::Tensor`], used where a dense parameter
/// (`w_down`, `embed`, `readout`) may be borrowed from a model mapping.
#[derive(Clone, Debug)]
pub struct ShTensor {
    pub shape: Vec<usize>,
    data: SharedSlice<f32>,
}

impl ShTensor {
    pub fn new(shape: Vec<usize>, data: SharedSlice<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != len {}",
            data.len()
        );
        ShTensor { shape, data }
    }

    pub fn from_tensor(t: crate::tensor::Tensor) -> Self {
        ShTensor {
            shape: t.shape,
            data: SharedSlice::owned(t.data),
        }
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        self.data.as_slice()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn is_borrowed(&self) -> bool {
        self.data.is_borrowed()
    }

    /// f32 storage bytes (memory-accounting parity with `Tensor::nbytes`).
    pub fn nbytes(&self) -> usize {
        self.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_roundtrip_and_sub() {
        let s = SharedSlice::owned(vec![1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(s.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(!s.is_borrowed());
        assert_eq!(s.sub(1, 2).as_slice(), &[2.0, 3.0]);
    }

    #[test]
    fn borrowed_from_heap_backing_when_aligned() {
        // a Vec<u8> allocation is at least 8-aligned in practice, but the
        // code must work either way — probe both offsets
        let mut bytes = Vec::new();
        for v in [1.5f32, -2.0, 0.25, 8.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let backing = Arc::new(Backing::Heap(bytes));
        let s: SharedSlice<f32> = SharedSlice::from_backing(&backing, 0, 16, false);
        assert_eq!(s.as_slice(), &[1.5, -2.0, 0.25, 8.0]);
        // force_copy gives the same values without the borrow
        let c: SharedSlice<f32> = SharedSlice::from_backing(&backing, 0, 16, true);
        assert!(!c.is_borrowed());
        assert_eq!(c.as_slice(), s.as_slice());
    }

    #[test]
    fn misaligned_offset_decodes_owned_copy() {
        let mut bytes = vec![0u8]; // 1-byte shim forces misalignment
        for v in [7.0f32, -1.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let backing = Arc::new(Backing::Heap(bytes));
        let s: SharedSlice<f32> = SharedSlice::from_backing(&backing, 1, 8, false);
        // absolute address 1 off the allocation start can never be
        // 4-aligned, so this must have fallen back to the copy path
        assert_eq!(s.as_slice(), &[7.0, -1.0]);
    }

    #[test]
    fn u64_words_roundtrip() {
        let words = [0xDEAD_BEEF_0123_4567u64, u64::MAX, 0];
        let mut bytes = Vec::new();
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let backing = Arc::new(Backing::Heap(bytes));
        let s: SharedSlice<u64> = SharedSlice::from_backing(&backing, 0, 24, false);
        assert_eq!(s.as_slice(), &words);
        let c: SharedSlice<u64> = SharedSlice::from_backing(&backing, 0, 24, true);
        assert_eq!(c.as_slice(), &words);
    }

    #[test]
    fn shtensor_shape_checked() {
        let t = ShTensor::new(vec![2, 2], SharedSlice::owned(vec![0.0f32; 4]));
        assert_eq!(t.nbytes(), 16);
        assert!(!t.is_borrowed());
    }

    #[test]
    #[should_panic]
    fn shtensor_shape_mismatch_panics() {
        ShTensor::new(vec![3], SharedSlice::owned(vec![0.0f32; 4]));
    }
}
