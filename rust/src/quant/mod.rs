//! AbsMean ternary quantization (eq. 5) and error metrics — the Rust
//! mirror of `python/compile/quant.py`.

use crate::tensor::Tensor;

pub const EPS: f32 = 1e-8;

/// Result of ternarizing a weight tensor.
#[derive(Clone, Debug)]
pub struct TernaryQuant {
    /// {-1, 0, +1} stored as i8, same shape/order as the source
    pub q: Vec<i8>,
    pub shape: Vec<usize>,
    /// AbsMean scale
    pub gamma: f32,
}

/// gamma = mean(|w|)  (eq. 5).
pub fn absmean_scale(w: &[f32]) -> f32 {
    if w.is_empty() {
        return EPS;
    }
    w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32 + EPS
}

/// Quantize to {-1, 0, +1} with AbsMean scaling.
pub fn ternary_quantize(t: &Tensor) -> TernaryQuant {
    let gamma = absmean_scale(&t.data);
    let q = t
        .data
        .iter()
        .map(|&v| {
            let r = (v / gamma).round();
            r.clamp(-1.0, 1.0) as i8
        })
        .collect();
    TernaryQuant {
        q,
        shape: t.shape.clone(),
        gamma,
    }
}

impl TernaryQuant {
    /// Dequantize back to f32 (gamma * q).
    pub fn dequantize(&self) -> Tensor {
        Tensor::from_vec(
            &self.shape,
            self.q.iter().map(|&v| v as f32 * self.gamma).collect(),
        )
    }

    /// Fraction of zero weights (sparsity of the ternary grid).
    pub fn zero_fraction(&self) -> f64 {
        if self.q.is_empty() {
            return 0.0;
        }
        self.q.iter().filter(|&&v| v == 0).count() as f64 / self.q.len() as f64
    }
}

/// Relative weight quantization MSE: ||Q(W)-W||^2 / ||W||^2 — the Fig. 4
/// weight-space metric (paper reports it as a percentage).
pub fn weight_quant_error(w: &Tensor) -> f64 {
    let tq = ternary_quantize(w);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&wv, &qv) in w.data.iter().zip(&tq.q) {
        let dq = qv as f32 * tq.gamma;
        num += ((dq - wv) as f64).powi(2);
        den += (wv as f64).powi(2);
    }
    num / (den + EPS as f64)
}

/// Relative output error between a quantized and a full-precision forward
/// (Fig. 4's activation-aware metric): ||y_q - y_fp||^2 / ||y_fp||^2.
pub fn output_quant_error(y_q: &[f32], y_fp: &[f32]) -> f64 {
    assert_eq!(y_q.len(), y_fp.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&a, &b) in y_q.iter().zip(y_fp) {
        num += ((a - b) as f64).powi(2);
        den += (b as f64).powi(2);
    }
    num / (den + EPS as f64)
}

/// Histogram of w/gamma values (Fig. 4 top panels: how tightly the latent
/// substrate clusters around the ternary grid).
pub fn scaled_weight_histogram(w: &Tensor, bins: usize, lo: f32, hi: f32) -> Vec<u64> {
    let gamma = absmean_scale(&w.data);
    let mut h = vec![0u64; bins];
    let width = (hi - lo) / bins as f32;
    for &v in &w.data {
        let x = v / gamma;
        let idx = ((x - lo) / width).floor();
        let idx = (idx.max(0.0) as usize).min(bins - 1);
        h[idx] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quantize_values_are_ternary() {
        let mut rng = Rng::new(0);
        let t = Tensor::rand_normal(&[32, 16], 1.0, &mut rng);
        let tq = ternary_quantize(&t);
        assert!(tq.q.iter().all(|&v| (-1..=1).contains(&v)));
        assert!(tq.gamma > 0.0);
    }

    #[test]
    fn absmean_matches_hand_value() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, -3.0, 0.0, 4.0]);
        let tq = ternary_quantize(&t);
        assert!((tq.gamma - 2.0).abs() < 1e-5);
        // 1/2 rounds to 0 (ties-away is .5 -> 1 in rust; 0.5.round()=1)
        assert_eq!(tq.q, vec![1, -1, 0, 1]);
    }

    #[test]
    fn exact_ternary_has_zero_error() {
        // mean|w| = gamma exactly when all entries are ±gamma
        let t = Tensor::from_vec(&[4], vec![0.5, -0.5, 0.5, -0.5]);
        assert!(weight_quant_error(&t) < 1e-9);
    }

    #[test]
    fn heavy_tails_have_large_error() {
        let mut rng = Rng::new(1);
        let mut t = Tensor::rand_normal(&[64, 64], 1.0, &mut rng);
        for v in t.data.iter_mut() {
            *v = v.powi(3); // heavy-tailed
        }
        assert!(weight_quant_error(&t) > 0.05);
    }

    #[test]
    fn dequantize_roundtrip_on_grid() {
        let t = Tensor::from_vec(&[3], vec![0.25, 0.0, -0.25]);
        let tq = ternary_quantize(&t);
        let back = tq.dequantize();
        for (a, b) in back.data.iter().zip(&t.data) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }

    #[test]
    fn output_error_zero_when_equal() {
        let y = vec![1.0f32, 2.0, 3.0];
        assert_eq!(output_quant_error(&y, &y), 0.0);
    }

    #[test]
    fn histogram_total_and_peak() {
        let mut rng = Rng::new(2);
        let t = Tensor::rand_normal(&[1000], 0.02, &mut rng);
        let h = scaled_weight_histogram(&t, 9, -4.5, 4.5);
        assert_eq!(h.iter().sum::<u64>(), 1000);
        // tight gaussian w/ absmean scaling spreads to ±~2 around 0; the
        // center bin should dominate the extremes
        assert!(h[4] > h[0] && h[4] > h[8]);
    }

    #[test]
    fn zero_fraction_sane() {
        let mut rng = Rng::new(3);
        let t = Tensor::rand_normal(&[4096], 1.0, &mut rng);
        let z = ternary_quantize(&t).zero_fraction();
        // For N(0,1) with gamma = E|w| ≈ 0.798, P(|w| < gamma/2) ≈ 0.31
        assert!(z > 0.2 && z < 0.45, "zero fraction {z}");
    }
}
