//! # ButterflyMoE
//!
//! Production-grade reproduction of *"ButterflyMoE: Sub-Linear Ternary
//! Experts via Structured Butterfly Orbits"* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — generation-session serving coordinator
//!   (continuous batching, seeded sampling, streaming token events —
//!   see [`coordinator`]), fleet front-door router (supervised multi-
//!   worker serving over one shared mmap substrate — see [`router`]),
//!   native edge inference engine (packed ternary
//!   + butterfly orbits, multi-layer residual LM), mmap-backed model
//!   artifacts (pack + zero-copy load — see [`artifact`]), PJRT runtime
//!   for the AOT-compiled jax graphs, training driver, and every
//!   analysis substrate the paper's evaluation needs (memory models,
//!   energy models, device profiles, baselines).
//! * **L2 (`python/compile/model.py`)** — the jax transformer-LM with
//!   ButterflyMoE FFNs, lowered once to `artifacts/*.hlo.txt`.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the fused
//!   butterfly transform and ternary matmul (interpret-lowered).
//!
//! Python runs only at build time (`make artifacts`); the `bmoe` binary
//! is self-contained afterwards.  See DESIGN.md for the system inventory
//! and the experiment index mapping every paper table/figure to code.
//!
//! ## Serving in five lines
//!
//! ```ignore
//! let coord = Coordinator::start(backend, SchedulerConfig::default());
//! let rx = coord.submit(
//!     GenerateRequest::greedy(vec![1, 2, 3], 16)
//!         .with_sampling(SamplingParams::temperature(0.8, 42)),
//! );
//! for event in rx { /* TokenEvent::Token ... then TokenEvent::Done */ }
//! ```
//!
//! Requests are **sessions**: the coordinator keeps each sequence
//! resident across decode steps (continuous batching — finished
//! sequences leave, queued ones join between steps), streams every
//! token as it is decoded, and reports TTFT / inter-token latency /
//! tokens-per-second in [`coordinator::Metrics`].

pub mod artifact;
pub mod baselines;
pub mod bench;
pub mod butterfly;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod devices;
pub mod energy;
pub mod expertcache;
pub mod faults;
pub mod jsonx;
pub mod kernels;
pub mod memmodel;
pub mod moe;
pub mod obs;
pub mod parallel;
pub mod quant;
pub mod router;
pub mod runtime;
pub mod tensor;
pub mod ternary;
#[cfg(any(test, feature = "testutil"))]
pub mod testutil;
pub mod train;
pub mod util;
