//! Working implementations of the Table 1 comparator compression methods,
//! applied to *real* expert weight tensors.
//!
//! The paper's Table 1 cites each method's published compression ratio;
//! `memmodel::Method` reproduces those numbers analytically.  This module
//! additionally *builds* a faithful-in-spirit version of each pipeline so
//! the repo can measure real bytes and real reconstruction error on the
//! same weights (bench `table1_compression` prints both):
//!
//! * [`moqe_compress`] — 2-bit weight-only groupwise quantization
//!   (MoQE, Kim et al. 2023).
//! * [`qmoe_compress`] — aggressive ternarization + entropy coding
//!   (QMoE, Frantar & Alistarh 2023, modeled as ternary + DEFLATE; QMoE's
//!   custom dictionary codec achieves sub-1-bit on *trained sparse*
//!   weights — DEFLATE recovers most of that entropy gap).
//! * [`puzzlemoe_compress`] — expert pair merging + per-expert sign/delta
//!   masks (PuzzleMoE, Zhao et al. 2025, simplified).
//! * [`mc_compress`] — mixed-precision assignment by expert importance
//!   (Mixture Compressor, Huang et al. 2024, simplified).

use std::io::Write as _;

use crate::tensor::Tensor;

/// Result of compressing a set of expert matrices.
#[derive(Clone, Debug)]
pub struct CompressionResult {
    pub method: &'static str,
    pub bytes: usize,
    /// mean relative reconstruction MSE across experts
    pub recon_error: f64,
}

impl CompressionResult {
    pub fn ratio_vs_fp32(&self, experts: &[Tensor]) -> f64 {
        let raw: usize = experts.iter().map(Tensor::nbytes).sum();
        raw as f64 / self.bytes as f64
    }
}

fn rel_mse(a: &Tensor, b: &Tensor) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (&x, &y) in a.data.iter().zip(&b.data) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    num / den.max(1e-12)
}

// ---------------------------------------------------------------------------
// MoQE: 2-bit groupwise
// ---------------------------------------------------------------------------

/// 2-bit quantization with per-group (row) absmax scaling: 4 levels
/// {-1, -1/3, +1/3, +1} * scale.
pub fn moqe_compress(experts: &[Tensor]) -> CompressionResult {
    let mut bytes = 0usize;
    let mut err = 0.0;
    for w in experts {
        let rows = w.shape[0];
        let cols = w.shape[1];
        let mut recon = Tensor::zeros(&w.shape);
        for r in 0..rows {
            let row = w.row(r);
            let scale = row.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
            for (c, &v) in row.iter().enumerate() {
                // quantize to nearest of {-1,-1/3,1/3,1}
                let q = (v / scale).clamp(-1.0, 1.0);
                let lvl = ((q + 1.0) * 1.5).round().clamp(0.0, 3.0); // 0..3
                let deq = lvl / 1.5 - 1.0;
                recon.data[r * cols + c] = deq * scale;
            }
            bytes += cols.div_ceil(4) + 2; // 2 bits/w + fp16 scale
        }
        err += rel_mse(&recon, w);
    }
    CompressionResult {
        method: "MoQE (2-bit)",
        bytes,
        recon_error: err / experts.len() as f64,
    }
}

// ---------------------------------------------------------------------------
// QMoE: ternary + entropy coding
// ---------------------------------------------------------------------------

pub fn qmoe_compress(experts: &[Tensor]) -> CompressionResult {
    let mut bytes = 0usize;
    let mut err = 0.0;
    for w in experts {
        let tq = crate::quant::ternary_quantize(w);
        let packed = crate::ternary::PackedTernary::from_quant(&tq);
        // DEFLATE the 2-bit stream: trained ternary weights are ~1/3
        // zeros, so entropy < 2 bits/weight.
        let mut enc =
            flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::best());
        enc.write_all(&packed.data).unwrap();
        let compressed = enc.finish().unwrap();
        bytes += compressed.len() + 4;
        err += rel_mse(&tq.dequantize(), w);
    }
    CompressionResult {
        method: "QMoE",
        bytes,
        recon_error: err / experts.len() as f64,
    }
}

// ---------------------------------------------------------------------------
// PuzzleMoE: merge expert pairs + per-expert 3-bit delta
// ---------------------------------------------------------------------------

pub fn puzzlemoe_compress(experts: &[Tensor]) -> CompressionResult {
    let n = experts.len();
    let mut bytes = 0usize;
    let mut err = 0.0;
    let cols = experts[0].shape[1];
    for pair in experts.chunks(2) {
        let a = &pair[0];
        if pair.len() == 1 {
            bytes += a.len() * 2; // unpaired expert kept at fp16
            continue;
        }
        let b = &pair[1];
        // shared mean at fp16
        bytes += a.len() * 2;
        // per-expert 3-bit delta codes
        bytes += 2 * (a.len() * 3).div_ceil(8);
        // reconstruction: mean + 8-level delta of (w - mean)
        let mut recon_a = Tensor::zeros(&a.shape);
        let mut recon_b = Tensor::zeros(&b.shape);
        let mut delta_scale = 0.0f32;
        for i in 0..a.len() {
            delta_scale = delta_scale.max((a.data[i] - b.data[i]).abs() / 2.0);
        }
        let delta_scale = delta_scale.max(1e-12);
        for i in 0..a.len() {
            let mean = 0.5 * (a.data[i] + b.data[i]);
            for (src, dst) in [(a, &mut recon_a), (b, &mut recon_b)] {
                let d = src.data[i] - mean;
                let lvl = ((d / delta_scale + 1.0) * 3.5).round().clamp(0.0, 7.0);
                let deq = (lvl / 3.5 - 1.0) * delta_scale;
                dst.data[i] = mean + deq;
            }
        }
        let _ = cols;
        err += rel_mse(&recon_a, a) + rel_mse(&recon_b, b);
    }
    CompressionResult {
        method: "PuzzleMoE",
        bytes,
        recon_error: err / n as f64,
    }
}

// ---------------------------------------------------------------------------
// Mixture Compressor: mixed precision by importance
// ---------------------------------------------------------------------------

/// Importance = expert weight-norm rank; top third gets 4 bits, middle
/// 3 bits, rest 2 bits (avg ~2.5-3 bits as MC reports ~2.54).
pub fn mc_compress(experts: &[Tensor]) -> CompressionResult {
    let n = experts.len();
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = experts
        .iter()
        .map(|w| w.data.iter().map(|&v| (v as f64) * (v as f64)).sum())
        .collect();
    order.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
    let mut bits = vec![2u32; n];
    for (rank, &e) in order.iter().enumerate() {
        bits[e] = if rank < n / 3 {
            4
        } else if rank < 2 * n / 3 {
            3
        } else {
            2
        };
    }
    let mut bytes = 0usize;
    let mut err = 0.0;
    for (w, &b) in experts.iter().zip(&bits) {
        bytes += (w.len() * b as usize).div_ceil(8) + 2 * w.shape[0]; // + row scales
        // uniform quantizer at b bits, per-row absmax
        let levels = (1u32 << b) as f32 - 1.0;
        let rows = w.shape[0];
        let cols = w.shape[1];
        let mut recon = Tensor::zeros(&w.shape);
        for r in 0..rows {
            let row = w.row(r);
            let scale = row.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
            for (c, &v) in row.iter().enumerate() {
                let q = ((v / scale + 1.0) / 2.0 * levels).round().clamp(0.0, levels);
                recon.data[r * cols + c] = (q / levels * 2.0 - 1.0) * scale;
            }
        }
        err += rel_mse(&recon, w);
    }
    CompressionResult {
        method: "MC",
        bytes,
        recon_error: err / n as f64,
    }
}

/// ButterflyMoE's own measured storage for the same expert count: packed
/// substrate + fp16 angles (the real deployable bytes, not the formula).
pub fn butterfly_measured_bytes(
    n_experts: usize,
    d_model: usize,
    d_ff: usize,
    packed_substrate_bytes: usize,
) -> usize {
    let angles = d_model / 2 * crate::util::log2_exact(d_model) as usize
        + d_ff / 2 * crate::util::log2_exact(d_ff) as usize;
    packed_substrate_bytes + n_experts * angles * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn experts(n: usize, rows: usize, cols: usize) -> Vec<Tensor> {
        let mut rng = Rng::new(42);
        (0..n)
            .map(|_| Tensor::rand_normal(&[rows, cols], 0.05, &mut rng))
            .collect()
    }

    #[test]
    fn moqe_is_about_16x() {
        let e = experts(4, 64, 128);
        let r = moqe_compress(&e);
        let ratio = r.ratio_vs_fp32(&e);
        // 2 bits + scales ~ 15-16x vs fp32
        assert!(ratio > 12.0 && ratio < 17.0, "ratio {ratio}");
        // absmax row scaling of gaussian weights at 4 levels: ~0.3 rel MSE
        assert!(r.recon_error < 0.5, "err {}", r.recon_error);
    }

    #[test]
    fn qmoe_beats_2bit_packing() {
        let e = experts(4, 64, 128);
        let r = qmoe_compress(&e);
        let packed_2bit: usize = e.iter().map(|w| w.len() / 4).sum();
        assert!(r.bytes < packed_2bit, "{} vs {}", r.bytes, packed_2bit);
        let ratio = r.ratio_vs_fp32(&e);
        assert!(ratio > 16.0, "ratio {ratio}");
    }

    #[test]
    fn puzzlemoe_is_about_2x_to_4x() {
        let e = experts(4, 64, 128);
        let r = puzzlemoe_compress(&e);
        let ratio = r.ratio_vs_fp32(&e);
        assert!(ratio > 1.5 && ratio < 5.0, "ratio {ratio}");
    }

    #[test]
    fn mc_between_moqe_and_puzzle() {
        let e = experts(6, 64, 128);
        let mc = mc_compress(&e).ratio_vs_fp32(&e);
        let pz = puzzlemoe_compress(&e).ratio_vs_fp32(&e);
        assert!(mc > pz, "mc {mc} vs puzzle {pz}");
    }

    #[test]
    fn better_precision_less_error() {
        let e = experts(6, 32, 64);
        let mc = mc_compress(&e);
        let qm = qmoe_compress(&e);
        // ternary (1.58 bit) loses more than mixed 2-4 bit
        assert!(qm.recon_error > mc.recon_error);
    }

    #[test]
    fn butterfly_measured_smaller_than_all() {
        let e = experts(8, 64, 128);
        let sub = 64 * 128 / 4; // 2-bit packed substrate
        let bf = butterfly_measured_bytes(8, 64, 128, sub);
        for r in [
            moqe_compress(&e),
            qmoe_compress(&e),
            puzzlemoe_compress(&e),
            mc_compress(&e),
        ] {
            assert!(bf < r.bytes, "{}: {} vs butterfly {}", r.method, r.bytes, bf);
        }
    }
}
