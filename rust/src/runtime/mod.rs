//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute from
//! the Rust request path.  The interchange contract (HLO text + manifest
//! + BMOE params) is documented in `python/compile/aot.py`.

pub mod engine;
pub mod exec_thread;
pub mod manifest;

pub use engine::Engine;
pub use exec_thread::{spawn_engine_thread, EngineHandle};
pub use manifest::{ArtifactSpec, IoSpec, Manifest};

use anyhow::{bail, Context, Result};

use crate::tensor::{IntTensor, Tensor};

/// Host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(t) => &t.shape,
        }
    }

    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(Tensor::from_vec(&[], vec![x]))
    }
    pub fn scalar_i32(x: i32) -> Value {
        Value::I32(IntTensor::from_vec(&[], vec![x]))
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("value is not f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&IntTensor> {
        match self {
            Value::I32(t) => Ok(t),
            _ => bail!("value is not i32"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Value::F32(t) => {
                if t.shape.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    xla::Literal::vec1(&t.data).reshape(&dims)?
                }
            }
            Value::I32(t) => {
                if t.shape.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    xla::Literal::vec1(&t.data).reshape(&dims)?
                }
            }
        })
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Value> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit.to_vec::<f32>()?;
                Ok(Value::F32(Tensor::from_vec(&dims, data)))
            }
            xla::ElementType::S32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(Value::I32(IntTensor::from_vec(&dims, data)))
            }
            ty => bail!("unsupported literal dtype {ty:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes() {
        let v = Value::F32(Tensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), &[2, 3]);
        assert!(v.as_f32().is_ok());
        assert!(v.as_i32().is_err());
        let s = Value::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
    }
}
