//! Engine execution thread.
//!
//! The published `xla` crate's client/executable types are `!Send`
//! (internal `Rc`s over the PJRT C handles), so the engine is pinned to a
//! dedicated thread that owns it outright — the standard one-executor-
//! per-accelerator layout.  Worker threads talk to it through a cloneable
//! [`EngineHandle`]; requests are serialized at the device boundary,
//! which on a single CPU PJRT device is where they would serialize
//! anyway.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::{Engine, Value};

enum Job {
    Run {
        artifact: String,
        /// Shared immutable input prefix (model parameters): crossing
        /// the channel costs a refcount bump, not a weight copy.
        prefix: Arc<Vec<Value>>,
        extra: Vec<Value>,
        reply: Sender<Result<Vec<Value>>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the engine thread.
pub struct EngineHandle {
    tx: Mutex<Sender<Job>>,
}

impl EngineHandle {
    /// Execute an artifact and wait for its outputs.
    pub fn run(&self, artifact: &str, inputs: Vec<Value>) -> Result<Vec<Value>> {
        self.run_with_prefix(artifact, Arc::new(Vec::new()), inputs)
    }

    /// Execute with a shared parameter prefix followed by per-call
    /// inputs — the decode-loop hot path, which would otherwise deep-copy
    /// every weight tensor once per step.
    pub fn run_with_prefix(
        &self,
        artifact: &str,
        prefix: Arc<Vec<Value>>,
        extra: Vec<Value>,
    ) -> Result<Vec<Value>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Run {
                artifact: artifact.to_string(),
                prefix,
                extra,
                reply: rtx,
            })
            .context("engine thread gone")?;
        rrx.recv().context("engine thread dropped reply")?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Job::Shutdown);
    }
}

/// Start the engine thread: the PJRT client and executables are `!Send`,
/// so the [`Engine`] is *created inside* the thread and never leaves it.
/// Blocks until the engine has initialized (or failed).
pub fn spawn_engine_thread(
    artifacts_dir: &std::path::Path,
) -> Result<(std::sync::Arc<EngineHandle>, std::thread::JoinHandle<()>)> {
    let (tx, rx): (Sender<Job>, Receiver<Job>) = mpsc::channel();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let dir = artifacts_dir.to_path_buf();
    let join = std::thread::Builder::new()
        .name("bmoe-engine".into())
        .spawn(move || {
            let engine = match Engine::new(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            for job in rx {
                match job {
                    Job::Run {
                        artifact,
                        prefix,
                        extra,
                        reply,
                    } => {
                        let result = engine.run_parts(&artifact, &prefix, &extra);
                        let _ = reply.send(result);
                    }
                    Job::Shutdown => break,
                }
            }
        })
        .expect("spawn engine thread");
    ready_rx
        .recv()
        .context("engine thread died during init")??;
    Ok((
        std::sync::Arc::new(EngineHandle { tx: Mutex::new(tx) }),
        join,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn engine_thread_roundtrip() {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest = super::super::Manifest::load(&dir).unwrap();
        let mut inputs = manifest.load_params("tiny.ffn").unwrap();
        let spec = manifest.artifact("tiny__moe_fwd_t16").unwrap();
        let shape = spec.inputs.last().unwrap().shape.clone();
        let mut rng = crate::util::Rng::new(0);
        inputs.push(Value::F32(crate::tensor::Tensor::rand_normal(
            &shape, 1.0, &mut rng,
        )));
        let (handle, join) = spawn_engine_thread(&dir).unwrap();
        // run from several threads concurrently
        let results: Vec<_> = std::thread::scope(|s| {
            (0..4)
                .map(|_| {
                    let handle = &handle;
                    let inputs = inputs.clone();
                    s.spawn(move || handle.run("tiny__moe_fwd_t16", inputs).unwrap())
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for out in &results {
            assert_eq!(out[0].as_f32().unwrap().shape, vec![16, 64]);
        }
        handle.shutdown();
        join.join().unwrap();
    }
}
