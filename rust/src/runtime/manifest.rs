//! Typed view of `artifacts/manifest.json` (written by aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{configs_from_manifest, ModelConfig};
use crate::jsonx::Json;

/// One input or output slot of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .context("io shape")?
                .iter()
                .map(|v| v.as_usize().context("dim"))
                .collect::<Result<_>>()?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .context("io dtype")?
                .to_string(),
        })
    }
}

/// One compiled-graph artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub config: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// For train_step artifacts: the number of model parameter tensors P
    /// (inputs are [P params, P m, P v, step, lr, tokens, targets]).
    pub fn train_param_count(&self) -> usize {
        debug_assert_eq!(self.kind, "train_step");
        (self.inputs.len() - 4) / 3
    }
}

/// Exported parameter file entry.
#[derive(Clone, Debug)]
pub struct ParamsSpec {
    pub file: String,
    pub names: Vec<String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ModelConfig>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub params: BTreeMap<String, ParamsSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parse manifest.json")?;
        let configs = configs_from_manifest(&j)?;

        let mut artifacts = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest artifacts")?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .context("artifact name")?
                .to_string();
            let spec = ArtifactSpec {
                name: name.clone(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .context("file")?
                    .to_string(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .context("kind")?
                    .to_string(),
                config: a
                    .get("config")
                    .and_then(Json::as_str)
                    .context("config")?
                    .to_string(),
                inputs: a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .context("inputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .context("outputs")?
                    .iter()
                    .map(IoSpec::parse)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(name, spec);
        }

        let mut params = BTreeMap::new();
        if let Some(pobj) = j.get("params").and_then(Json::as_obj) {
            for (k, v) in pobj {
                params.insert(
                    k.clone(),
                    ParamsSpec {
                        file: v
                            .get("file")
                            .and_then(Json::as_str)
                            .context("params file")?
                            .to_string(),
                        names: v
                            .get("names")
                            .and_then(Json::as_arr)
                            .context("params names")?
                            .iter()
                            .map(|n| n.as_str().context("name").map(str::to_string))
                            .collect::<Result<_>>()?,
                    },
                );
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            configs,
            artifacts,
            params,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs
            .get(name)
            .with_context(|| format!("config '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Artifacts of a kind for a config, e.g. the lm_logits batch buckets.
    pub fn find(&self, config: &str, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts
            .values()
            .filter(|a| a.config == config && a.kind == kind)
            .collect()
    }

    /// Load the exported initial params for a config, ordered to match
    /// the executables' flattened input order.  (Pure file I/O — no PJRT;
    /// callable from any thread.)
    pub fn load_params(&self, params_key: &str) -> Result<Vec<super::Value>> {
        use crate::tensor::store::{Entry, TensorStore};
        let spec = self
            .params
            .get(params_key)
            .with_context(|| format!("no params entry '{params_key}'"))?;
        let store = TensorStore::read(&self.dir.join(&spec.file))?;
        spec.names
            .iter()
            .map(|n| {
                let e = store
                    .get(n)
                    .with_context(|| format!("params file missing tensor '{n}'"))?;
                match e {
                    Entry::F32(t) => Ok(super::Value::F32(t.clone())),
                    Entry::I32(t) => Ok(super::Value::I32(t.clone())),
                    Entry::U8 { .. } => anyhow::bail!("u8 tensor '{n}' not a model param"),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // `make artifacts` not run yet
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.configs.contains_key("tiny"));
        let ts = m.artifact("tiny__train_step").unwrap();
        assert_eq!(ts.kind, "train_step");
        let p = ts.train_param_count();
        assert_eq!(ts.inputs.len(), 3 * p + 4);
        assert_eq!(ts.outputs.len(), 3 * p + 5);
        // params export is listed and names align with input specs
        let ps = m.params.get("tiny").unwrap();
        assert_eq!(ps.names.len(), p);
        // hlo files exist
        for a in m.artifacts.values() {
            assert!(m.hlo_path(a).exists(), "{}", a.name);
        }
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
