//! The PJRT execution engine: one CPU client, a cache of compiled
//! executables, and typed run helpers.
//!
//! Compilation happens once per artifact per process (XLA compile of the
//! bigger train-step graphs takes seconds); executions are cheap and
//! internally synchronized, so `Engine` is shared behind `Arc` by the
//! coordinator's engine loop.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::manifest::Manifest;
use super::Value;
use crate::tensor::Tensor;
use crate::util::Stopwatch;

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    // name -> compiled executable.  Mutex (not RwLock): compile is rare,
    // execute holds no lock.
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.manifest.hlo_path(spec);
        let sw = Stopwatch::start();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("XLA compile {name}"))?,
        );
        crate::obs::log("engine", &format!("compiled {name} in {:.2}s", sw.secs()));
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host values; returns the decomposed
    /// output tuple (aot.py lowers with return_tuple=True).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.run_parts(name, inputs, &[])
    }

    /// Like [`Engine::run`] but with the inputs split into a shared
    /// prefix (model parameters) and per-call extras, so callers on the
    /// decode hot path never have to concatenate owned copies.
    pub fn run_parts(&self, name: &str, prefix: &[Value], extra: &[Value]) -> Result<Vec<Value>> {
        let spec = self.manifest.artifact(name)?;
        let n_inputs = prefix.len() + extra.len();
        anyhow::ensure!(
            n_inputs == spec.inputs.len(),
            "{name}: got {} inputs, artifact wants {}",
            n_inputs,
            spec.inputs.len()
        );
        for (v, s) in prefix.iter().chain(extra.iter()).zip(&spec.inputs) {
            anyhow::ensure!(
                v.shape() == &s.shape[..],
                "{name}: input '{}' shape {:?} != manifest {:?}",
                s.name,
                v.shape(),
                s.shape
            );
        }
        let exe = self.load(name)?;
        let lits: Vec<xla::Literal> = prefix
            .iter()
            .chain(extra.iter())
            .map(Value::to_literal)
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        let parts = result.to_tuple().context("decompose output tuple")?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{name}: {} outputs, manifest says {}",
            parts.len(),
            spec.outputs.len()
        );
        parts.iter().map(Value::from_literal).collect()
    }

    /// Load the exported initial params for a config (ordered to match
    /// the train_step artifact's first P inputs).
    pub fn load_params(&self, params_key: &str) -> Result<Vec<Value>> {
        self.manifest.load_params(params_key)
    }

    /// Zeros shaped like the given values (Adam moment init).
    pub fn zeros_like(vals: &[Value]) -> Vec<Value> {
        vals.iter()
            .map(|v| match v {
                Value::F32(t) => Value::F32(Tensor::zeros(&t.shape)),
                Value::I32(t) => Value::I32(crate::tensor::IntTensor::zeros(&t.shape)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Engine> {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("manifest.json").exists() {
            Some(Engine::new(&dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn run_moe_fwd_artifact() {
        let Some(eng) = engine() else { return };
        // tiny moe_fwd_t16: ffn params + x (16, 64)
        let spec = eng.manifest.artifact("tiny__moe_fwd_t16").unwrap().clone();
        let mut inputs = eng.load_params("tiny.ffn").unwrap();
        let t = spec.inputs.last().unwrap().shape.clone();
        let mut rng = crate::util::Rng::new(0);
        inputs.push(Value::F32(Tensor::rand_normal(&t, 1.0, &mut rng)));
        let out = eng.run("tiny__moe_fwd_t16", &inputs).unwrap();
        // outputs: y (16, 64), load (4,)
        let y = out[0].as_f32().unwrap();
        assert_eq!(y.shape, vec![16, 64]);
        assert!(y.data.iter().all(|v| v.is_finite()));
        let load = out[1].as_f32().unwrap();
        assert!((load.data.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn input_shape_mismatch_is_rejected() {
        let Some(eng) = engine() else { return };
        let mut inputs = eng.load_params("tiny.ffn").unwrap();
        let mut rng = crate::util::Rng::new(0);
        inputs.push(Value::F32(Tensor::rand_normal(&[3, 3], 1.0, &mut rng)));
        assert!(eng.run("tiny__moe_fwd_t16", &inputs).is_err());
    }
}
