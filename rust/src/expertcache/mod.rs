//! Expert residency cache — budgeted materialization of hot butterfly
//! orbits.
//!
//! The paper makes expert *identity* cheap (shared ternary substrate +
//! O(d log d) angles), but the serving hot path still pays the full
//! synthesis cost — rotate, decode the bitplane substrate, GEMM, rotate —
//! for every expert on every decode step, even for experts routed to on
//! nearly every step.  This module trades memory back for speed, MoTE- /
//! edge-MoE-style: a small byte-budgeted working set of *hot* experts is
//! kept in a fast resident form, while cold experts keep the sub-linear
//! on-the-fly synthesis path.
//!
//! # The resident form, and why it is the *decoded* working set
//!
//! A resident expert is served from a [`DecodedExpert`]: the substrate's
//! sign rows expanded to dense f32 (`±1.0 / 0.0`) plus a bit-packed
//! nonzero-word skip map — exactly the intermediate
//! [`BitplaneTernary::gemm`]/[`BitplaneTernary::gemv`] re-derive from the
//! bitplanes on every call.  Serving from it is a plain dense GEMM with
//! the decode hoisted out of the loop.
//!
//! Fully folding the rotations into one dense matrix
//! `B(phi)·Q(W)·B(theta)ᵀ` would also elide the O(d log d) rotations
//! (a few percent of the step), but matrix composition re-associates
//! floating-point operations and therefore breaks the guarantee the
//! serving stack is built on: **cached and synthesized outputs are
//! bit-identical**.  The decoded form performs literally the same
//! arithmetic as the synthesis path (same `dot_f32` spans, same word
//! order, same zero-word skips), so `experts_forward` produces identical
//! bits whichever path an expert takes — parity-tested in
//! `rust/tests/expert_cache.rs`.
//!
//! The cache accelerates the **exact (f32) substrate path only**.  The
//! W1.58A8 serving default (`ButterflyMoeLayer::act_quant`, §Perf
//! iteration 8) quantizes activations per token and runs the substrate
//! GEMM in integer arithmetic — a resident decoded-f32 working set is
//! the wrong operand for it, so a8 forwards keep the synthesis path
//! unconditionally and the stack assembler attaches no cache in a8
//! mode (`--expert-cache-mb` takes effect under `--exact`; `cmd_serve`
//! warns about the combination instead of silently ignoring it).
//!
//! Because the v1 substrate is fully shared, resident decodes currently
//! have identical *contents* across experts; residency, budgeting and
//! eviction are still per-expert because the gating statistics, the
//! admission decision, and (with per-expert substrate deltas on the
//! roadmap) the decoded bytes themselves are per-expert.  A follow-up can
//! deduplicate the shared plane.
//!
//! # Accounting
//!
//! Cache bytes are **working-set** bytes — a deployment-time
//! memory↔throughput dial — *not* expert-identity bytes: Table 1 and
//! [`crate::moe::MoeLayer::expert_bytes`] are unchanged by residency.
//! The closed-form curve lives in `memmodel::cached_butterfly_bytes`
//! (`Method::CachedButterfly`), pinned against [`DecodedExpert::nbytes`]
//! in tests.
//!
//! # Lifecycle
//!
//! * [`ExpertResidencyCache::observe`] — `experts_forward` reports the
//!   per-expert load fractions of each forward (the eq.-6 statistics it
//!   already computes).
//! * [`ExpertResidencyCache::lookup`] — per-expert fast/slow decision in
//!   the dispatch loop; counts hits and misses.
//! * [`ExpertResidencyCache::tick`] — driven once per decode step by the
//!   engine loop: folds observed loads into a per-expert EWMA, evicts
//!   residents that went cold, admits the hottest non-residents under
//!   the byte budget (with hysteresis and an age gate so one-off routes
//!   don't thrash), and bounds materialization work per step.
//! * [`ExpertResidencyCache::prewarm`] — fills the budget with the
//!   hottest experts seen so far (warmup traffic), so the first real
//!   request doesn't pay materialization cost.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::ternary::BitplaneTernary;

/// Knobs of the residency policy.  `budget_bytes == 0` disables the
/// cache entirely (pure sub-linear mode; the default).
#[derive(Clone, Copy, Debug)]
pub struct ExpertCacheConfig {
    /// Hard ceiling on resident working-set bytes.  Never exceeded.
    pub budget_bytes: usize,
    /// Admission floor, as a multiple of the uniform load `1/E`: an
    /// expert is admissible once its EWMA load ≥ `admit_factor / E`.
    pub admit_factor: f64,
    /// Eviction floor (hysteresis: strictly below the admission floor):
    /// a resident is evicted once its EWMA load < `evict_factor / E`.
    pub evict_factor: f64,
    /// Under budget pressure, a candidate replaces the coldest resident
    /// only if `candidate_ewma > coldest_ewma * (1 + replace_margin)`.
    pub replace_margin: f64,
    /// EWMA decay per tick: `ewma = (1-α)·ewma + α·load_this_tick`.
    pub ewma_alpha: f64,
    /// Residents younger than this many ticks are never evicted
    /// (anti-thrash age gate).
    pub min_resident_ticks: u64,
    /// Materialization work bound per tick (decode-step jitter bound);
    /// `prewarm` ignores it.
    pub max_admissions_per_tick: usize,
}

impl Default for ExpertCacheConfig {
    fn default() -> Self {
        ExpertCacheConfig {
            budget_bytes: 0,
            admit_factor: 0.5,
            evict_factor: 0.125,
            replace_margin: 0.5,
            ewma_alpha: 0.1,
            min_resident_ticks: 4,
            max_admissions_per_tick: 1,
        }
    }
}

impl ExpertCacheConfig {
    /// The CLI surface: `--expert-cache-mb` with everything else default.
    pub fn with_budget_mb(mb: f64) -> Self {
        ExpertCacheConfig {
            budget_bytes: (mb.max(0.0) * 1024.0 * 1024.0) as usize,
            ..ExpertCacheConfig::default()
        }
    }

    pub fn with_budget_bytes(bytes: usize) -> Self {
        ExpertCacheConfig {
            budget_bytes: bytes,
            ..ExpertCacheConfig::default()
        }
    }
}

/// Closed-form bytes of one resident expert's decoded working set —
/// must match [`DecodedExpert::nbytes`] exactly (pinned in tests and
/// reused by `memmodel::resident_expert_bytes`).
pub fn decoded_expert_bytes(rows: usize, cols: usize) -> usize {
    let wpr = cols.div_ceil(64);
    rows * cols * 4 + (rows * wpr).div_ceil(64) * 8 + 4
}

// ---------------------------------------------------------------------------
// DecodedExpert — the resident fast form
// ---------------------------------------------------------------------------

/// A substrate decoded once into dense f32 sign rows plus a bit-packed
/// per-(row, 64-column-word) nonzero map.  Its [`gemv`](Self::gemv) and
/// [`gemm`](Self::gemm) perform *the same floating-point operations in
/// the same order* as [`BitplaneTernary::gemv`] / [`BitplaneTernary::gemm`]
/// — the decode is hoisted, nothing is re-associated — so swapping one
/// for the other changes no output bit.
pub struct DecodedExpert {
    rows: usize,
    cols: usize,
    gamma: f32,
    words_per_row: usize,
    /// rows × cols, exact decode of the bitplanes (±1.0 / 0.0).
    signs: Vec<f32>,
    /// bit (r·wpr + wi) set ⟺ word wi of row r has any nonzero sign —
    /// the same predicate as `plus|minus != 0` in the bitplane GEMV.
    word_nonzero: Vec<u64>,
}

impl DecodedExpert {
    /// Decode the substrate's bitplanes into the resident dense form.
    pub fn materialize(sub: &BitplaneTernary) -> Self {
        let (rows, cols) = (sub.rows, sub.cols);
        let wpr = sub.words_per_row();
        let mut signs = vec![0.0f32; rows * cols];
        let mut word_nonzero = vec![0u64; (rows * wpr).div_ceil(64)];
        for r in 0..rows {
            let (pr, mr) = sub.row_planes(r);
            let row = &mut signs[r * cols..(r + 1) * cols];
            for (wi, (&pw, &mw)) in pr.iter().zip(mr).enumerate() {
                let base = wi * 64;
                let n = (cols - base).min(64);
                // identical decode expression to the bitplane GEMM's
                let (mut p, mut m) = (pw, mw);
                for s in row[base..base + n].iter_mut() {
                    *s = ((p & 1) as i32 - (m & 1) as i32) as f32;
                    p >>= 1;
                    m >>= 1;
                }
                if (pw | mw) != 0 {
                    let idx = r * wpr + wi;
                    word_nonzero[idx / 64] |= 1u64 << (idx % 64);
                }
            }
        }
        DecodedExpert {
            rows,
            cols,
            gamma: sub.gamma,
            words_per_row: wpr,
            signs,
            word_nonzero,
        }
    }

    /// Resident bytes of this working set (what the budget meters).
    pub fn nbytes(&self) -> usize {
        self.signs.len() * 4 + self.word_nonzero.len() * 8 + 4
    }

    #[inline]
    fn word_is_nonzero(&self, r: usize, wi: usize) -> bool {
        let idx = r * self.words_per_row + wi;
        self.word_nonzero[idx / 64] & (1u64 << (idx % 64)) != 0
    }

    /// y = gamma · Q x — bit-identical mirror of [`BitplaneTernary::gemv`]
    /// (same per-word `dot_f32` spans in the same order, same all-zero
    /// word skip), with the sign decode already done.
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let wpr = self.words_per_row;
        for r in 0..self.rows {
            let row = &self.signs[r * self.cols..(r + 1) * self.cols];
            let mut acc = 0.0f32;
            for wi in 0..wpr {
                if !self.word_is_nonzero(r, wi) {
                    continue; // whole word of zeros: skip 64 columns
                }
                let base = wi * 64;
                let n = (self.cols - base).min(64);
                acc += crate::util::dot_f32(&row[base..base + n], &x[base..base + n]);
            }
            y[r] = acc * self.gamma;
        }
    }

    /// Batched X (t, cols) -> Y (t, rows) — bit-identical mirror of
    /// [`BitplaneTernary::gemm`]: both route through the *same*
    /// register-blocked micro-kernel ([`crate::kernels::gemm_f32`],
    /// §Perf iteration 6) over the same sign values, here with the
    /// bitplane decode already hoisted at materialization time.  `t == 1`
    /// delegates to the word-skipping GEMV exactly as the bitplane path
    /// does.  No scratch needed: the decode *is* the resident form.
    pub fn gemm(&self, x: &[f32], t: usize, y: &mut [f32]) {
        assert_eq!(x.len(), t * self.cols);
        assert_eq!(y.len(), t * self.rows);
        if t == 1 {
            return self.gemv(x, y);
        }
        crate::kernels::gemm_f32(&self.signs, self.rows, self.cols, x, t, self.gamma, y);
    }
}

// ---------------------------------------------------------------------------
// Cache statistics
// ---------------------------------------------------------------------------

/// Point-in-time counters, exposed on the serving `STATS` wire line and
/// in `Metrics::snapshot`.
#[derive(Clone, Debug, Default)]
pub struct CacheStatsSnapshot {
    /// False when the budget can't hold even one expert (budget 0 = pure
    /// sub-linear mode).
    pub enabled: bool,
    /// Expert dispatches served from a resident decode.
    pub hits: u64,
    /// Expert dispatches that fell back to on-the-fly synthesis.
    pub misses: u64,
    pub evictions: u64,
    pub materializations: u64,
    pub resident_experts: usize,
    /// Always ≤ `budget_bytes` (asserted in tests).
    pub resident_bytes: usize,
    pub budget_bytes: usize,
    /// Working-set bytes of one resident expert.
    pub entry_bytes: usize,
}

impl CacheStatsSnapshot {
    /// hits / (hits + misses); 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "cache hit {:.1}% ({} hit / {} miss) resident {}/{} ({} experts) evict={} mat={}",
            100.0 * self.hit_rate(),
            self.hits,
            self.misses,
            crate::util::human_bytes(self.resident_bytes as f64),
            crate::util::human_bytes(self.budget_bytes as f64),
            self.resident_experts,
            self.evictions,
            self.materializations,
        )
    }
}

// ---------------------------------------------------------------------------
// The residency cache
// ---------------------------------------------------------------------------

struct Entry {
    dec: Arc<DecodedExpert>,
    /// Tick of the last cache hit — LRU tie-break when the replacement
    /// pass must pick among equally cold residents.
    last_used: u64,
    admitted: u64,
}

struct Inner {
    entries: HashMap<usize, Entry>,
    /// Per-expert EWMA of load fraction (the eq.-6 statistic).
    ewma: Vec<f64>,
    /// Loads accumulated by `observe` since the last tick.
    pending: Vec<f64>,
    pending_obs: u64,
    tick: u64,
    resident_bytes: usize,
}

/// Byte-budgeted residency of hot experts' decoded working sets.
///
/// Shared `Arc`-style between the owning `ButterflyMoeLayer` (lookup /
/// observe on the forward path) and the serving engine loop (per-step
/// `tick`, warmup `prewarm`, stats).  All state is behind one mutex;
/// counters are atomics so stats reads never contend with the step.
pub struct ExpertResidencyCache {
    cfg: ExpertCacheConfig,
    substrate: Arc<BitplaneTernary>,
    n_experts: usize,
    entry_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    materializations: AtomicU64,
    inner: Mutex<Inner>,
}

impl ExpertResidencyCache {
    pub fn new(cfg: ExpertCacheConfig, substrate: Arc<BitplaneTernary>, n_experts: usize) -> Self {
        let entry_bytes = decoded_expert_bytes(substrate.rows, substrate.cols);
        ExpertResidencyCache {
            cfg,
            substrate,
            n_experts,
            entry_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            materializations: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                ewma: vec![0.0; n_experts],
                pending: vec![0.0; n_experts],
                pending_obs: 0,
                tick: 0,
                resident_bytes: 0,
            }),
        }
    }

    /// True when the budget can hold at least one resident expert.
    pub fn enabled(&self) -> bool {
        self.cfg.budget_bytes >= self.entry_bytes
    }

    /// Working-set bytes of one resident expert.
    pub fn entry_bytes(&self) -> usize {
        self.entry_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.cfg.budget_bytes
    }

    /// How many experts the budget can hold.
    pub fn capacity_experts(&self) -> usize {
        self.cfg.budget_bytes / self.entry_bytes
    }

    /// Fold loads accumulated since the last fold into the per-expert
    /// EWMA (an empty window decays every expert toward zero — idle
    /// traffic cools the working set).
    fn fold_pending(&self, inner: &mut Inner) {
        let obs = inner.pending_obs.max(1) as f64;
        let alpha = self.cfg.ewma_alpha;
        for (w, p) in inner.ewma.iter_mut().zip(inner.pending.iter_mut()) {
            *w = (1.0 - alpha) * *w + alpha * (*p / obs);
            *p = 0.0;
        }
        inner.pending_obs = 0;
    }

    /// Merge one forward's per-expert load fractions into the pending
    /// window folded at the next [`tick`](Self::tick).
    pub fn observe(&self, loads: &[f64]) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        assert_eq!(loads.len(), inner.pending.len(), "load vector length");
        for (p, &l) in inner.pending.iter_mut().zip(loads) {
            *p += l;
        }
        inner.pending_obs += 1;
    }

    /// Resident decode for expert `e`, if any.  Counts a hit or a miss;
    /// `None` means the caller must synthesize on the fly.
    pub fn lookup(&self, e: usize) -> Option<Arc<DecodedExpert>> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.tick;
        match inner.entries.get_mut(&e) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.dec.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// One decode step of residency bookkeeping: fold observed loads into
    /// the EWMA, evict residents that went cold, admit the hottest
    /// non-residents under the budget (bounded materialization work).
    pub fn tick(&self) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        inner.tick += 1;
        self.fold_pending(inner);

        let uniform = 1.0 / self.n_experts as f64;
        let evict_floor = self.cfg.evict_factor * uniform;
        let admit_floor = self.cfg.admit_factor * uniform;

        // evict residents that went cold (age-gated)
        let cold: Vec<usize> = inner
            .entries
            .iter()
            .filter(|(e, entry)| {
                inner.ewma[**e] < evict_floor
                    && inner.tick - entry.admitted >= self.cfg.min_resident_ticks
            })
            .map(|(e, _)| *e)
            .collect();
        for e in cold {
            self.evict(inner, e);
        }

        // admit the hottest admissible non-residents
        let mut candidates: Vec<usize> = (0..self.n_experts)
            .filter(|e| !inner.entries.contains_key(e) && inner.ewma[*e] >= admit_floor)
            .collect();
        candidates.sort_by(|&a, &b| inner.ewma[b].partial_cmp(&inner.ewma[a]).unwrap());
        let mut admitted = 0usize;
        for e in candidates {
            if admitted >= self.cfg.max_admissions_per_tick {
                break;
            }
            if inner.resident_bytes + self.entry_bytes <= self.cfg.budget_bytes {
                self.admit(inner, e);
                admitted += 1;
                continue;
            }
            // budget pressure: replace the coldest old-enough resident
            // (LRU tie-break on equal heat) only if the candidate is
            // decisively hotter (hysteresis)
            let victim = inner
                .entries
                .iter()
                .filter(|(_, en)| inner.tick - en.admitted >= self.cfg.min_resident_ticks)
                .map(|(ve, en)| (*ve, en.last_used))
                .min_by(|a, b| {
                    inner.ewma[a.0]
                        .partial_cmp(&inner.ewma[b.0])
                        .unwrap()
                        .then(a.1.cmp(&b.1))
                })
                .map(|(ve, _)| ve);
            match victim {
                Some(v) if inner.ewma[e] > inner.ewma[v] * (1.0 + self.cfg.replace_margin) => {
                    self.evict(inner, v);
                    self.admit(inner, e);
                    admitted += 1;
                }
                _ => break, // hotter candidates were already tried
            }
        }
    }

    /// Fill the budget with the hottest experts observed so far (ties and
    /// a cold start fall back to index order) — warmup pre-materialization
    /// so the first real request doesn't pay decode cost.  Ignores the
    /// admission floor and the per-tick materialization bound.
    pub fn prewarm(&self) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        // fold any warmup traffic observed since the last tick (but
        // don't decay observed heat when there was none)
        if inner.pending_obs > 0 {
            self.fold_pending(inner);
        }
        let mut order: Vec<usize> = (0..self.n_experts).collect();
        order.sort_by(|&x, &y| {
            inner.ewma[y]
                .partial_cmp(&inner.ewma[x])
                .unwrap()
                .then(x.cmp(&y))
        });
        for e in order {
            if inner.resident_bytes + self.entry_bytes > self.cfg.budget_bytes {
                break;
            }
            if !inner.entries.contains_key(&e) {
                self.admit(inner, e);
            }
        }
    }

    fn admit(&self, inner: &mut Inner, e: usize) {
        let dec = Arc::new(DecodedExpert::materialize(&self.substrate));
        debug_assert_eq!(dec.nbytes(), self.entry_bytes);
        inner.resident_bytes += self.entry_bytes;
        debug_assert!(inner.resident_bytes <= self.cfg.budget_bytes);
        inner.entries.insert(
            e,
            Entry {
                dec,
                last_used: inner.tick,
                admitted: inner.tick,
            },
        );
        self.materializations.fetch_add(1, Ordering::Relaxed);
    }

    fn evict(&self, inner: &mut Inner, e: usize) {
        if inner.entries.remove(&e).is_some() {
            inner.resident_bytes -= self.entry_bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> CacheStatsSnapshot {
        let inner = self.inner.lock().unwrap();
        CacheStatsSnapshot {
            enabled: self.enabled(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            materializations: self.materializations.load(Ordering::Relaxed),
            resident_experts: inner.entries.len(),
            resident_bytes: inner.resident_bytes,
            budget_bytes: self.cfg.budget_bytes,
            entry_bytes: self.entry_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_substrate as substrate;
    use crate::util::Rng;

    fn cache(
        sub: &Arc<BitplaneTernary>,
        n_experts: usize,
        budget_experts: usize,
    ) -> ExpertResidencyCache {
        let entry = decoded_expert_bytes(sub.rows, sub.cols);
        let cfg = ExpertCacheConfig {
            budget_bytes: budget_experts * entry,
            min_resident_ticks: 1,
            max_admissions_per_tick: 8,
            ewma_alpha: 0.5,
            ..ExpertCacheConfig::default()
        };
        ExpertResidencyCache::new(cfg, sub.clone(), n_experts)
    }

    #[test]
    fn decoded_gemv_bit_identical_to_bitplane() {
        for (rows, cols, seed) in [(16usize, 64usize, 1u64), (32, 100, 2), (7, 200, 3)] {
            let sub = substrate(rows, cols, seed);
            let dec = DecodedExpert::materialize(&sub);
            let mut rng = Rng::new(seed + 50);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(1.0)).collect();
            let mut a = vec![0.0f32; rows];
            let mut b = vec![0.0f32; rows];
            sub.gemv(&x, &mut a);
            dec.gemv(&x, &mut b);
            assert_eq!(a, b, "({rows},{cols}) gemv must be bit-identical");
        }
    }

    #[test]
    fn decoded_gemm_bit_identical_to_bitplane() {
        let sub = substrate(24, 96, 4);
        let dec = DecodedExpert::materialize(&sub);
        let mut rng = Rng::new(5);
        for t in [1usize, 2, 5, 16] {
            let x: Vec<f32> = (0..t * 96).map(|_| rng.normal_f32(1.0)).collect();
            let mut a = vec![0.0f32; t * 24];
            let mut b = vec![0.0f32; t * 24];
            sub.gemm(&x, t, &mut a);
            dec.gemm(&x, t, &mut b);
            assert_eq!(a, b, "t={t} gemm must be bit-identical");
        }
    }

    #[test]
    fn nbytes_matches_closed_form() {
        for (rows, cols) in [(16usize, 64usize), (2048, 512), (7, 200)] {
            let sub = substrate(rows, cols, 9);
            let dec = DecodedExpert::materialize(&sub);
            assert_eq!(dec.nbytes(), decoded_expert_bytes(rows, cols));
        }
    }

    #[test]
    fn budget_zero_disables_everything() {
        let sub = substrate(8, 64, 10);
        let c = cache(&sub, 4, 0);
        assert!(!c.enabled());
        c.observe(&[1.0, 0.0, 0.0, 0.0]);
        c.tick();
        c.prewarm();
        assert!(c.lookup(0).is_none());
        let s = c.snapshot();
        assert!(!s.enabled);
        assert_eq!(s.hits + s.misses, 0, "disabled cache records nothing");
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn admission_respects_budget_and_counts_hits() {
        let sub = substrate(8, 64, 11);
        let c = cache(&sub, 4, 2);
        // expert 0 and 1 hot, 2 and 3 cold
        for _ in 0..4 {
            c.observe(&[0.5, 0.4, 0.1, 0.0]);
            c.tick();
        }
        let s = c.snapshot();
        assert_eq!(s.resident_experts, 2);
        assert_eq!(s.resident_bytes, 2 * c.entry_bytes());
        assert!(s.resident_bytes <= c.budget_bytes());
        assert!(c.lookup(0).is_some());
        assert!(c.lookup(1).is_some());
        assert!(c.lookup(3).is_none());
        let s = c.snapshot();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn shifted_hot_set_replaces_resident_with_hysteresis() {
        let sub = substrate(8, 64, 12);
        let c = cache(&sub, 4, 1);
        for _ in 0..4 {
            c.observe(&[1.0, 0.0, 0.0, 0.0]);
            c.tick();
        }
        assert!(c.lookup(0).is_some());
        // load shifts to expert 3; margin + age gate let it take over
        // only after a few ticks, not on the first one-off route
        c.observe(&[0.0, 0.0, 0.0, 1.0]);
        c.tick();
        assert!(c.lookup(0).is_some(), "one tick must not thrash");
        for _ in 0..6 {
            c.observe(&[0.0, 0.0, 0.0, 1.0]);
            c.tick();
        }
        assert!(c.lookup(3).is_some(), "sustained load must win residency");
        assert!(c.lookup(0).is_none());
        let s = c.snapshot();
        assert!(s.evictions >= 1);
        assert_eq!(s.resident_bytes, c.entry_bytes());
    }

    #[test]
    fn one_off_route_does_not_evict_hot_resident() {
        let sub = substrate(8, 64, 13);
        let c = cache(&sub, 4, 1);
        for _ in 0..5 {
            c.observe(&[0.8, 0.1, 0.1, 0.0]);
            c.tick();
        }
        assert!(c.lookup(0).is_some());
        // a single burst to expert 2 amid continuing expert-0 traffic
        c.observe(&[0.4, 0.0, 0.6, 0.0]);
        c.tick();
        for _ in 0..3 {
            c.observe(&[0.8, 0.1, 0.1, 0.0]);
            c.tick();
        }
        assert!(c.lookup(0).is_some(), "hot resident survives a one-off");
        assert_eq!(c.snapshot().resident_experts, 1);
    }

    #[test]
    fn replacement_breaks_equal_heat_ties_by_lru() {
        let sub = substrate(8, 64, 16);
        let c = cache(&sub, 4, 2);
        // experts 0 and 1 equally hot -> both resident
        c.observe(&[0.5, 0.5, 0.0, 0.0]);
        c.tick();
        assert_eq!(c.snapshot().resident_experts, 2);
        // advance a tick (keeping the heat tie), then hit 0 so expert 1
        // becomes the least-recently-used of the tie
        c.observe(&[0.5, 0.5, 0.0, 0.0]);
        c.tick();
        assert!(c.lookup(0).is_some());
        // expert 2 becomes decisively hotter: it must replace 1, not 0
        c.observe(&[0.0, 0.0, 1.0, 0.0]);
        c.tick();
        assert!(c.lookup(2).is_some());
        assert!(c.lookup(0).is_some(), "recently used resident survives");
        assert!(c.lookup(1).is_none(), "LRU resident is the victim");
    }

    #[test]
    fn prewarm_fills_budget_by_observed_heat() {
        let sub = substrate(8, 64, 14);
        let c = cache(&sub, 6, 3);
        c.observe(&[0.0, 0.1, 0.0, 0.6, 0.3, 0.0]);
        c.prewarm();
        let s = c.snapshot();
        assert_eq!(s.resident_experts, 3);
        assert!(c.lookup(3).is_some());
        assert!(c.lookup(4).is_some());
        assert!(c.lookup(1).is_some());
        // cold start (no stats at all) falls back to index order
        let c2 = cache(&sub, 6, 2);
        c2.prewarm();
        assert!(c2.lookup(0).is_some());
        assert!(c2.lookup(1).is_some());
        assert!(c2.lookup(2).is_none());
    }

    #[test]
    fn eviction_frees_bytes_when_load_vanishes() {
        let sub = substrate(8, 64, 15);
        let c = cache(&sub, 4, 2);
        for _ in 0..3 {
            c.observe(&[0.5, 0.5, 0.0, 0.0]);
            c.tick();
        }
        assert_eq!(c.snapshot().resident_experts, 2);
        // traffic stops entirely: EWMAs decay below the eviction floor
        for _ in 0..12 {
            c.tick();
        }
        let s = c.snapshot();
        assert_eq!(s.resident_experts, 0);
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.evictions, 2);
    }
}
