//! Closed-form memory models for every compression method in Table 1 and
//! the Fig. 3 scaling curve (Props. 1 & 2 of the paper).
//!
//! All models count *expert-identity* storage only — the N weight
//! matrices (or their compressed forms) — excluding the gate and shared
//! down projection, exactly as the paper's 256 MB baseline does
//! (64 × 2048 × 512 × 4 B).

/// Layer shape for memory accounting.
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    pub d_model: usize,
    pub d_ff: usize,
}

impl LayerShape {
    pub const fn paper() -> Self {
        LayerShape {
            d_model: 512,
            d_ff: 2048,
        }
    }
    fn weights_per_expert(&self) -> f64 {
        (self.d_model * self.d_ff) as f64
    }
}

/// A compression method's memory model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// FP32 dense experts: N * d_ff * d_model * 4 B
    StandardMoe,
    /// Frantar & Alistarh 2023 — sub-1-bit codes; the paper's Table 1
    /// credits it 10–20x vs FP32; we model the midpoint 16x.
    Qmoe,
    /// Kim et al. 2023 — 2-bit weight-only; paper credits 5x.
    Moqe,
    /// Zhao et al. 2025 — expert merging + 3-bit; paper credits 2x.
    PuzzleMoe,
    /// Huang et al. 2024 — mixed precision avg 2.54 bit; paper credits 4x.
    MixtureCompressor,
    /// This paper (Prop. 1): shared 1.58-bit substrate + FP16 butterfly
    /// angles per expert.
    ButterflyMoe,
}

pub const ALL_METHODS: [Method; 6] = [
    Method::StandardMoe,
    Method::Qmoe,
    Method::Moqe,
    Method::PuzzleMoe,
    Method::MixtureCompressor,
    Method::ButterflyMoe,
];

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::StandardMoe => "Standard MoE",
            Method::Qmoe => "QMoE",
            Method::Moqe => "MoQE (2-bit)",
            Method::PuzzleMoe => "PuzzleMoE",
            Method::MixtureCompressor => "MC",
            Method::ButterflyMoe => "ButterflyMoE",
        }
    }

    /// Published compression ratio vs FP32 (used for the comparator rows
    /// we cannot fully rebuild; ButterflyMoE/Standard are exact formulas).
    pub fn paper_ratio(&self) -> Option<f64> {
        match self {
            Method::Qmoe => Some(16.0),
            Method::Moqe => Some(5.0),
            Method::PuzzleMoe => Some(2.0),
            Method::MixtureCompressor => Some(4.0),
            _ => None,
        }
    }

    /// Asymptotic memory scaling as printed in Table 1.
    pub fn scaling(&self) -> &'static str {
        match self {
            Method::ButterflyMoe => "O(d^2 + N*d*log d)",
            Method::PuzzleMoe | Method::MixtureCompressor => "O(N*d^2) reduced",
            _ => "O(N*d^2)",
        }
    }

    /// Expert-identity bytes for `n` experts.
    pub fn bytes(&self, n: usize, s: LayerShape) -> f64 {
        let w = s.weights_per_expert();
        match self {
            Method::StandardMoe => n as f64 * w * 4.0,
            Method::ButterflyMoe => butterfly_bytes(n, s),
            m => n as f64 * w * 4.0 / m.paper_ratio().unwrap(),
        }
    }

    /// Compression ratio vs standard FP32 at `n` experts.
    pub fn ratio(&self, n: usize, s: LayerShape) -> f64 {
        Method::StandardMoe.bytes(n, s) / self.bytes(n, s)
    }
}

/// Prop. 1 exactly:
/// M = 1.58/8 * d_ff * d_model
///   + N * (d_model/2 * log2 d_model + d_ff/2 * log2 d_ff) * 2 bytes.
pub fn butterfly_bytes(n: usize, s: LayerShape) -> f64 {
    substrate_bytes(s) + n as f64 * per_expert_bytes(s)
}

pub fn substrate_bytes(s: LayerShape) -> f64 {
    1.58 / 8.0 * (s.d_ff * s.d_model) as f64
}

/// FP16 butterfly angles for one expert (input + output transform).
pub fn per_expert_bytes(s: LayerShape) -> f64 {
    let angles = s.d_model as f64 / 2.0 * (s.d_model as f64).log2()
        + s.d_ff as f64 / 2.0 * (s.d_ff as f64).log2();
    angles * 2.0
}

/// Prop. 2: asymptotic compression ratio (substrate amortized away).
pub fn asymptotic_ratio(s: LayerShape) -> f64 {
    (s.d_model * s.d_ff) as f64 * 4.0 / per_expert_bytes(s)
}

/// Butterfly bytes with truncated depth (Table 2 ablation accounting;
/// both transforms counted over d_model as the paper's params/expert
/// column does).
pub fn butterfly_bytes_depth(n: usize, s: LayerShape, depth: usize) -> f64 {
    let angles_per_expert = 2.0 * depth as f64 * s.d_model as f64 / 2.0;
    substrate_bytes(s) + n as f64 * angles_per_expert * 2.0
}

/// Max experts that fit in `budget_bytes` (Table "devices").  For
/// ButterflyMoE the substrate is paid once; for others every expert pays
/// full freight.
pub fn max_experts(m: Method, budget_bytes: f64, s: LayerShape) -> usize {
    match m {
        Method::ButterflyMoe => {
            let rem = budget_bytes - substrate_bytes(s);
            if rem <= 0.0 {
                0
            } else {
                (rem / per_expert_bytes(s)).floor() as usize
            }
        }
        _ => (budget_bytes / m.bytes(1, s)).floor() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: LayerShape = LayerShape::paper();

    #[test]
    fn standard_moe_matches_paper_256mb() {
        // 64 experts, d=512, d_ff=2048, FP32 -> 256 MB
        let b = Method::StandardMoe.bytes(64, S);
        assert_eq!(b, 64.0 * 2048.0 * 512.0 * 4.0);
        assert!((b / 1048576.0 - 256.0).abs() < 1.0);
    }

    #[test]
    fn per_expert_angle_count_matches_prop1() {
        // (512/2 * 9 + 2048/2 * 11) * 2 = (2304 + 11264) * 2 = 27136 B
        assert_eq!(per_expert_bytes(S), 27136.0);
    }

    #[test]
    fn butterfly_64_experts_close_to_paper_1_9mb() {
        // Prop. 1 at N=64: 0.207 MB substrate + 64*27136 B = 1.86 MB; the
        // paper rounds to 1.9 MB.
        let mb = butterfly_bytes(64, S) / 1048576.0;
        assert!((mb - 1.9).abs() < 0.1, "got {mb}");
    }

    #[test]
    fn asymptotic_ratio_matches_prop2() {
        // paper: ~154.5x
        let r = asymptotic_ratio(S);
        assert!((r - 154.5).abs() < 0.5, "got {r}");
    }

    #[test]
    fn ratio_improves_with_expert_count() {
        let r8 = Method::ButterflyMoe.ratio(8, S);
        let r64 = Method::ButterflyMoe.ratio(64, S);
        let r256 = Method::ButterflyMoe.ratio(256, S);
        assert!(r8 < r64 && r64 < r256, "{r8} {r64} {r256}");
        // at 256 experts the paper claims ~150x
        assert!(r256 > 130.0 && r256 < 160.0, "r256={r256}");
    }

    #[test]
    fn fig3_curve_values() {
        // paper Fig. 3: 4.70 MB at 256 experts (vs 1024 MB standard)
        let b = butterfly_bytes(256, S) / 1048576.0;
        assert!((b - 6.8).abs() < 0.3, "formula gives {b} MB");
        // note: Prop. 1 actually gives 6.8 MB at 256 experts; the paper's
        // 4.70 MB figure matches a ~square-only accounting.  We report
        // both (EXPERIMENTS.md).
        let std = Method::StandardMoe.bytes(256, S) / 1048576.0;
        assert!((std - 1024.0).abs() < 1.0);
    }

    #[test]
    fn quantization_rows_match_table1() {
        // QMoE 13–26 MB band (midpoint model: 16 MB), MoQE 51 MB,
        // PuzzleMoE 128 MB, MC 64 MB.
        let mb = |m: Method| m.bytes(64, S) / 1048576.0;
        assert!((mb(Method::Qmoe) - 16.0).abs() < 0.1);
        assert!((mb(Method::Moqe) - 51.2).abs() < 0.1);
        assert!((mb(Method::PuzzleMoe) - 128.0).abs() < 0.1);
        assert!((mb(Method::MixtureCompressor) - 64.0).abs() < 0.1);
    }

    #[test]
    fn max_experts_monotone_in_budget() {
        for m in ALL_METHODS {
            let small = max_experts(m, 512.0 * 1024.0, S);
            let big = max_experts(m, 4e9, S);
            assert!(big >= small, "{m:?}");
        }
    }

    #[test]
    fn esp32_fits_butterfly_but_not_standard() {
        // 512 KB budget: standard fits 0 experts, butterfly fits >=10
        let budget = 512.0 * 1024.0;
        assert_eq!(max_experts(Method::StandardMoe, budget, S), 0);
        assert!(max_experts(Method::ButterflyMoe, budget, S) >= 10);
    }

    #[test]
    fn depth_truncation_reduces_bytes() {
        let b2 = butterfly_bytes_depth(64, S, 2);
        let b9 = butterfly_bytes_depth(64, S, 9);
        assert!(b2 < b9);
        // params/expert at depth 2 (d=512 both sides): 2*2*256 = 1024
        let per2 = (b2 - substrate_bytes(S)) / 64.0 / 2.0; // angles (fp16)
        assert_eq!(per2, 1024.0);
    }
}
