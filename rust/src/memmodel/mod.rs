//! Closed-form memory models for every compression method in Table 1 and
//! the Fig. 3 scaling curve (Props. 1 & 2 of the paper).
//!
//! All models count *expert-identity* storage only — the N weight
//! matrices (or their compressed forms) — excluding the gate and shared
//! down projection, exactly as the paper's 256 MB baseline does
//! (64 × 2048 × 512 × 4 B).
//!
//! # Residency-cache accounting ([`Method::CachedButterfly`])
//!
//! The expert-residency cache (`crate::expertcache`, the
//! `--expert-cache-mb` serving dial) adds **working-set** bytes on top
//! of identity bytes: each cache-resident expert keeps a decoded dense
//! form ([`resident_expert_bytes`], ≈ `d_ff·d_model·4` B) so decode
//! steps skip the bitplane expansion.  These bytes are a *deployment*
//! memory↔throughput trade and are **not** expert-identity storage —
//! Table 1 and `MoeLayer::expert_bytes` are unchanged by residency.
//!
//! The same split applies to the expert-parallel worker pool
//! (`crate::parallel`, the `--workers` dial): each dispatch block's
//! gather scratch (`xg`/`hg`, ≈ `t·top_k·(d_model + d_ff)·4` B across
//! all blocks, retained between steps) is **working-set** memory too —
//! it scales with batch size and worker schedule, not with expert
//! count, and never counts toward Table-1 identity bytes.
//!
//! Kernel scratch (`crate::kernels`, §Perf iteration 6) follows the same
//! rule: the blocked GEMMs' decode/quantize buffers
//! (`kernels::TernaryScratch`, ≈ `NR·cols·5 + t·(cols + 4)` B per
//! dispatch block) and the blocked butterfly's transpose block
//! (≈ `d·RB·4` B) are **working-set** bytes — a constant-per-block tile
//! sized by the micro-kernel's register/L1 blocking, independent of
//! expert count, never Table-1 identity bytes.
//! [`cached_butterfly_bytes`] is the Fig.-3 companion curve: identity
//! bytes (Prop. 1) plus `R` resident working sets, interpolating between
//! the pure sub-linear point (`R = 0`, the paper's 150× headline) and a
//! fully dense-speed deployment (`R = N`, which costs about the same as
//! standard FP32 MoE: the resident signs are stored as f32 so the fast
//! path stays bit-identical to synthesis — the dial trades the *entire*
//! compression win back for throughput if you push it all the way).

/// Layer shape for memory accounting.
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    pub d_model: usize,
    pub d_ff: usize,
}

impl LayerShape {
    pub const fn paper() -> Self {
        LayerShape {
            d_model: 512,
            d_ff: 2048,
        }
    }
    fn weights_per_expert(&self) -> f64 {
        (self.d_model * self.d_ff) as f64
    }
}

/// A compression method's memory model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// FP32 dense experts: N * d_ff * d_model * 4 B
    StandardMoe,
    /// Frantar & Alistarh 2023 — sub-1-bit codes; the paper's Table 1
    /// credits it 10–20x vs FP32; we model the midpoint 16x.
    Qmoe,
    /// Kim et al. 2023 — 2-bit weight-only; paper credits 5x.
    Moqe,
    /// Zhao et al. 2025 — expert merging + 3-bit; paper credits 2x.
    PuzzleMoe,
    /// Huang et al. 2024 — mixed precision avg 2.54 bit; paper credits 4x.
    MixtureCompressor,
    /// This paper (Prop. 1): shared 1.58-bit substrate + FP16 butterfly
    /// angles per expert.
    ButterflyMoe,
    /// ButterflyMoE identity bytes plus `resident` cache-materialized
    /// working sets (`crate::expertcache`) — the serving
    /// memory↔throughput dial.
    CachedButterfly { resident: usize },
}

pub const ALL_METHODS: [Method; 6] = [
    Method::StandardMoe,
    Method::Qmoe,
    Method::Moqe,
    Method::PuzzleMoe,
    Method::MixtureCompressor,
    Method::ButterflyMoe,
];

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::StandardMoe => "Standard MoE",
            Method::Qmoe => "QMoE",
            Method::Moqe => "MoQE (2-bit)",
            Method::PuzzleMoe => "PuzzleMoE",
            Method::MixtureCompressor => "MC",
            Method::ButterflyMoe => "ButterflyMoE",
            Method::CachedButterfly { .. } => "ButterflyMoE + cache",
        }
    }

    /// Published compression ratio vs FP32 (used for the comparator rows
    /// we cannot fully rebuild; ButterflyMoE/Standard are exact formulas).
    pub fn paper_ratio(&self) -> Option<f64> {
        match self {
            Method::Qmoe => Some(16.0),
            Method::Moqe => Some(5.0),
            Method::PuzzleMoe => Some(2.0),
            Method::MixtureCompressor => Some(4.0),
            _ => None,
        }
    }

    /// Asymptotic memory scaling as printed in Table 1.
    pub fn scaling(&self) -> &'static str {
        match self {
            Method::ButterflyMoe => "O(d^2 + N*d*log d)",
            Method::CachedButterfly { .. } => "O(d^2 + N*d*log d + R*d^2)",
            Method::PuzzleMoe | Method::MixtureCompressor => "O(N*d^2) reduced",
            _ => "O(N*d^2)",
        }
    }

    /// Bytes for `n` experts: expert-identity storage, plus resident
    /// working sets for [`Method::CachedButterfly`] (see module docs on
    /// the accounting split).
    pub fn bytes(&self, n: usize, s: LayerShape) -> f64 {
        let w = s.weights_per_expert();
        match self {
            Method::StandardMoe => n as f64 * w * 4.0,
            Method::ButterflyMoe => butterfly_bytes(n, s),
            Method::CachedButterfly { resident } => cached_butterfly_bytes(n, *resident, s),
            m => n as f64 * w * 4.0 / m.paper_ratio().unwrap(),
        }
    }

    /// Compression ratio vs standard FP32 at `n` experts.
    pub fn ratio(&self, n: usize, s: LayerShape) -> f64 {
        Method::StandardMoe.bytes(n, s) / self.bytes(n, s)
    }
}

/// Prop. 1 exactly:
/// M = 1.58/8 * d_ff * d_model
///   + N * (d_model/2 * log2 d_model + d_ff/2 * log2 d_ff) * 2 bytes.
pub fn butterfly_bytes(n: usize, s: LayerShape) -> f64 {
    substrate_bytes(s) + n as f64 * per_expert_bytes(s)
}

pub fn substrate_bytes(s: LayerShape) -> f64 {
    1.58 / 8.0 * (s.d_ff * s.d_model) as f64
}

/// FP16 butterfly angles for one expert (input + output transform).
pub fn per_expert_bytes(s: LayerShape) -> f64 {
    let angles = s.d_model as f64 / 2.0 * (s.d_model as f64).log2()
        + s.d_ff as f64 / 2.0 * (s.d_ff as f64).log2();
    angles * 2.0
}

/// Prop. 2: asymptotic compression ratio (substrate amortized away).
pub fn asymptotic_ratio(s: LayerShape) -> f64 {
    (s.d_model * s.d_ff) as f64 * 4.0 / per_expert_bytes(s)
}

/// Working-set bytes of ONE cache-resident expert: the decoded dense
/// sign rows plus the nonzero-word skip map the residency cache
/// materializes (`expertcache::DecodedExpert`) — pinned against the
/// actual `DecodedExpert::nbytes` in `rust/tests/expert_cache.rs`.
/// ≈ 4 bytes/weight: the price of skipping the bitplane decode.
pub fn resident_expert_bytes(s: LayerShape) -> f64 {
    crate::expertcache::decoded_expert_bytes(s.d_ff, s.d_model) as f64
}

/// The Fig.-3 companion curve for the serving cache: Prop.-1 identity
/// bytes plus `resident` materialized working sets (clamped to `n`).
/// `resident = 0` is exactly [`butterfly_bytes`] — the cache-disabled
/// accounting is unchanged.
pub fn cached_butterfly_bytes(n: usize, resident: usize, s: LayerShape) -> f64 {
    butterfly_bytes(n, s) + resident.min(n) as f64 * resident_expert_bytes(s)
}

/// Payload bytes of a packed `.bmoe` model artifact at full butterfly
/// depth (DESIGN.md §3): embed + readout, and per layer the gate,
/// substrate bitplanes (2 bits/weight in u64 words) + gamma, the raw
/// angle tensors *plus* their 2× (cos, sin) serving tables, and the
/// dense `w_down`.  Excludes container headers, the JSON manifest and
/// `__pad.*` alignment fillers — a packed file is at least this big and
/// at most a few KiB over (pinned against real artifacts in
/// `rust/tests/artifact.rs`).
///
/// These are **file** bytes, not Table-1 identity bytes: the artifact
/// stores angles at f32 ×3 (angles + cos + sin) where Prop. 1 counts
/// FP16 angles once, and it carries the gate, `w_down` and embeddings
/// that identity accounting excludes.  The trade is deliberate — the
/// 3× angle storage is what makes mmap loading trig-free and zero-copy
/// ([`crate::artifact`]).
pub fn model_file_bytes(n_layers: usize, n_experts: usize, s: LayerShape, vocab: usize) -> f64 {
    let (d, dff) = (s.d_model as f64, s.d_ff as f64);
    let depth_in = (s.d_model as f64).log2();
    let depth_out = (s.d_ff as f64).log2();
    let embeds = 2.0 * vocab as f64 * d * 4.0;
    let gate = n_experts as f64 * d * 4.0;
    let planes = 2.0 * dff * (s.d_model.div_ceil(64) * 8) as f64;
    // angles + interleaved (cos, sin): 3x f32 per angle
    let angles = n_experts as f64 * (depth_in * d / 2.0 + depth_out * dff / 2.0) * 4.0 * 3.0;
    let w_down = d * dff * 4.0;
    embeds + n_layers as f64 * (gate + 4.0 + planes + angles + w_down)
}

/// Butterfly bytes with truncated depth (Table 2 ablation accounting;
/// both transforms counted over d_model as the paper's params/expert
/// column does).
pub fn butterfly_bytes_depth(n: usize, s: LayerShape, depth: usize) -> f64 {
    let angles_per_expert = 2.0 * depth as f64 * s.d_model as f64 / 2.0;
    substrate_bytes(s) + n as f64 * angles_per_expert * 2.0
}

/// Max experts that fit in `budget_bytes` (Table "devices").  For
/// ButterflyMoE the substrate is paid once; for others every expert pays
/// full freight.
pub fn max_experts(m: Method, budget_bytes: f64, s: LayerShape) -> usize {
    match m {
        Method::ButterflyMoe => {
            let rem = budget_bytes - substrate_bytes(s);
            if rem <= 0.0 {
                0
            } else {
                (rem / per_expert_bytes(s)).floor() as usize
            }
        }
        Method::CachedButterfly { resident } => {
            // n experts fit iff identity(n) + min(resident, n)·ws <= budget
            // (same clamp as `cached_butterfly_bytes`): either the full
            // resident set is paid off the top (n >= resident), or every
            // expert is resident and pays identity + working set.
            let ws = resident_expert_bytes(s);
            let rem = budget_bytes - substrate_bytes(s);
            if rem <= 0.0 {
                0
            } else {
                let full_charge =
                    ((rem - resident as f64 * ws) / per_expert_bytes(s)).floor().max(0.0);
                let all_resident =
                    (rem / (per_expert_bytes(s) + ws)).floor().min(resident as f64);
                full_charge.max(all_resident).max(0.0) as usize
            }
        }
        _ => (budget_bytes / m.bytes(1, s)).floor() as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: LayerShape = LayerShape::paper();

    #[test]
    fn standard_moe_matches_paper_256mb() {
        // 64 experts, d=512, d_ff=2048, FP32 -> 256 MB
        let b = Method::StandardMoe.bytes(64, S);
        assert_eq!(b, 64.0 * 2048.0 * 512.0 * 4.0);
        assert!((b / 1048576.0 - 256.0).abs() < 1.0);
    }

    #[test]
    fn per_expert_angle_count_matches_prop1() {
        // (512/2 * 9 + 2048/2 * 11) * 2 = (2304 + 11264) * 2 = 27136 B
        assert_eq!(per_expert_bytes(S), 27136.0);
    }

    #[test]
    fn butterfly_64_experts_close_to_paper_1_9mb() {
        // Prop. 1 at N=64: 0.207 MB substrate + 64*27136 B = 1.86 MB; the
        // paper rounds to 1.9 MB.
        let mb = butterfly_bytes(64, S) / 1048576.0;
        assert!((mb - 1.9).abs() < 0.1, "got {mb}");
    }

    #[test]
    fn asymptotic_ratio_matches_prop2() {
        // paper: ~154.5x
        let r = asymptotic_ratio(S);
        assert!((r - 154.5).abs() < 0.5, "got {r}");
    }

    #[test]
    fn ratio_improves_with_expert_count() {
        let r8 = Method::ButterflyMoe.ratio(8, S);
        let r64 = Method::ButterflyMoe.ratio(64, S);
        let r256 = Method::ButterflyMoe.ratio(256, S);
        assert!(r8 < r64 && r64 < r256, "{r8} {r64} {r256}");
        // at 256 experts the paper claims ~150x
        assert!(r256 > 130.0 && r256 < 160.0, "r256={r256}");
    }

    #[test]
    fn fig3_curve_values() {
        // paper Fig. 3: 4.70 MB at 256 experts (vs 1024 MB standard)
        let b = butterfly_bytes(256, S) / 1048576.0;
        assert!((b - 6.8).abs() < 0.3, "formula gives {b} MB");
        // note: Prop. 1 actually gives 6.8 MB at 256 experts; the paper's
        // 4.70 MB figure matches a ~square-only accounting.  We report
        // both (EXPERIMENTS.md).
        let std = Method::StandardMoe.bytes(256, S) / 1048576.0;
        assert!((std - 1024.0).abs() < 1.0);
    }

    #[test]
    fn quantization_rows_match_table1() {
        // QMoE 13–26 MB band (midpoint model: 16 MB), MoQE 51 MB,
        // PuzzleMoE 128 MB, MC 64 MB.
        let mb = |m: Method| m.bytes(64, S) / 1048576.0;
        assert!((mb(Method::Qmoe) - 16.0).abs() < 0.1);
        assert!((mb(Method::Moqe) - 51.2).abs() < 0.1);
        assert!((mb(Method::PuzzleMoe) - 128.0).abs() < 0.1);
        assert!((mb(Method::MixtureCompressor) - 64.0).abs() < 0.1);
    }

    #[test]
    fn cached_curve_interpolates_sublinear_to_dense() {
        // resident 0 is exactly the Prop.-1 accounting: cache-disabled
        // behavior and bytes are unchanged
        assert_eq!(cached_butterfly_bytes(64, 0, S), butterfly_bytes(64, S));
        // each resident expert adds exactly one working set
        let ws = resident_expert_bytes(S);
        assert_eq!(
            cached_butterfly_bytes(64, 8, S),
            butterfly_bytes(64, S) + 8.0 * ws
        );
        // working set ≈ 4 MB at the paper shape (f32 signs + skip map)
        assert!((ws - 4.0 * 1048576.0).abs() < 16384.0, "{ws}");
        // a small working set keeps most of the 150x win: 8 of 64
        // resident costs ~35 MB vs 256 MB standard
        let dialed = Method::CachedButterfly { resident: 8 }.bytes(64, S);
        assert!(dialed < Method::StandardMoe.bytes(64, S) / 7.0, "{dialed}");
        // fully resident ≈ standard FP32 (the dial's far end)
        let full = Method::CachedButterfly { resident: 64 }.bytes(64, S);
        let std_b = Method::StandardMoe.bytes(64, S);
        assert!((full / std_b - 1.0).abs() < 0.02, "{full} vs {std_b}");
        // resident count clamps to n
        assert_eq!(
            cached_butterfly_bytes(4, 100, S),
            cached_butterfly_bytes(4, 4, S)
        );
    }

    #[test]
    fn cached_max_experts_pays_working_set_off_the_top() {
        let m0 = max_experts(Method::ButterflyMoe, 64.0 * 1048576.0, S);
        let m2 = max_experts(Method::CachedButterfly { resident: 2 }, 64.0 * 1048576.0, S);
        assert!(m2 < m0, "{m2} vs {m0}");
        // budget smaller than the working set fits nothing
        assert_eq!(
            max_experts(Method::CachedButterfly { resident: 2 }, 1048576.0, S),
            0
        );
        // round-trip with resident > n: the clamp must match
        // `cached_butterfly_bytes` (which charges min(resident, n) sets)
        let m = Method::CachedButterfly { resident: 100 };
        assert!(max_experts(m, m.bytes(2, S), S) >= 2);
    }

    #[test]
    fn max_experts_monotone_in_budget() {
        for m in ALL_METHODS {
            let small = max_experts(m, 512.0 * 1024.0, S);
            let big = max_experts(m, 4e9, S);
            assert!(big >= small, "{m:?}");
        }
    }

    #[test]
    fn esp32_fits_butterfly_but_not_standard() {
        // 512 KB budget: standard fits 0 experts, butterfly fits >=10
        let budget = 512.0 * 1024.0;
        assert_eq!(max_experts(Method::StandardMoe, budget, S), 0);
        assert!(max_experts(Method::ButterflyMoe, budget, S) >= 10);
    }

    #[test]
    fn model_file_bytes_scales_linearly_in_layers() {
        let one = model_file_bytes(1, 64, S, 512);
        let four = model_file_bytes(4, 64, S, 512);
        let embeds = 2.0 * 512.0 * 512.0 * 4.0;
        assert!(one > embeds);
        // layers add identical increments; embeds are paid once
        assert!((four - embeds - 4.0 * (one - embeds)).abs() < 1.0);
        // the paper shape's per-layer file cost is dominated by the 3x
        // f32 angle storage + dense w_down, an order above identity bytes
        assert!(one - embeds > butterfly_bytes(64, S));
    }

    #[test]
    fn depth_truncation_reduces_bytes() {
        let b2 = butterfly_bytes_depth(64, S, 2);
        let b9 = butterfly_bytes_depth(64, S, 9);
        assert!(b2 < b9);
        // params/expert at depth 2 (d=512 both sides): 2*2*256 = 1024
        let per2 = (b2 - substrate_bytes(S)) / 64.0 / 2.0; // angles (fp16)
        assert_eq!(per2, 1024.0);
    }
}
