//! Shared seeded test fixtures.
//!
//! The same `Rng`-seeded helpers used to be duplicated across
//! `ternary/mod.rs`, `expertcache/mod.rs`, `moe/layer.rs` unit tests and
//! the integration tests under `rust/tests/`; they live here once so a
//! fixture tweak can't silently fork the test corpora.  Compiled for
//! unit tests via `cfg(test)` and for integration tests / fault
//! injection via the tiny default-on `testutil` cargo feature (zero
//! dependencies, no runtime cost when unused).
//!
//! Determinism matters more than realism here: every helper is a pure
//! function of its seed, so "same seed ⇒ same weights" holds across
//! test binaries — the property the bitwise parity and determinism
//! suites are built on.

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{Backend, InflightBatch, StepOutput};
use crate::moe::ButterflyMoeLayer;
use crate::parallel::WorkerPool;
use crate::quant::{ternary_quantize, TernaryQuant};
use crate::tensor::Tensor;
use crate::ternary::BitplaneTernary;
use crate::util::Rng;

/// Seeded random ternary quantization of a normal matrix — the
/// `random_quant` fixture from the ternary tests.
pub fn random_quant(rows: usize, cols: usize, seed: u64) -> TernaryQuant {
    let mut rng = Rng::new(seed);
    let t = Tensor::rand_normal(&[rows, cols], 1.0, &mut rng);
    ternary_quantize(&t)
}

/// Seeded bitplane substrate — the `substrate` fixture from the
/// expert-cache tests.
pub fn random_substrate(rows: usize, cols: usize, seed: u64) -> Arc<BitplaneTernary> {
    Arc::new(BitplaneTernary::from_quant(&random_quant(rows, cols, seed)))
}

/// Seeded ButterflyMoE layer (full butterfly depth) — the `layer`
/// fixture from the moe and expert-cache tests.
pub fn butterfly_layer(
    d_model: usize,
    d_ff: usize,
    n_experts: usize,
    top_k: usize,
    seed: u64,
) -> ButterflyMoeLayer {
    let mut rng = Rng::new(seed);
    ButterflyMoeLayer::random(d_model, d_ff, n_experts, top_k, None, &mut rng)
}

/// Seeded standard-normal activation batch.
pub fn normal_vec(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.normal_f32(1.0)).collect()
}

/// Instant deterministic [`Backend`]: logits peak at (context length %
/// vocab), so greedy decode yields a stream that depends only on prompt
/// length — the `CountBackend` fixture the scheduler, server, and
/// router suites share.  An optional per-step [`Duration`] turns it
/// into the old `SlowBackend` for shutdown/ordering/crash tests.
pub struct CountBackend {
    pub vocab: usize,
    pub max_batch: usize,
    pub delay: Duration,
}

impl CountBackend {
    /// The historical defaults (vocab 32, max_batch 8, no delay).
    pub fn new() -> Self {
        CountBackend {
            vocab: 32,
            max_batch: 8,
            delay: Duration::ZERO,
        }
    }

    pub fn with_vocab(mut self, vocab: usize) -> Self {
        self.vocab = vocab;
        self
    }

    /// Sleep this long inside every `step` (the `SlowBackend` behaviour).
    pub fn with_delay(mut self, delay: Duration) -> Self {
        self.delay = delay;
        self
    }
}

impl Default for CountBackend {
    fn default() -> Self {
        CountBackend::new()
    }
}

impl Backend for CountBackend {
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn seq_len(&self) -> usize {
        64
    }
    fn vocab(&self) -> usize {
        self.vocab
    }
    fn name(&self) -> String {
        "count".into()
    }
    fn step(&self, batch: &mut InflightBatch) -> anyhow::Result<Vec<StepOutput>> {
        if self.delay > Duration::ZERO {
            std::thread::sleep(self.delay);
        }
        let (seq_len, chunk) = (self.seq_len(), batch.prefill_chunk);
        Ok(batch
            .seqs
            .iter_mut()
            .map(|s| {
                let was_prefill = !s.prefill_done();
                let span = s.next_span(seq_len, chunk);
                // mid-prefill steps carry no logits; once the prompt is
                // consumed, logits peak at (context length % vocab) so
                // greedy streams depend only on prompt length — the
                // historical behaviour at the default chunk 0
                let logits = s.prefill_done().then(|| {
                    let mut logits = vec![0.0f32; self.vocab];
                    logits[s.tokens.len() % self.vocab] = 1.0;
                    logits
                });
                StepOutput {
                    seq_id: s.id,
                    logits,
                    prefilled: if was_prefill { span.len() } else { 0 },
                }
            })
            .collect())
    }
}

/// Worker pool sized by the environment (`BMOE_WORKERS`, else cores) —
/// what the integration suites attach so CI's `BMOE_WORKERS=1` /
/// `BMOE_WORKERS=4` matrix actually exercises both schedules.
pub fn env_pool() -> Arc<WorkerPool> {
    Arc::new(WorkerPool::from_env())
}
