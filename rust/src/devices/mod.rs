//! Edge-device profiles used by the deployability table and the energy
//! model.  The paper evaluates Raspberry Pi 5, Jetson Nano and ESP32; the
//! profiles below are the published hardware numbers, with a documented
//! "model budget" (RAM usable for weights after OS/runtime overhead — the
//! paper's own device table implies a similar derating, see
//! EXPERIMENTS.md).

#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Physical RAM in bytes.
    pub ram_bytes: f64,
    /// Fraction of RAM available for model weights.
    pub usable_fraction: f64,
    /// DRAM access energy, pJ per bit (Horowitz ISSCC'14 gives ~6.4
    /// pJ/bit for LPDDR-class memory — the figure the paper cites).
    pub dram_pj_per_bit: f64,
    /// Peak memory bandwidth, bytes/sec (for latency estimates).
    pub mem_bandwidth: f64,
}

pub const RPI5: DeviceProfile = DeviceProfile {
    name: "RPi 5",
    ram_bytes: 8.0 * GIB,
    usable_fraction: 0.75,
    dram_pj_per_bit: 6.4,
    mem_bandwidth: 17.1e9, // LPDDR4X-4267 x 32-bit
};

pub const JETSON_NANO: DeviceProfile = DeviceProfile {
    name: "Jetson",
    ram_bytes: 4.0 * GIB,
    usable_fraction: 0.75,
    dram_pj_per_bit: 6.4,
    mem_bandwidth: 25.6e9,
};

pub const ESP32: DeviceProfile = DeviceProfile {
    name: "ESP32",
    ram_bytes: 512.0 * KIB,
    usable_fraction: 0.9, // no OS to speak of
    dram_pj_per_bit: 6.4, // on-package PSRAM; same model for comparability
    mem_bandwidth: 40.0e6,
};

pub const ALL_DEVICES: [DeviceProfile; 3] = [RPI5, JETSON_NANO, ESP32];

pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

impl DeviceProfile {
    /// Bytes available for model weights.
    pub fn model_budget(&self) -> f64 {
        self.ram_bytes * self.usable_fraction
    }

    /// Max experts for a compression method on this device.
    pub fn max_experts(&self, m: crate::memmodel::Method, s: crate::memmodel::LayerShape) -> usize {
        crate::memmodel::max_experts(m, self.model_budget(), s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memmodel::{LayerShape, Method};

    #[test]
    fn budgets_ordered() {
        assert!(RPI5.model_budget() > JETSON_NANO.model_budget());
        assert!(JETSON_NANO.model_budget() > ESP32.model_budget());
    }

    #[test]
    fn device_table_shape_holds() {
        // The paper's device table (§4.1): ButterflyMoE fits orders of
        // magnitude more experts than any quantization method, and the
        // RPi/Jetson ratio is ~2x (RAM ratio).
        let s = LayerShape::paper();
        for dev in [RPI5, JETSON_NANO] {
            let std = dev.max_experts(Method::StandardMoe, s);
            let qmoe = dev.max_experts(Method::Qmoe, s);
            let bf = dev.max_experts(Method::ButterflyMoe, s);
            assert!(qmoe > 2 * std, "{}", dev.name);
            // butterfly/qmoe per-expert ratio is ~(4MB/16)/27KB ~ 9.7x
            assert!(bf > 5 * qmoe, "{}", dev.name);
        }
        let r = RPI5.max_experts(Method::ButterflyMoe, s) as f64
            / JETSON_NANO.max_experts(Method::ButterflyMoe, s) as f64;
        assert!((r - 2.0).abs() < 0.1, "ratio {r}");
    }

    #[test]
    fn esp32_standard_moe_zero_experts() {
        let s = LayerShape::paper();
        assert_eq!(ESP32.max_experts(Method::StandardMoe, s), 0);
        // paper: ButterflyMoE fits ~131 on ESP32's 512 KB; our exact
        // Prop. 1 accounting (with 90% usable) gives the same order.
        let n = ESP32.max_experts(Method::ButterflyMoe, s);
        assert!(n >= 8, "n={n}");
    }
}
