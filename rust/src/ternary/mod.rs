//! Packed ternary weight storage and the add/sub-only GEMV hot path.
//!
//! Two physical layouts, both exactly representing a {-1,0,+1}^(n×k)
//! matrix plus one f32 scale:
//!
//! * [`PackedTernary`] — 2 bits/weight (4 weights/byte).  The deployment
//!   format: 2.0 bits/weight stored vs the paper's information-theoretic
//!   1.58; Table 1 reports both (entropy coding would close the gap; see
//!   `baselines::qmoe` which does exactly that for the QMoE row).
//! * [`BitplaneTernary`] — two k-bit planes per row (plus-plane,
//!   minus-plane).  GEMV becomes `sum(x[plus]) - sum(x[minus])`, which
//!   vectorizes via 64-bit mask words; this is the optimized inference
//!   path (see EXPERIMENTS.md §Perf for measured speedups).
//!
//! Row-major semantics match `kernels/ref.py::ternary_matmul_ref`:
//! `y = gamma * (x @ Q^T)` with Q (n, k), x (k,) -> y (n,).
//!
//! Batched GEMMs route through the shared register-blocked micro-kernels
//! in [`crate::kernels`] (§Perf iteration 6) with caller-retained decode
//! scratch; the per-(row, token) dot loops are retained as
//! [`BitplaneTernary::gemm_ref`] / [`BitplaneTernary::gemm_a8_ref`] for
//! the ablation and are bit-identical to the blocked paths.

use crate::artifact::SharedSlice;
use crate::kernels::{self, TernaryScratch};
use crate::quant::TernaryQuant;

/// 2-bit packed layout: code 0b00 = 0, 0b01 = +1, 0b10 = -1.
#[derive(Clone, Debug)]
pub struct PackedTernary {
    pub rows: usize,
    pub cols: usize,
    pub gamma: f32,
    /// ceil(cols/4) bytes per row, row-major
    pub bytes_per_row: usize,
    pub data: Vec<u8>,
}

impl PackedTernary {
    pub fn from_quant(q: &TernaryQuant) -> Self {
        assert_eq!(q.shape.len(), 2, "PackedTernary wants a matrix");
        let (rows, cols) = (q.shape[0], q.shape[1]);
        let bpr = cols.div_ceil(4);
        let mut data = vec![0u8; rows * bpr];
        for r in 0..rows {
            for c in 0..cols {
                let v = q.q[r * cols + c];
                let code: u8 = match v {
                    0 => 0b00,
                    1 => 0b01,
                    -1 => 0b10,
                    _ => unreachable!("non-ternary value {v}"),
                };
                data[r * bpr + c / 4] |= code << ((c % 4) * 2);
            }
        }
        PackedTernary {
            rows,
            cols,
            gamma: q.gamma,
            bytes_per_row: bpr,
            data,
        }
    }

    /// Storage bytes (weights only) — the Table 1 "measured" number.
    pub fn nbytes(&self) -> usize {
        self.data.len() + 4 // + gamma
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        let byte = self.data[r * self.bytes_per_row + c / 4];
        match (byte >> ((c % 4) * 2)) & 0b11 {
            0b00 => 0,
            0b01 => 1,
            0b10 => -1,
            _ => 0, // 0b11 unused
        }
    }

    /// Unpack to i8 (tests / conversion).
    pub fn unpack(&self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(self.get(r, c));
            }
        }
        out
    }

    /// y = gamma * Q x  — scalar reference path (unpack on the fly).
    ///
    /// The interior bytes of a row always hold 4 codes, so their decode
    /// loop is branch-free with a fixed trip count; only the final byte
    /// of a row with `cols % 4 != 0` takes the partial-limit path
    /// (the old version re-tested the limit for every byte).
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let full_bytes = self.cols / 4;
        let rem = self.cols % 4;
        for r in 0..self.rows {
            let row = &self.data[r * self.bytes_per_row..(r + 1) * self.bytes_per_row];
            let mut acc = 0.0f32;
            let mut c = 0;
            for &byte in &row[..full_bytes] {
                let mut b = byte;
                for _ in 0..4 {
                    match b & 0b11 {
                        0b01 => acc += x[c],
                        0b10 => acc -= x[c],
                        _ => {}
                    }
                    b >>= 2;
                    c += 1;
                }
            }
            if rem > 0 {
                let mut b = row[full_bytes];
                for _ in 0..rem {
                    match b & 0b11 {
                        0b01 => acc += x[c],
                        0b10 => acc -= x[c],
                        _ => {}
                    }
                    b >>= 2;
                    c += 1;
                }
            }
            y[r] = acc * self.gamma;
        }
    }
}

/// Bitplane layout: per row, `words = ceil(cols/64)` u64 words for the
/// +1 positions and the same for -1 positions.
///
/// The planes live in [`SharedSlice`] storage: owned when built by
/// [`BitplaneTernary::from_quant`], or borrowed straight from a model
/// artifact's mapping via [`BitplaneTernary::from_planes`] (DESIGN.md
/// §3) — the substrate's pages are then shared with every other process
/// mapping the same file.
#[derive(Clone, Debug)]
pub struct BitplaneTernary {
    pub rows: usize,
    pub cols: usize,
    pub gamma: f32,
    words_per_row: usize,
    plus: SharedSlice<u64>,
    minus: SharedSlice<u64>,
}

impl BitplaneTernary {
    pub fn from_quant(q: &TernaryQuant) -> Self {
        assert_eq!(q.shape.len(), 2);
        let (rows, cols) = (q.shape[0], q.shape[1]);
        let wpr = cols.div_ceil(64);
        let mut plus = vec![0u64; rows * wpr];
        let mut minus = vec![0u64; rows * wpr];
        for r in 0..rows {
            for c in 0..cols {
                match q.q[r * cols + c] {
                    1 => plus[r * wpr + c / 64] |= 1u64 << (c % 64),
                    -1 => minus[r * wpr + c / 64] |= 1u64 << (c % 64),
                    _ => {}
                }
            }
        }
        Self::from_planes(
            rows,
            cols,
            q.gamma,
            SharedSlice::owned(plus),
            SharedSlice::owned(minus),
        )
    }

    /// Build directly from bitplane words (the artifact loader's path;
    /// word `wi` bit `b` of a row is column `wi*64 + b`, exactly the
    /// layout [`Self::from_quant`] produces and the packer serializes).
    pub fn from_planes(
        rows: usize,
        cols: usize,
        gamma: f32,
        plus: SharedSlice<u64>,
        minus: SharedSlice<u64>,
    ) -> Self {
        let wpr = cols.div_ceil(64);
        assert_eq!(plus.len(), rows * wpr, "plus-plane word count mismatch");
        assert_eq!(minus.len(), rows * wpr, "minus-plane word count mismatch");
        BitplaneTernary {
            rows,
            cols,
            gamma,
            words_per_row: wpr,
            plus,
            minus,
        }
    }

    /// Storage bytes (two bitplanes = 2 bits/weight, same density as the
    /// 2-bit packing, different access pattern).
    pub fn nbytes(&self) -> usize {
        (self.plus.len() + self.minus.len()) * 8 + 4
    }

    /// 64-column words per row (the unit of the zero-skip in [`Self::gemv`]).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// All plus-plane words, row-major (what the model packer serializes).
    pub fn plus_words(&self) -> &[u64] {
        self.plus.as_slice()
    }

    /// All minus-plane words, row-major (see [`Self::plus_words`]).
    pub fn minus_words(&self) -> &[u64] {
        self.minus.as_slice()
    }

    /// Row `r`'s (plus, minus) bitplane words — what
    /// `expertcache::DecodedExpert` expands into its resident dense form.
    pub fn row_planes(&self, r: usize) -> (&[u64], &[u64]) {
        let wpr = self.words_per_row;
        (
            &self.plus.as_slice()[r * wpr..(r + 1) * wpr],
            &self.minus.as_slice()[r * wpr..(r + 1) * wpr],
        )
    }

    /// y = gamma * Q x.
    ///
    /// Optimized path (§Perf iteration 1): branchless sign expansion —
    /// per 64-column word, `sign = bit(plus) - bit(minus)` feeds a
    /// multiply-add over a fixed-width inner loop that LLVM vectorizes.
    /// The earlier sparse (`trailing_zeros`) walk is kept as
    /// [`Self::gemv_sparse`] for comparison; it loses once zero fraction
    /// drops below ~2/3 because of its serial dependent chain.
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let wpr = self.words_per_row;
        let (plus, minus) = (self.plus.as_slice(), self.minus.as_slice());
        for r in 0..self.rows {
            let pr = &plus[r * wpr..(r + 1) * wpr];
            let mr = &minus[r * wpr..(r + 1) * wpr];
            let mut acc = 0.0f32;
            for (wi, (&pw, &mw)) in pr.iter().zip(mr).enumerate() {
                if pw == 0 && mw == 0 {
                    continue; // whole word of zeros: skip 64 columns
                }
                let base = wi * 64;
                let n = (self.cols - base).min(64);
                let xs = &x[base..base + n];
                // decode the word into a stack sign buffer (shift-chain,
                // no variable shifts), then a lane-parallel dot
                let mut signs = [0.0f32; 64];
                let (mut p, mut m) = (pw, mw);
                for s in signs[..n].iter_mut() {
                    *s = ((p & 1) as i32 - (m & 1) as i32) as f32;
                    p >>= 1;
                    m >>= 1;
                }
                acc += crate::util::dot_f32(&signs[..n], xs);
            }
            y[r] = acc * self.gamma;
        }
    }

    /// Sparse-iteration GEMV (original implementation; wins only on very
    /// sparse rows).  Kept for the §Perf ablation in `hotpath.rs`.
    pub fn gemv_sparse(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let wpr = self.words_per_row;
        let (plus, minus) = (self.plus.as_slice(), self.minus.as_slice());
        for r in 0..self.rows {
            let pr = &plus[r * wpr..(r + 1) * wpr];
            let mr = &minus[r * wpr..(r + 1) * wpr];
            let mut acc = 0.0f32;
            for (wi, (&pw, &mw)) in pr.iter().zip(mr).enumerate() {
                let base = wi * 64;
                let mut p = pw;
                while p != 0 {
                    let b = p.trailing_zeros() as usize;
                    acc += x[base + b];
                    p &= p - 1;
                }
                let mut m = mw;
                while m != 0 {
                    let b = m.trailing_zeros() as usize;
                    acc -= x[base + b];
                    m &= m - 1;
                }
            }
            y[r] = acc * self.gamma;
        }
    }

    /// Decode row `r`'s bitplanes into dense f32 signs (±1.0 / 0.0) —
    /// the exact decode expression every GEMM path shares (and that
    /// `expertcache::DecodedExpert` materializes once).
    #[inline]
    fn decode_row_f32(&self, r: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), self.cols);
        let (pr, mr) = self.row_planes(r);
        for (wi, (&pw, &mw)) in pr.iter().zip(mr).enumerate() {
            let base = wi * 64;
            let n = (self.cols - base).min(64);
            let (mut p, mut m) = (pw, mw);
            for s in dst[base..base + n].iter_mut() {
                *s = ((p & 1) as i32 - (m & 1) as i32) as f32;
                p >>= 1;
                m >>= 1;
            }
        }
    }

    /// i8 variant of [`Self::decode_row_f32`] for the W1.58A8 path.
    #[inline]
    fn decode_row_i8(&self, r: usize, dst: &mut [i8]) {
        debug_assert_eq!(dst.len(), self.cols);
        let (pr, mr) = self.row_planes(r);
        for (wi, (&pw, &mw)) in pr.iter().zip(mr).enumerate() {
            let base = wi * 64;
            let n = (self.cols - base).min(64);
            let (mut p, mut m) = (pw, mw);
            for s in dst[base..base + n].iter_mut() {
                *s = (p & 1) as i8 - (m & 1) as i8;
                p >>= 1;
                m >>= 1;
            }
        }
    }

    /// Batched GEMM: X (t, cols) -> Y (t, rows), row-major.
    ///
    /// Allocates a fresh [`TernaryScratch`] per call; the hot path holds
    /// one per dispatch block and calls [`Self::gemm_with`] instead.
    pub fn gemm(&self, x: &[f32], t: usize, y: &mut [f32]) {
        self.gemm_with(x, t, y, &mut TernaryScratch::default());
    }

    /// Register-blocked batched GEMM (§Perf iteration 6, replacing the
    /// per-(row, token) dot loop of iteration 2, which is retained as
    /// [`Self::gemm_ref`]): signs decode [`kernels::NR`] rows at a time
    /// into `scratch` and the block runs through the shared
    /// [`kernels::gemm_f32_strided`] micro-kernel, so each activation
    /// chunk is loaded once per `NR` weight rows.  Bit-identical to
    /// `gemm_ref` (the micro-kernel reproduces `dot_f32`'s association
    /// exactly) and to `DecodedExpert::gemm`, which routes through the
    /// *same* micro-kernel — the cached/uncached parity contract.
    ///
    /// `t == 1` delegates to the word-skipping [`Self::gemv`], exactly
    /// as the decoded path does.  Zero steady-state allocation: the
    /// scratch is resized in place and retained by the caller.
    pub fn gemm_with(&self, x: &[f32], t: usize, y: &mut [f32], scratch: &mut TernaryScratch) {
        assert_eq!(x.len(), t * self.cols);
        assert_eq!(y.len(), t * self.rows);
        if t == 1 {
            return self.gemv(x, y);
        }
        scratch.signs_f32.resize(kernels::NR * self.cols, 0.0);
        let signs = &mut scratch.signs_f32[..];
        let mut r = 0;
        while r < self.rows {
            let nr = (self.rows - r).min(kernels::NR);
            for rr in 0..nr {
                self.decode_row_f32(r + rr, &mut signs[rr * self.cols..(rr + 1) * self.cols]);
            }
            kernels::gemm_f32_strided(
                &signs[..nr * self.cols],
                nr,
                self.cols,
                x,
                t,
                self.gamma,
                y,
                r,
                self.rows,
            );
            r += nr;
        }
    }

    /// Reference batched GEMM (§Perf iteration 2): decode each weight
    /// row once, then one `dot_f32` per (row, token).  Kept for the
    /// old-vs-new ablation in `benches/hotpath.rs` and the bit-identity
    /// property tests in `rust/tests/kernels.rs`.
    pub fn gemm_ref(&self, x: &[f32], t: usize, y: &mut [f32]) {
        assert_eq!(x.len(), t * self.cols);
        assert_eq!(y.len(), t * self.rows);
        if t == 1 {
            return self.gemv(x, y);
        }
        let mut signs = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            self.decode_row_f32(r, &mut signs);
            for i in 0..t {
                let xi = &x[i * self.cols..(i + 1) * self.cols];
                y[i * self.rows + r] = crate::util::dot_f32(&signs, xi) * self.gamma;
            }
        }
    }
}

impl BitplaneTernary {
    /// Batched GEMM with int8-quantized activations (§Perf iteration 5,
    /// the bitnet.cpp trick): per-token absmax scales map x to i8, the
    /// ternary signs decode to i8, and the inner dot runs in widening
    /// integer arithmetic — 2-4x more SIMD lanes than f32 on this core.
    ///
    /// Activation quantization adds ~0.1-0.4% relative error (8-bit,
    /// measured in tests) — the same order as the ternary substrate's
    /// own error, and the deployment-standard choice (W1.58A8).
    pub fn gemm_a8(&self, x: &[f32], t: usize, y: &mut [f32]) {
        self.gemm_a8_with(x, t, y, &mut TernaryScratch::default());
    }

    /// [`Self::gemm_a8`] with caller-retained scratch: the per-call
    /// `xq`/`scales`/sign-buffer allocations are hoisted into `scratch`
    /// (resized in place), and the inner loops run through the shared
    /// register-blocked [`kernels::gemm_i8_strided`] micro-kernel.
    /// Bit-identical to [`Self::gemm_a8_ref`]: i32 accumulation is
    /// exact, and the quantization arithmetic is unchanged.
    ///
    /// Depth bound: `cols ≤ 2^16` ([`kernels::MAX_I8_DOT_LEN`]) keeps
    /// the i32 dot accumulation overflow-free at `|q| ≤ 127` — asserted
    /// here, documented on [`kernels::dot_i8`].
    pub fn gemm_a8_with(&self, x: &[f32], t: usize, y: &mut [f32], scratch: &mut TernaryScratch) {
        assert_eq!(x.len(), t * self.cols);
        assert_eq!(y.len(), t * self.rows);
        debug_assert!(
            self.cols <= kernels::MAX_I8_DOT_LEN,
            "gemm_a8 depth {} exceeds the i32-accumulation bound 2^16",
            self.cols
        );
        // Non-vacuity witness for the a8-default accuracy gate
        // (rust/tests/determinism.rs asserts this counter moved).
        kernels::dispatch::note_a8_gemm();
        let cols = self.cols;
        // quantize activations: per-token absmax -> i8 in [-127, 127]
        scratch.xq.resize(t * cols, 0);
        scratch.scales.resize(t, 0.0);
        let (xq, scales) = (&mut scratch.xq[..t * cols], &mut scratch.scales[..t]);
        for i in 0..t {
            let xi = &x[i * cols..(i + 1) * cols];
            let amax = xi.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
            let inv = 127.0 / amax;
            scales[i] = amax / 127.0 * self.gamma;
            for (q, &v) in xq[i * cols..(i + 1) * cols].iter_mut().zip(xi) {
                *q = (v * inv).round() as i8;
            }
        }
        scratch.signs_i8.resize(kernels::NR * cols, 0);
        let signs = &mut scratch.signs_i8[..];
        let mut r = 0;
        while r < self.rows {
            let nr = (self.rows - r).min(kernels::NR);
            for rr in 0..nr {
                self.decode_row_i8(r + rr, &mut signs[rr * cols..(rr + 1) * cols]);
            }
            kernels::gemm_i8_strided(
                &signs[..nr * cols],
                nr,
                cols,
                xq,
                t,
                scales,
                y,
                r,
                self.rows,
            );
            r += nr;
        }
    }

    /// Reference W1.58A8 GEMM (§Perf iteration 5's original loop order):
    /// decode each row once, one [`kernels::dot_i8`] per (row, token),
    /// per-call buffers.  Kept for the ablation and the bit-identity
    /// property tests.
    pub fn gemm_a8_ref(&self, x: &[f32], t: usize, y: &mut [f32]) {
        assert_eq!(x.len(), t * self.cols);
        assert_eq!(y.len(), t * self.rows);
        let cols = self.cols;
        let mut xq = vec![0i8; t * cols];
        let mut scales = vec![0.0f32; t];
        for i in 0..t {
            let xi = &x[i * cols..(i + 1) * cols];
            let amax = xi.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
            let inv = 127.0 / amax;
            scales[i] = amax / 127.0 * self.gamma;
            for (q, &v) in xq[i * cols..(i + 1) * cols].iter_mut().zip(xi) {
                *q = (v * inv).round() as i8;
            }
        }
        let mut signs = vec![0i8; cols];
        for r in 0..self.rows {
            self.decode_row_i8(r, &mut signs);
            for i in 0..t {
                let qi = &xq[i * cols..(i + 1) * cols];
                y[i * self.rows + r] = kernels::dot_i8(&signs, qi) as f32 * scales[i];
            }
        }
    }
}

/// Dense reference: y = gamma * Q x from an i8 matrix (tests).
pub fn dense_ternary_gemv(q: &[i8], rows: usize, cols: usize, gamma: f32, x: &[f32], y: &mut [f32]) {
    for r in 0..rows {
        let mut acc = 0.0f32;
        for c in 0..cols {
            acc += q[r * cols + c] as f32 * x[c];
        }
        y[r] = acc * gamma;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_quant;
    use crate::util::Rng;

    #[test]
    fn pack_unpack_roundtrip() {
        for (rows, cols) in [(4usize, 7usize), (16, 64), (3, 130), (1, 1)] {
            let q = random_quant(rows, cols, (rows * cols) as u64);
            let p = PackedTernary::from_quant(&q);
            assert_eq!(p.unpack(), q.q, "({rows},{cols})");
        }
    }

    #[test]
    fn packed_density_is_2bits() {
        let q = random_quant(512, 2048, 1);
        let p = PackedTernary::from_quant(&q);
        assert_eq!(p.nbytes() - 4, 512 * 2048 / 4);
    }

    #[test]
    fn packed_gemv_matches_dense() {
        for (rows, cols, seed) in [(8usize, 16usize, 2u64), (32, 100, 3), (5, 257, 4)] {
            let q = random_quant(rows, cols, seed);
            let p = PackedTernary::from_quant(&q);
            let mut rng = Rng::new(seed + 100);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(1.0)).collect();
            let mut y = vec![0.0; rows];
            let mut want = vec![0.0; rows];
            p.gemv(&x, &mut y);
            dense_ternary_gemv(&q.q, rows, cols, q.gamma, &x, &mut want);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b} ({rows}x{cols})");
            }
        }
    }

    #[test]
    fn bitplane_gemv_matches_dense() {
        for (rows, cols, seed) in [(8usize, 16usize, 5u64), (64, 512, 6), (7, 200, 7)] {
            let q = random_quant(rows, cols, seed);
            let bp = BitplaneTernary::from_quant(&q);
            let mut rng = Rng::new(seed + 200);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(1.0)).collect();
            let mut y = vec![0.0; rows];
            let mut want = vec![0.0; rows];
            bp.gemv(&x, &mut y);
            dense_ternary_gemv(&q.q, rows, cols, q.gamma, &x, &mut want);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b} ({rows}x{cols})");
            }
        }
    }

    #[test]
    fn bitplane_gemm_matches_row_gemv() {
        let q = random_quant(16, 96, 8);
        let bp = BitplaneTernary::from_quant(&q);
        let mut rng = Rng::new(9);
        let t = 5;
        let x: Vec<f32> = (0..t * 96).map(|_| rng.normal_f32(1.0)).collect();
        let mut y = vec![0.0; t * 16];
        bp.gemm(&x, t, &mut y);
        for i in 0..t {
            let mut yi = vec![0.0; 16];
            bp.gemv(&x[i * 96..(i + 1) * 96], &mut yi);
            for (a, b) in y[i * 16..(i + 1) * 16].iter().zip(&yi) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn gemm_a8_close_to_exact() {
        let q = random_quant(64, 256, 31);
        let bp = BitplaneTernary::from_quant(&q);
        let mut rng = Rng::new(32);
        let t = 7;
        let x: Vec<f32> = (0..t * 256).map(|_| rng.normal_f32(1.0)).collect();
        let mut exact = vec![0.0; t * 64];
        let mut approx = vec![0.0; t * 64];
        bp.gemm(&x, t, &mut exact);
        bp.gemm_a8(&x, t, &mut approx);
        // relative error of 8-bit activation quantization
        let scale = exact.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (a, b) in approx.iter().zip(&exact) {
            assert!((a - b).abs() / scale < 0.01, "{a} vs {b}");
        }
    }

    // NOTE: blocked-vs-reference bit-identity (gemm_with vs gemm_ref,
    // gemm_a8_with vs gemm_a8_ref) is property-tested across shapes in
    // rust/tests/kernels.rs — one suite, no duplicated corpora.

    #[test]
    fn packed_gemv_covers_every_byte_tail_remainder() {
        // cols % 4 ∈ {0, 1, 2, 3}: the split interior/partial-byte loops
        // must agree with the dense reference at every remainder
        for (cols, seed) in [(64usize, 60u64), (65, 61), (66, 62), (67, 63)] {
            let q = random_quant(6, cols, seed);
            let p = PackedTernary::from_quant(&q);
            let mut rng = Rng::new(seed + 10);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(1.0)).collect();
            let mut y = vec![0.0; 6];
            let mut want = vec![0.0; 6];
            p.gemv(&x, &mut y);
            dense_ternary_gemv(&q.q, 6, cols, q.gamma, &x, &mut want);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "cols={cols}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gemv_sparse_matches_gemv() {
        for (rows, cols, seed) in [(32usize, 128usize, 21u64), (7, 200, 22)] {
            let q = random_quant(rows, cols, seed);
            let bp = BitplaneTernary::from_quant(&q);
            let mut rng = Rng::new(seed + 500);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(1.0)).collect();
            let mut a = vec![0.0; rows];
            let mut b = vec![0.0; rows];
            bp.gemv(&x, &mut a);
            bp.gemv_sparse(&x, &mut b);
            for (u, v) in a.iter().zip(&b) {
                assert!((u - v).abs() < 1e-3, "{u} vs {v}");
            }
        }
    }

    #[test]
    fn from_planes_reproduces_from_quant_bitwise() {
        // the pack -> load substrate path: rebuilding from serialized
        // words must serve identical bits to the original quantization
        let q = random_quant(16, 96, 77);
        let a = BitplaneTernary::from_quant(&q);
        let b = BitplaneTernary::from_planes(
            16,
            96,
            a.gamma,
            SharedSlice::owned(a.plus_words().to_vec()),
            SharedSlice::owned(a.minus_words().to_vec()),
        );
        let mut rng = Rng::new(78);
        let x: Vec<f32> = (0..96).map(|_| rng.normal_f32(1.0)).collect();
        let (mut ya, mut yb) = (vec![0.0; 16], vec![0.0; 16]);
        a.gemv(&x, &mut ya);
        b.gemv(&x, &mut yb);
        assert_eq!(ya, yb);
        let t = 3;
        let xs: Vec<f32> = (0..t * 96).map(|_| rng.normal_f32(1.0)).collect();
        let (mut ga, mut gb) = (vec![0.0; t * 16], vec![0.0; t * 16]);
        a.gemm(&xs, t, &mut ga);
        b.gemm(&xs, t, &mut gb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn all_zero_matrix() {
        let q = TernaryQuant {
            q: vec![0; 12],
            shape: vec![3, 4],
            gamma: 0.5,
        };
        let p = PackedTernary::from_quant(&q);
        let bp = BitplaneTernary::from_quant(&q);
        let x = vec![1.0; 4];
        let mut y = vec![9.0; 3];
        p.gemv(&x, &mut y);
        assert_eq!(y, vec![0.0; 3]);
        bp.gemv(&x, &mut y);
        assert_eq!(y, vec![0.0; 3]);
    }

    #[test]
    fn gamma_scales_output() {
        let q = TernaryQuant {
            q: vec![1, -1],
            shape: vec![1, 2],
            gamma: 2.5,
        };
        let p = PackedTernary::from_quant(&q);
        let mut y = vec![0.0; 1];
        p.gemv(&[3.0, 1.0], &mut y);
        assert!((y[0] - 5.0).abs() < 1e-6);
    }
}
