//! Configuration system: model presets (kept in lockstep with
//! `python/compile/configs.py` — the artifact manifest carries the python
//! side, and `ModelConfig::from_manifest` cross-checks), runtime options,
//! and a small `key=value` config-file parser.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonx::Json;

/// Expert parameterization (mirrors configs.py `arch`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Butterfly,
    Standard,
    Dense,
}

impl Arch {
    pub fn parse(s: &str) -> Result<Arch> {
        Ok(match s {
            "butterfly" => Arch::Butterfly,
            "standard" => Arch::Standard,
            "dense" => Arch::Dense,
            _ => bail!("unknown arch '{s}'"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Butterfly => "butterfly",
            Arch::Standard => "standard",
            Arch::Dense => "dense",
        }
    }
}

/// Model hyperparameters (mirror of python ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub seq_len: usize,
    pub bfly_depth: Option<usize>,
    pub arch: Arch,
    pub learn_rotations: bool,
    pub balance_lambda: f64,
}

impl ModelConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.d_model.is_power_of_two() || !self.d_ff.is_power_of_two() {
            bail!("d_model/d_ff must be powers of two (butterfly constraint)");
        }
        if self.top_k == 0 || self.top_k > self.n_experts.max(1) {
            bail!("top_k out of range");
        }
        Ok(())
    }

    /// Parse the config dict embedded in `artifacts/manifest.json`.
    pub fn from_manifest(name: &str, j: &Json) -> Result<ModelConfig> {
        let get = |k: &str| -> Result<&Json> {
            j.get(k).with_context(|| format!("config '{name}' missing key {k}"))
        };
        let cfg = ModelConfig {
            name: name.to_string(),
            vocab: get("vocab")?.as_usize().context("vocab")?,
            d_model: get("d_model")?.as_usize().context("d_model")?,
            d_ff: get("d_ff")?.as_usize().context("d_ff")?,
            n_heads: get("n_heads")?.as_usize().context("n_heads")?,
            n_blocks: get("n_blocks")?.as_usize().context("n_blocks")?,
            n_experts: get("n_experts")?.as_usize().context("n_experts")?,
            top_k: get("top_k")?.as_usize().context("top_k")?,
            seq_len: get("seq_len")?.as_usize().context("seq_len")?,
            bfly_depth: match get("bfly_depth")? {
                Json::Null => None,
                v => Some(v.as_usize().context("bfly_depth")?),
            },
            arch: Arch::parse(get("arch")?.as_str().context("arch")?)?,
            learn_rotations: get("learn_rotations")?.as_bool().unwrap_or(true),
            balance_lambda: get("balance_lambda")?.as_f64().unwrap_or(0.01),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn layer_shape(&self) -> crate::memmodel::LayerShape {
        crate::memmodel::LayerShape {
            d_model: self.d_model,
            d_ff: self.d_ff,
        }
    }
}

/// Runtime / launcher options, parsed from CLI flags or a config file.
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    /// artifacts directory
    pub artifacts_dir: String,
    /// config preset name to serve/train
    pub config: String,
    pub steps: usize,
    pub lr: f64,
    pub warmup_steps: usize,
    pub seed: u64,
    /// continuous batching: max sequences resident in the decode loop
    pub max_batch: usize,
    /// continuous batching: idle-start admission deadline in milliseconds
    /// (how long the first batch may wait to fill)
    pub max_wait_ms: u64,
    /// continuous batching: max prompt tokens ingested per engine tick
    /// per prefilling sequence (`--prefill-chunk`); 0 = the whole
    /// prompt at once.  Decoded streams are bit-identical for every
    /// value (DESIGN.md §2)
    pub prefill_chunk: usize,
    /// session parameters used by client-side commands (`bench-client`);
    /// the wire protocol carries them explicitly per request
    pub max_new_tokens: usize,
    /// sampling temperature for client-side commands (0 = greedy)
    pub temperature: f64,
    /// top-k truncation for client-side commands (0 = full vocab)
    pub top_k: usize,
    /// expert-residency cache budget in MB for the native serving
    /// backend (`--expert-cache-mb`); 0 disables the cache — pure
    /// sub-linear mode (see `expertcache`)
    pub expert_cache_mb: f64,
    /// worker threads for the native MoE hot path (`--workers`); 0 =
    /// auto (the `BMOE_WORKERS` env var, else every available core —
    /// see `parallel::resolve_workers`).  Decoded streams are
    /// bit-identical for every value.
    pub workers: usize,
    /// residual ButterflyMoE blocks in the synthetic native model
    /// (`--layers`); ignored when `model_path` names a `.bmoe` artifact,
    /// which carries its own layer count
    pub n_layers: usize,
    /// packed `.bmoe` model artifact for `serve --native` (`--model`);
    /// empty = synthesize the seeded stand-in model instead
    pub model_path: String,
    /// how to load `model_path` (`--load mmap|heap`): `mmap` borrows
    /// tensor payloads from a shared file mapping (zero-copy cold
    /// start), `heap` eagerly deserializes — decoded token streams are
    /// bit-identical either way (see `artifact`)
    pub load_mode: String,
    /// serving numerics (`--exact`): the native backend defaults to the
    /// W1.58A8 quantized substrate GEMM (`BitplaneTernary::gemm_a8`),
    /// whose max logit error vs the exact f32 path is bounded by the
    /// accuracy-gate test; `--exact` opts back into the f32 path
    /// (bit-identical to pre-A8 releases) and re-enables the
    /// expert-residency cache
    pub exact: bool,
    /// kernel ISA override (`--kernel-isa scalar|avx2|neon|auto`, else
    /// the `BMOE_KERNEL_ISA` env var); empty/`auto` = detect at startup
    /// (see `kernels::dispatch`)
    pub kernel_isa: String,
    pub port: u16,
    /// router (`bmoe route`): worker processes to spawn and supervise
    /// (`--fleet`)
    pub fleet: usize,
    /// router: concurrent sessions the router opens against one worker
    /// before queueing (`--sessions-per-worker`); admission capacity is
    /// `healthy_workers * sessions_per_worker`
    pub sessions_per_worker: usize,
    /// router: bounded admission queue (`--route-queue`); arrivals
    /// beyond it are shed with an immediate `END shed`
    pub route_queue: usize,
    /// router: max concurrent sessions per client IP (`--client-cap`);
    /// 0 = unlimited
    pub client_cap: usize,
    /// router: health-poll cadence in milliseconds
    /// (`--health-interval-ms`)
    pub health_interval_ms: u64,
    /// router: max transparent failovers per session
    /// (`--failover-retries`); a worker lost mid-stream is re-placed and
    /// its delivered prefix replay-verified this many times before the
    /// terminal `ERR worker lost` (see `router::proxy`)
    pub failover_retries: u32,
    /// fault-injection spec (`--fault`, else the `BMOE_FAULT` env var);
    /// empty = inert.  `key=value` pairs separated by `;` — see `faults`
    pub fault: String,
    /// observability: hot-path trace sample rate (`--trace-sample`);
    /// 0 = off (the default — one atomic load per instrumented site),
    /// N = time every Nth occurrence per stage (see `obs::trace`).
    /// Decoded token streams are bit-identical at every rate.
    pub trace_sample: u32,
    /// observability: JSONL structured-event sink (`--log-json`);
    /// empty = none, `-` = stdout, else an append-mode file path
    /// (see `obs::event`)
    pub log_json: String,
    pub checkpoint_every: usize,
    pub out_dir: String,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            artifacts_dir: "artifacts".into(),
            config: "tiny".into(),
            steps: 200,
            lr: 1e-3,
            warmup_steps: 20,
            seed: 0,
            max_batch: 16,
            max_wait_ms: 5,
            prefill_chunk: 0,
            max_new_tokens: 32,
            temperature: 0.0,
            top_k: 0,
            expert_cache_mb: 0.0,
            workers: 0,
            n_layers: 1,
            model_path: String::new(),
            load_mode: "mmap".into(),
            exact: false,
            kernel_isa: String::new(),
            port: 7070,
            fleet: 2,
            sessions_per_worker: 16,
            route_queue: 64,
            client_cap: 0,
            health_interval_ms: 500,
            failover_retries: 2,
            fault: String::new(),
            trace_sample: 0,
            log_json: String::new(),
            checkpoint_every: 100,
            out_dir: "runs".into(),
        }
    }
}

impl RuntimeConfig {
    /// Apply `key=value` overrides (from CLI or file lines).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "config" => self.config = value.into(),
            "steps" => self.steps = value.parse().context("steps")?,
            "lr" => self.lr = value.parse().context("lr")?,
            "warmup_steps" => self.warmup_steps = value.parse().context("warmup_steps")?,
            "seed" => self.seed = value.parse().context("seed")?,
            "max_batch" => self.max_batch = value.parse().context("max_batch")?,
            "max_wait_ms" => self.max_wait_ms = value.parse().context("max_wait_ms")?,
            "prefill_chunk" => self.prefill_chunk = value.parse().context("prefill_chunk")?,
            "max_new_tokens" => self.max_new_tokens = value.parse().context("max_new_tokens")?,
            "temperature" => self.temperature = value.parse().context("temperature")?,
            "top_k" => self.top_k = value.parse().context("top_k")?,
            "expert_cache_mb" => {
                self.expert_cache_mb = value.parse().context("expert_cache_mb")?
            }
            "workers" => self.workers = value.parse().context("workers")?,
            "n_layers" => {
                self.n_layers = value.parse().context("n_layers")?;
                anyhow::ensure!(self.n_layers >= 1, "n_layers must be >= 1");
            }
            "model_path" => self.model_path = value.into(),
            "load_mode" => {
                anyhow::ensure!(
                    matches!(value, "mmap" | "heap"),
                    "load_mode must be mmap|heap"
                );
                self.load_mode = value.into();
            }
            "exact" => self.exact = value.parse().context("exact")?,
            "kernel_isa" => {
                // validate eagerly: a typo'd ISA must fail at startup,
                // not fall back to auto-detection
                crate::kernels::Isa::parse(value)?;
                self.kernel_isa = value.into();
            }
            "port" => self.port = value.parse().context("port")?,
            "fleet" => {
                self.fleet = value.parse().context("fleet")?;
                anyhow::ensure!(self.fleet >= 1, "fleet must be >= 1");
            }
            "sessions_per_worker" => {
                self.sessions_per_worker = value.parse().context("sessions_per_worker")?;
                anyhow::ensure!(self.sessions_per_worker >= 1, "sessions_per_worker must be >= 1");
            }
            "route_queue" => self.route_queue = value.parse().context("route_queue")?,
            "client_cap" => self.client_cap = value.parse().context("client_cap")?,
            "health_interval_ms" => {
                self.health_interval_ms = value.parse().context("health_interval_ms")?;
                anyhow::ensure!(self.health_interval_ms >= 1, "health_interval_ms must be >= 1");
            }
            "failover_retries" => {
                self.failover_retries = value.parse().context("failover_retries")?
            }
            "fault" => {
                // validate eagerly: a typo'd spec must fail at startup,
                // not silently run a different chaos schedule
                crate::faults::FaultPlan::parse(value)?;
                self.fault = value.into();
            }
            "trace_sample" => self.trace_sample = value.parse().context("trace_sample")?,
            "log_json" => self.log_json = value.into(),
            "checkpoint_every" => {
                self.checkpoint_every = value.parse().context("checkpoint_every")?
            }
            "out_dir" => self.out_dir = value.into(),
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Load a config file of `key = value` lines ('#' comments allowed).
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{}:{}: expected key=value", path.display(), lineno + 1))?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

/// Parse all configs from a manifest.
pub fn configs_from_manifest(manifest: &Json) -> Result<BTreeMap<String, ModelConfig>> {
    let obj = manifest
        .get("configs")
        .and_then(Json::as_obj)
        .context("manifest missing configs")?;
    obj.iter()
        .map(|(name, j)| ModelConfig::from_manifest(name, j).map(|c| (name.clone(), c)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_parse_roundtrip() {
        for a in [Arch::Butterfly, Arch::Standard, Arch::Dense] {
            assert_eq!(Arch::parse(a.name()).unwrap(), a);
        }
        assert!(Arch::parse("bogus").is_err());
    }

    #[test]
    fn manifest_config_parses() {
        let j = Json::parse(
            r#"{"vocab":512,"d_model":64,"d_ff":256,"n_heads":4,"n_blocks":2,
                "n_experts":4,"top_k":2,"seq_len":32,"bfly_depth":null,
                "arch":"butterfly","learn_rotations":true,"balance_lambda":0.01,
                "dropout":0.0,"name":"tiny"}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest("tiny", &j).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.arch, Arch::Butterfly);
        assert_eq!(c.bfly_depth, None);
    }

    #[test]
    fn manifest_config_rejects_non_pow2() {
        let j = Json::parse(
            r#"{"vocab":512,"d_model":48,"d_ff":256,"n_heads":4,"n_blocks":2,
                "n_experts":4,"top_k":2,"seq_len":32,"bfly_depth":null,
                "arch":"butterfly","learn_rotations":true,"balance_lambda":0.01}"#,
        )
        .unwrap();
        assert!(ModelConfig::from_manifest("bad", &j).is_err());
    }

    #[test]
    fn runtime_overrides() {
        let mut r = RuntimeConfig::default();
        r.set("steps", "500").unwrap();
        r.set("lr", "0.01").unwrap();
        r.set("config", "small").unwrap();
        assert_eq!(r.steps, 500);
        assert_eq!(r.lr, 0.01);
        assert!(r.set("nope", "1").is_err());
        assert!(r.set("steps", "abc").is_err());
    }

    #[test]
    fn serving_overrides() {
        let mut r = RuntimeConfig::default();
        assert_eq!(r.prefill_chunk, 0, "default: whole prompt in one tick");
        r.set("max_new_tokens", "64").unwrap();
        r.set("temperature", "0.7").unwrap();
        r.set("top_k", "40").unwrap();
        r.set("expert_cache_mb", "24.5").unwrap();
        r.set("workers", "4").unwrap();
        r.set("prefill_chunk", "8").unwrap();
        assert_eq!(r.max_new_tokens, 64);
        assert_eq!(r.temperature, 0.7);
        assert_eq!(r.top_k, 40);
        assert_eq!(r.expert_cache_mb, 24.5);
        assert_eq!(r.workers, 4);
        assert_eq!(r.prefill_chunk, 8);
        assert!(r.set("expert_cache_mb", "lots").is_err());
        assert!(r.set("workers", "many").is_err());
        assert!(r.set("prefill_chunk", "some").is_err());
    }

    #[test]
    fn model_artifact_overrides() {
        let mut r = RuntimeConfig::default();
        assert_eq!(r.n_layers, 1);
        assert_eq!(r.load_mode, "mmap");
        assert!(r.model_path.is_empty());
        r.set("n_layers", "4").unwrap();
        r.set("model_path", "runs/model.bmoe").unwrap();
        r.set("load_mode", "heap").unwrap();
        assert_eq!(r.n_layers, 4);
        assert_eq!(r.model_path, "runs/model.bmoe");
        assert_eq!(r.load_mode, "heap");
        assert!(r.set("n_layers", "0").is_err());
        assert!(r.set("load_mode", "floppy").is_err());
    }

    #[test]
    fn numerics_and_isa_overrides() {
        let mut r = RuntimeConfig::default();
        assert!(!r.exact, "W1.58A8 serving is the default; --exact opts out");
        assert!(r.kernel_isa.is_empty(), "kernel ISA auto-detects by default");
        r.set("exact", "true").unwrap();
        r.set("kernel_isa", "scalar").unwrap();
        assert!(r.exact);
        assert_eq!(r.kernel_isa, "scalar");
        r.set("kernel_isa", "auto").unwrap();
        assert_eq!(r.kernel_isa, "auto");
        assert!(r.set("exact", "yep").is_err());
        assert!(r.set("kernel_isa", "sse9").is_err(), "typo'd ISA fails at set time");
    }

    #[test]
    fn router_overrides() {
        let mut r = RuntimeConfig::default();
        assert_eq!(r.fleet, 2);
        assert_eq!(r.client_cap, 0);
        assert_eq!(r.failover_retries, 2, "failover on by default");
        assert!(r.fault.is_empty(), "no fault plan by default");
        r.set("fleet", "4").unwrap();
        r.set("sessions_per_worker", "8").unwrap();
        r.set("route_queue", "32").unwrap();
        r.set("client_cap", "2").unwrap();
        r.set("health_interval_ms", "250").unwrap();
        r.set("failover_retries", "0").unwrap();
        r.set("fault", "seed=7;kill_after=3").unwrap();
        assert_eq!(r.fleet, 4);
        assert_eq!(r.sessions_per_worker, 8);
        assert_eq!(r.route_queue, 32);
        assert_eq!(r.client_cap, 2);
        assert_eq!(r.health_interval_ms, 250);
        assert_eq!(r.failover_retries, 0);
        assert_eq!(r.fault, "seed=7;kill_after=3");
        assert!(r.set("fleet", "0").is_err());
        assert!(r.set("sessions_per_worker", "0").is_err());
        assert!(r.set("health_interval_ms", "0").is_err());
        assert!(r.set("fault", "frobnicate=1").is_err(), "typo'd fault spec fails at set time");
    }

    #[test]
    fn observability_overrides() {
        let mut r = RuntimeConfig::default();
        assert_eq!(r.trace_sample, 0, "tracing is off by default");
        assert!(r.log_json.is_empty(), "no JSONL sink by default");
        r.set("trace_sample", "64").unwrap();
        r.set("log_json", "-").unwrap();
        assert_eq!(r.trace_sample, 64);
        assert_eq!(r.log_json, "-");
        r.set("log_json", "/tmp/events.jsonl").unwrap();
        assert_eq!(r.log_json, "/tmp/events.jsonl");
        assert!(r.set("trace_sample", "often").is_err());
    }

    #[test]
    fn config_file_parsing() {
        let dir = std::env::temp_dir().join("bmoe_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.cfg");
        std::fs::write(&p, "# comment\nsteps = 42\n\nlr=0.5 # inline\n").unwrap();
        let mut r = RuntimeConfig::default();
        r.load_file(&p).unwrap();
        assert_eq!(r.steps, 42);
        assert_eq!(r.lr, 0.5);
    }
}
