//! Flight recorder: a fixed-size lock-free ring of the most recent
//! events, dumped for postmortems (DESIGN.md §7).
//!
//! Every emitted event lands here regardless of whether a JSONL sink is
//! configured, so a crash always has recent history.  The ring holds
//! the last [`RING`] rendered lines; writers claim a monotonically
//! increasing slot sequence and `swap` their boxed entry into
//! `slot = seq % RING` — each swap transfers unique ownership of the
//! previous pointer, so concurrent writers never free the same entry
//! and never block.
//!
//! [`dump`] drains the ring (swapping nulls back in), sorts by
//! sequence, and writes `bmoe-flight-<pid>.jsonl` into the flight
//! directory (`BMOE_FLIGHT_DIR`, else the OS temp dir; tests override
//! via [`set_dir`]).  It is called from the installed panic hook, from
//! the router when a worker is declared down, and from the server's
//! protocol-`ERR` paths.  Draining means each event appears in at most
//! one dump; the newest dump wins the fixed file name.

use std::path::PathBuf;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// Ring capacity (events). 256 recent events ≈ the last few seconds of
/// session/worker lifecycle at serving rates — enough context for a
/// worker-lost postmortem without unbounded memory.
pub const RING: usize = 256;

struct Entry {
    seq: u64,
    line: String,
}

static SLOT_SEQ: AtomicU64 = AtomicU64::new(0);
static CELLS: OnceLock<Box<[AtomicPtr<Entry>]>> = OnceLock::new();
static DIR_OVERRIDE: Mutex<Option<PathBuf>> = Mutex::new(None);

fn cells() -> &'static [AtomicPtr<Entry>] {
    CELLS.get_or_init(|| {
        (0..RING)
            .map(|_| AtomicPtr::new(std::ptr::null_mut()))
            .collect()
    })
}

/// Append one rendered event line to the ring (lock-free).
pub fn record(line: &str) {
    let seq = SLOT_SEQ.fetch_add(1, Ordering::Relaxed);
    let entry = Box::into_raw(Box::new(Entry {
        seq,
        line: line.to_string(),
    }));
    let prev = cells()[(seq % RING as u64) as usize].swap(entry, Ordering::AcqRel);
    if !prev.is_null() {
        // the swap made us the unique owner of the displaced entry
        unsafe { drop(Box::from_raw(prev)) };
    }
}

/// Override the dump directory (tests).  `None` restores the default
/// (`BMOE_FLIGHT_DIR` env var, else the OS temp dir).
pub fn set_dir(dir: Option<PathBuf>) {
    *DIR_OVERRIDE.lock().unwrap_or_else(|e| e.into_inner()) = dir;
}

fn dir() -> PathBuf {
    if let Some(d) = DIR_OVERRIDE
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone()
    {
        return d;
    }
    match std::env::var_os("BMOE_FLIGHT_DIR") {
        Some(d) => PathBuf::from(d),
        None => std::env::temp_dir(),
    }
}

/// The path this process dumps to.
pub fn dump_path() -> PathBuf {
    dir().join(format!("bmoe-flight-{}.jsonl", std::process::id()))
}

/// Drain the ring and write a postmortem dump.  The first line is a
/// `flight_dump` header (reason + timestamp), followed by the drained
/// events in emission order.  Returns the path on success; failures are
/// swallowed (a postmortem writer must never take the process down).
pub fn dump(reason: &str) -> Option<PathBuf> {
    let mut entries: Vec<Entry> = Vec::with_capacity(RING);
    for cell in cells() {
        let p = cell.swap(std::ptr::null_mut(), Ordering::AcqRel);
        if !p.is_null() {
            entries.push(*unsafe { Box::from_raw(p) });
        }
    }
    entries.sort_by_key(|e| e.seq);
    let path = dump_path();
    let header = crate::jsonx::Json::obj(vec![
        ("event", crate::jsonx::Json::str("flight_dump")),
        ("reason", crate::jsonx::Json::str(reason)),
        (
            "ts_us",
            crate::jsonx::Json::num(super::monotonic_us() as f64),
        ),
        (
            "pid",
            crate::jsonx::Json::num(std::process::id() as f64),
        ),
        ("events", crate::jsonx::Json::num(entries.len() as f64)),
    ]);
    let mut body = String::with_capacity(64 * (entries.len() + 1));
    body.push_str(&header.to_string());
    body.push('\n');
    for e in &entries {
        body.push_str(&e.line);
        body.push('\n');
    }
    match std::fs::write(&path, body) {
        Ok(()) => {
            // plain stderr, not an event: emitting here would re-seed
            // the ring we just drained (and recurse through dispatch)
            eprintln!("[obs] flight recorder dumped ({reason}) -> {}", path.display());
            Some(path)
        }
        Err(_) => None,
    }
}

/// Serializes tests that mutate the process-global ring or the dump
/// directory override (this module's and the router's flight tests).
#[doc(hidden)]
pub static TEST_MUTEX: Mutex<()> = Mutex::new(());

static PANIC_HOOK: Once = Once::new();

/// Chain a dump onto the process panic hook (idempotent): any panic
/// writes the flight dump first, then runs the previous hook.
pub fn install_panic_hook() {
    PANIC_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let _ = dump("panic");
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_most_recent_and_dump_orders_by_seq() {
        let _g = TEST_MUTEX.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("bmoe_obs_flight_test");
        std::fs::create_dir_all(&dir).unwrap();
        set_dir(Some(dir));
        let _ = dump("drain-before-test"); // start from an empty ring
        for i in 0..(RING + 50) {
            record(&format!("{{\"i\":{i}}}"));
        }
        let path = dump("test").expect("dump writes");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("\"flight_dump\""), "{}", lines[0]);
        assert!(lines[0].contains("\"reason\":\"test\""), "{}", lines[0]);
        // capacity-bounded: at most RING events survive, the newest win
        // (unrelated tests may emit events concurrently, so assert
        // containment rather than exact ring contents)
        assert!(lines.len() <= 1 + RING, "{} lines", lines.len());
        assert!(
            text.contains(&format!("{{\"i\":{}}}", RING + 49)),
            "newest record must survive"
        );
        assert!(
            !text.contains("{\"i\":0}"),
            "oldest records must be displaced"
        );
        // emission order: i-records appear sorted by seq
        let idx: Vec<usize> = lines
            .iter()
            .filter_map(|l| l.strip_prefix("{\"i\":")?.strip_suffix('}')?.parse().ok())
            .collect();
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "dump must be seq-ordered");
        // dump drains: a second dump carries none of our records
        let path2 = dump("again").unwrap();
        let text2 = std::fs::read_to_string(&path2).unwrap();
        assert!(!text2.contains("{\"i\":"), "drained ring must not re-dump");
        set_dir(None);
        let _ = std::fs::remove_file(&path);
    }
}
