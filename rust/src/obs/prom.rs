//! Prometheus text-exposition encoder for the `METRICS` wire verb
//! (DESIGN.md §7).
//!
//! Hand-rolled against the text format v0.0.4: `# HELP` / `# TYPE`
//! comment lines once per metric family, `name{label="value"} value`
//! sample lines, histograms as cumulative `_bucket{le="..."}` series
//! plus `_sum` and `_count`.  The reply is framed by a final `# EOF`
//! line so wire clients (and the router's fleet aggregation) know where
//! the exposition ends without closing the connection.
//!
//! [`inject_label`] is the router's relabeling half: it adds a
//! `worker="wN"` pair to every sample line of a scraped worker
//! exposition, so fleet-aggregated series stay distinguishable.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use crate::util::stats::LatencyHistogram;

/// The terminator line framing a `METRICS` reply on the wire.
pub const EOF_LINE: &str = "# EOF";

/// Escape a label *value* per the exposition format: backslash, double
/// quote, and newline.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn fmt_labels(labels: &[(&str, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// Builder for one exposition document.  HELP/TYPE headers are emitted
/// once per family even when a family is written several times with
/// different label sets (e.g. one histogram per stage/layer).
#[derive(Default)]
pub struct PromText {
    out: String,
    seen: BTreeSet<String>,
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    fn header(&mut self, name: &str, help: &str, typ: &str) {
        if self.seen.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {typ}");
        }
    }

    fn sample(&mut self, name: &str, labels: &[(&str, String)], value: f64) {
        let _ = writeln!(self.out, "{name}{} {value}", fmt_labels(labels));
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, String)], value: f64) {
        self.header(name, help, "counter");
        self.sample(name, labels, value);
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, String)], value: f64) {
        self.header(name, help, "gauge");
        self.sample(name, labels, value);
    }

    /// Write one histogram series: cumulative `le` buckets (ascending,
    /// closed by `+Inf` carrying `n`), then `_sum` and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, String)],
        h: &LatencyHistogram,
    ) {
        self.header(name, help, "histogram");
        let bucket = format!("{name}_bucket");
        for (le, c) in h.cumulative_buckets() {
            let mut ls: Vec<(&str, String)> = labels.to_vec();
            ls.push(("le", format!("{le}")));
            self.sample(&bucket, &ls, c as f64);
        }
        let mut ls: Vec<(&str, String)> = labels.to_vec();
        ls.push(("le", "+Inf".to_string()));
        self.sample(&bucket, &ls, h.n as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum);
        self.sample(&format!("{name}_count"), labels, h.n as f64);
    }

    /// Finish the document: append the `# EOF` frame and return it.
    pub fn finish(mut self) -> String {
        self.out.push_str(EOF_LINE);
        self.out.push('\n');
        self.out
    }

    /// The document so far, unframed (router aggregation concatenates
    /// several parts before framing once).
    pub fn into_unframed(self) -> String {
        self.out
    }
}

/// Add `key="value"` to every sample line of an exposition fragment
/// (comment lines and blanks pass through).  Lines that already carry
/// labels get the pair prepended inside the braces; bare-name lines
/// grow a label set.
pub fn inject_label(text: &str, key: &str, value: &str) -> String {
    let mut out = String::with_capacity(text.len() + 32);
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            out.push_str(line);
        } else if let Some(brace) = line.find('{') {
            out.push_str(&line[..brace + 1]);
            let _ = write!(out, "{key}=\"{}\",", escape_label(value));
            out.push_str(&line[brace + 1..]);
        } else if let Some(space) = line.find(' ') {
            out.push_str(&line[..space]);
            let _ = write!(out, "{{{key}=\"{}\"}}", escape_label(value));
            out.push_str(&line[space..]);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label(r#"a"b"#), r#"a\"b"#);
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
        let mut p = PromText::new();
        p.gauge("g", "h", &[("k", "v\"w\n\\x".to_string())], 1.0);
        let text = p.finish();
        assert!(text.contains(r#"g{k="v\"w\n\\x"} 1"#), "{text}");
    }

    #[test]
    fn headers_once_per_family_and_eof_frame() {
        let mut p = PromText::new();
        p.counter("c_total", "help", &[("a", "1".into())], 2.0);
        p.counter("c_total", "help", &[("a", "2".into())], 3.0);
        let text = p.finish();
        assert_eq!(text.matches("# HELP c_total").count(), 1);
        assert_eq!(text.matches("# TYPE c_total counter").count(), 1);
        assert_eq!(text.matches("c_total{a=").count(), 2);
        assert!(text.ends_with("# EOF\n"));
    }

    #[test]
    fn histogram_buckets_are_monotone_and_sum_count_consistent() {
        let mut h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        let mut p = PromText::new();
        p.histogram("lat_seconds", "help", &[], &h);
        let text = p.finish();
        // parse the bucket series back out
        let mut counts = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("lat_seconds_bucket{le=\"") {
                let (_le, tail) = rest.split_once("\"}").unwrap();
                counts.push(tail.trim().parse::<f64>().unwrap());
            }
        }
        assert!(counts.len() >= 2, "{text}");
        assert!(
            counts.windows(2).all(|w| w[1] >= w[0]),
            "bucket counts must be cumulative/monotone: {counts:?}"
        );
        assert_eq!(*counts.last().unwrap(), 100.0, "+Inf bucket carries n");
        // _count == n, _sum == recorded sum
        let count_line = text
            .lines()
            .find(|l| l.starts_with("lat_seconds_count"))
            .unwrap();
        assert_eq!(count_line, "lat_seconds_count 100");
        let sum_line = text
            .lines()
            .find(|l| l.starts_with("lat_seconds_sum"))
            .unwrap();
        let sum: f64 = sum_line.split_whitespace().nth(1).unwrap().parse().unwrap();
        assert!((sum - h.sum).abs() < 1e-12, "{sum_line} vs {}", h.sum);
        assert!(text.contains("# TYPE lat_seconds histogram"));
    }

    #[test]
    fn inject_label_handles_bare_and_labeled_lines() {
        let src = "# HELP x h\n# TYPE x counter\nx 5\ny{a=\"b\"} 7\n";
        let out = inject_label(src, "worker", "w3");
        assert!(out.contains("# HELP x h\n"), "{out}");
        assert!(out.contains("x{worker=\"w3\"} 5\n"), "{out}");
        assert!(out.contains("y{worker=\"w3\",a=\"b\"} 7\n"), "{out}");
    }
}
