//! Observability: sampled hot-path tracing, structured events, flight
//! recorder, and Prometheus exposition (DESIGN.md §7).
//!
//! Zero-dependency and determinism-neutral by construction — nothing in
//! this module influences decoded bits:
//!
//! * [`trace`] — per-stage latency sampling around the serving hot path
//!   (gather → rotate → ternary/cached GEMM → reduce → down-project,
//!   plus scheduler step and cache tick).  Off by default: the cost at
//!   every instrumented site is one relaxed atomic load and a branch.
//!   `--trace-sample N` records every Nth occurrence per stage into a
//!   per-(stage, layer) [`LatencyHistogram`](crate::util::stats::
//!   LatencyHistogram).  Timers only read the clock and write into a
//!   side registry, so token streams are bit-identical with tracing on
//!   or off at any rate (pinned by rust/tests/determinism.rs).
//! * [`event`] — one structured logger for the whole stack: typed
//!   session/worker lifecycle events and human log lines, rendered as
//!   JSONL (`--log-json <path|->`) with monotonic µs timestamps and a
//!   global sequence number.  Human log lines also mirror to stderr
//!   (on by default) in the `[component] message` format the scattered
//!   `eprintln!`s used, so operator UX is unchanged.
//! * [`flight`] — a fixed-size lock-free ring of the most recent
//!   events, dumped to `bmoe-flight-<pid>.jsonl` from the panic hook,
//!   on worker death, and on protocol `ERR` — postmortems for
//!   `ERR worker lost` have history even when no JSONL sink was set.
//! * [`prom`] — the Prometheus text-exposition encoder behind the
//!   `METRICS` wire verb (serve: process metrics + per-stage
//!   histograms; route: fleet aggregation with `worker="wN"` labels).

pub mod event;
pub mod flight;
pub mod prom;
pub mod trace;

pub use event::{log, set_stderr_mirror, Event};
pub use trace::{stage_timer, Stage, StageTimer, DEFAULT_SAMPLE};

use std::sync::OnceLock;
use std::time::Instant;

static PROCESS_START: OnceLock<Instant> = OnceLock::new();

/// Monotonic microseconds since the first call in this process — the
/// timestamp every event carries.  Monotonic (never wall-clock) so
/// event ordering survives clock steps.
pub fn monotonic_us() -> u64 {
    PROCESS_START.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// One-stop initialization from the CLI/runtime config: set the trace
/// sample rate, open the JSONL sink (`""` = none, `"-"` = stdout), and
/// install the flight-recorder panic hook.  Idempotent.
pub fn init(trace_sample: u32, log_json: &str) -> anyhow::Result<()> {
    let _ = monotonic_us(); // pin the epoch before any event
    trace::set_sample(trace_sample);
    if !log_json.is_empty() {
        event::set_json_sink(log_json)?;
    }
    flight::install_panic_hook();
    Ok(())
}
