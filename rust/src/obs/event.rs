//! The structured event logger (DESIGN.md §7).
//!
//! One emission path for the whole stack.  Every event is rendered once
//! as a compact JSON object carrying `ts_us` (monotonic µs since
//! process start), `seq` (global per-process counter), `pid`, `event`
//! (the kind), and the caller's typed fields, then fanned out to:
//!
//! 1. the flight-recorder ring (always — postmortems need history even
//!    with no sink configured),
//! 2. the JSONL sink when `--log-json <path|->` set one (append mode;
//!    `-` = stdout),
//! 3. for [`log`] lines only: a human-readable stderr mirror
//!    (`[component] message`, on by default) — the exact format the
//!    pre-obs `eprintln!` sites used, so operator output is unchanged.
//!
//! Lifecycle events (session enqueue/admit/first-token/finish/cancel/
//! shed/error, worker spawn/up/down/restart/drain) are *not* mirrored
//! to stderr: they are machine telemetry, and mirroring them would spam
//! a terminal at session rate.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::jsonx::Json;

use super::flight;

/// A typed event under construction (builder style):
///
/// ```ignore
/// obs::Event::new("session_finish")
///     .u64("session", id)
///     .str("reason", "max_tokens")
///     .u64("tokens", n)
///     .emit();
/// ```
pub struct Event {
    kind: &'static str,
    fields: Vec<(&'static str, Json)>,
}

impl Event {
    pub fn new(kind: &'static str) -> Event {
        Event {
            kind,
            fields: Vec::new(),
        }
    }

    pub fn u64(mut self, key: &'static str, v: u64) -> Event {
        self.fields.push((key, Json::num(v as f64)));
        self
    }

    pub fn f64(mut self, key: &'static str, v: f64) -> Event {
        self.fields.push((key, Json::num(v)));
        self
    }

    pub fn str(mut self, key: &'static str, v: impl Into<String>) -> Event {
        self.fields.push((key, Json::Str(v.into())));
        self
    }

    /// Render once, stamp ts/seq/pid, and fan out (ring + sink).
    pub fn emit(self) {
        let line = render(self.kind, &self.fields);
        dispatch(&line);
    }
}

static SEQ: AtomicU64 = AtomicU64::new(0);
static STDERR_MIRROR: AtomicBool = AtomicBool::new(true);

enum Sink {
    Stdout,
    File(std::fs::File),
}

static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// Toggle the human-readable stderr mirror for [`log`] lines (default
/// on).
pub fn set_stderr_mirror(on: bool) {
    STDERR_MIRROR.store(on, Ordering::Relaxed);
}

/// Point the JSONL sink at `path` (append + create), or stdout for
/// `"-"`.  Every subsequent event goes there, one JSON object per line.
pub fn set_json_sink(path: &str) -> Result<()> {
    let sink = if path == "-" {
        Sink::Stdout
    } else {
        Sink::File(
            std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .with_context(|| format!("open --log-json {path}"))?,
        )
    };
    *SINK.lock().unwrap_or_else(|e| e.into_inner()) = Some(sink);
    Ok(())
}

/// Render the canonical JSONL form.  `seq` is claimed here so ring and
/// sink agree on ordering.
fn render(kind: &str, fields: &[(&'static str, Json)]) -> String {
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let mut pairs: Vec<(&str, Json)> = vec![
        ("ts_us", Json::num(super::monotonic_us() as f64)),
        ("seq", Json::num(seq as f64)),
        ("pid", Json::num(std::process::id() as f64)),
        ("event", Json::str(kind)),
    ];
    pairs.extend(fields.iter().map(|(k, v)| (*k, v.clone())));
    Json::obj(pairs).to_string()
}

fn dispatch(line: &str) {
    flight::record(line);
    let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
    match sink.as_mut() {
        None => {}
        Some(Sink::Stdout) => {
            let mut out = std::io::stdout().lock();
            let _ = writeln!(out, "{line}");
        }
        Some(Sink::File(f)) => {
            let _ = writeln!(f, "{line}");
        }
    }
}

/// Human log line: mirrors to stderr as `[component] message` (unless
/// the mirror is off) and emits a structured `log` event.  This is the
/// drop-in replacement for the old ad-hoc `eprintln!("[x] ...")` sites.
pub fn log(component: &str, msg: impl AsRef<str>) {
    let msg = msg.as_ref();
    if STDERR_MIRROR.load(Ordering::Relaxed) {
        eprintln!("[{component}] {msg}");
    }
    let line = render(
        "log",
        &[
            ("component", Json::str(component)),
            ("msg", Json::str(msg)),
        ],
    );
    dispatch(&line);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rendered_events_are_valid_jsonl_with_envelope() {
        let line = render(
            "session_finish",
            &[
                ("session", Json::num(42.0)),
                ("reason", Json::str("max_tokens")),
            ],
        );
        assert!(!line.contains('\n'), "one line per event");
        let v = Json::parse(&line).expect("line parses as JSON");
        assert_eq!(v.get("event").unwrap().as_str().unwrap(), "session_finish");
        assert_eq!(v.get("session").unwrap().as_usize().unwrap(), 42);
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "max_tokens");
        assert!(v.get("ts_us").unwrap().as_f64().is_some());
        assert!(v.get("seq").unwrap().as_f64().is_some());
        assert!(v.get("pid").unwrap().as_f64().is_some());
    }

    #[test]
    fn seq_is_strictly_increasing_across_renders() {
        let a = render("a", &[]);
        let b = render("b", &[]);
        let sa = Json::parse(&a).unwrap().get("seq").unwrap().as_f64().unwrap();
        let sb = Json::parse(&b).unwrap().get("seq").unwrap().as_f64().unwrap();
        assert!(sb > sa, "seq must increase: {sa} then {sb}");
    }

    #[test]
    fn json_sink_receives_events() {
        let dir = std::env::temp_dir().join("bmoe_obs_event_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("events-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        set_json_sink(path.to_str().unwrap()).unwrap();
        Event::new("test_sink_event").u64("k", 7).emit();
        // detach so other tests don't keep appending here
        *SINK.lock().unwrap_or_else(|e| e.into_inner()) = None;
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text
            .lines()
            .find(|l| l.contains("test_sink_event"))
            .expect("event written to sink");
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("k").unwrap().as_usize().unwrap(), 7);
        let _ = std::fs::remove_file(&path);
    }
}
